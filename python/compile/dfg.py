"""DFG + schedule interchange loader (the contract with the Rust side).

Reads ``benchmarks/dfg/<kernel>.json`` as emitted by ``tmfu export-dfg``
(see ``rust/src/sched/mod.rs::program_to_json``) and re-derives the
per-stage execution structure independently, so the Python compile path
cross-checks the Rust scheduler rather than trusting it blindly.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field

OPS = ("add", "sub", "mul", "and", "or", "xor")


@dataclass(frozen=True)
class Node:
    kind: str  # input | const | op | output
    name: str | None = None
    value: int | None = None
    op: str | None = None
    args: tuple[int, ...] = ()


@dataclass
class Stage:
    stage: int
    ops: list[int]
    arrivals: list[int]
    bypasses: list[int]
    consts: list[tuple[int, int]]  # (node id, value)
    n_loads: int
    n_execs: int

    @property
    def emissions(self) -> list[int]:
        """Values this stage's FU sends downstream, in issue order."""
        return list(self.ops) + list(self.bypasses)


@dataclass
class Kernel:
    name: str
    nodes: list[Node]
    stages: list[Stage]
    ii: int
    latency: int
    output_order: list[tuple[str, int]]
    inputs: list[int] = field(default_factory=list)
    outputs: list[int] = field(default_factory=list)

    @property
    def n_inputs(self) -> int:
        return len(self.inputs)

    @property
    def n_outputs(self) -> int:
        return len(self.outputs)

    @property
    def n_fus(self) -> int:
        return len(self.stages)

    @property
    def n_ops(self) -> int:
        return sum(1 for n in self.nodes if n.kind == "op")


def _parse_node(j: dict) -> Node:
    kind = j["kind"]
    if kind == "input":
        return Node(kind, name=j["name"])
    if kind == "const":
        v = int(j["value"])
        assert -(2**31) <= v < 2**31, f"const {v} out of i32 range"
        return Node(kind, value=v)
    if kind == "op":
        op = j["op"]
        assert op in OPS, f"unknown op {op}"
        args = tuple(int(a) for a in j["args"])
        assert len(args) == 2
        return Node(kind, op=op, args=args)
    if kind == "output":
        return Node(kind, name=j["name"], args=tuple(int(a) for a in j["args"]))
    raise ValueError(f"unknown node kind {kind!r}")


def load(path: str) -> Kernel:
    """Load and validate one kernel JSON."""
    with open(path) as f:
        doc = json.load(f)
    dfg = doc["dfg"]
    sched = doc["schedule"]
    nodes = [_parse_node(n) for n in dfg["nodes"]]
    # Topological validation.
    for i, n in enumerate(nodes):
        for a in n.args:
            assert a < i, f"node {i}: forward reference {a}"
    stages = [
        Stage(
            stage=int(s["stage"]),
            ops=[int(v) for v in s["ops"]],
            arrivals=[int(v) for v in s["arrivals"]],
            bypasses=[int(v) for v in s["bypasses"]],
            consts=[(int(c["node"]), int(c["value"])) for c in s["consts"]],
            n_loads=int(s["n_loads"]),
            n_execs=int(s["n_execs"]),
        )
        for s in sched["stages"]
    ]
    k = Kernel(
        name=dfg["name"],
        nodes=nodes,
        stages=stages,
        ii=int(sched["ii"]),
        latency=int(sched["latency"]),
        output_order=[(o["name"], int(o["pos"])) for o in sched["output_order"]],
        inputs=[i for i, n in enumerate(nodes) if n.kind == "input"],
        outputs=[i for i, n in enumerate(nodes) if n.kind == "output"],
    )
    _cross_check(k)
    return k


def _cross_check(k: Kernel) -> None:
    """Independently re-derive the stage structure and compare with the
    Rust scheduler's output (defence against interchange drift)."""
    # ASAP levels.
    level = [0] * len(k.nodes)
    for i, n in enumerate(k.nodes):
        if n.kind == "op":
            level[i] = 1 + max(level[a] for a in n.args)
        elif n.kind == "output":
            level[i] = level[n.args[0]]
    depth = max((level[i] for i, n in enumerate(k.nodes) if n.kind == "op"), default=0)
    assert depth == k.n_fus, f"{k.name}: depth {depth} != stages {k.n_fus}"
    for s in k.stages:
        for op in s.ops:
            assert level[op] == s.stage, f"{k.name}: op {op} mis-staged"
        # Consistency of load/exec counts.
        assert s.n_loads == len(s.arrivals)
        assert s.n_execs == len(s.ops) + len(s.bypasses)
    # Emissions of stage s == arrivals of stage s+1.
    for a, b in zip(k.stages, k.stages[1:]):
        assert a.emissions == b.arrivals, f"{k.name}: dataflow mismatch {a.stage}->{b.stage}"
    # II from the paper's model: max stage cost + 2 flush cycles.
    ii = max(s.n_loads + s.n_execs for s in k.stages) + 2
    assert ii == k.ii, f"{k.name}: II {ii} != {k.ii}"


def load_all(dfg_dir: str) -> dict[str, Kernel]:
    out = {}
    for fn in sorted(os.listdir(dfg_dir)):
        if fn.endswith(".json"):
            k = load(os.path.join(dfg_dir, fn))
            out[k.name] = k
    return out


def default_dfg_dir() -> str:
    """benchmarks/dfg relative to the repo root (python/ is cwd for the
    compile path; tests may run from elsewhere)."""
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.normpath(os.path.join(here, "..", "..", "benchmarks", "dfg"))
