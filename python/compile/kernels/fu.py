"""L1 — the time-multiplexed FU stage as a Pallas kernel.

One pipeline stage of the overlay executes a short, *statically known*
instruction list against its register file for every data packet. That
is exactly the shape Pallas wants: the instruction list is unrolled at
trace time (the overlay analogue of "the context is already loaded"),
the RF block lives in VMEM, and the batch dimension plays the role of
pipeline replication (DESIGN.md §Hardware-Adaptation).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that both the Python
tests and the Rust runtime execute (see /opt/xla-example/README.md).

VMEM accounting (per grid step, int32):
    RF tile      : TILE_B x n_arrivals x 4  bytes
    emit tile    : TILE_B x n_execs    x 4  bytes
With TILE_B = 256 and the paper's RF bound (32), a stage tile is at
most 256*32*4 = 32 KiB in + 32 KiB out — comfortably inside a TPU
core's ~16 MiB VMEM, leaving headroom to fuse all stages of an 8-FU
pipeline in one kernel if desired (see DESIGN.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.dfg import Kernel, Stage

# Batch tile: one grid step processes this many packets.
TILE_B = 256

_OPS = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "xor": lambda a, b: a ^ b,
}


def _stage_instrs(k: Kernel, s: Stage):
    """Materialize the stage's instruction list as static Python data:
    (kind, op, src1, src2) where src is ('rf', col) or ('const', val).

    Mirrors rust/src/sched/program.rs: RF slots are arrival order;
    constants live at slots 31 downward but here resolve to literals.
    """
    slot_of = {v: i for i, v in enumerate(s.arrivals)}
    const_of = dict(s.consts)

    def src(node_id: int):
        if node_id in slot_of:
            return ("rf", slot_of[node_id])
        if node_id in const_of:
            return ("const", const_of[node_id])
        raise KeyError(f"{k.name} stage {s.stage}: operand {node_id} not in RF")

    instrs = []
    for op_id in s.ops:
        n = k.nodes[op_id]
        instrs.append(("arith", n.op, src(n.args[0]), src(n.args[1])))
    for v in s.bypasses:
        instrs.append(("bypass", None, src(v), None))
    return instrs


def stage_kernel(k: Kernel, s: Stage):
    """Build the Pallas kernel for one FU stage.

    Returns a function int32[B, n_arrivals] -> int32[B, n_execs]
    (B must be a multiple of TILE_B or smaller than it).
    """
    instrs = _stage_instrs(k, s)
    n_arr = len(s.arrivals)
    n_out = len(instrs)

    def body(rf_ref, out_ref):
        rf = rf_ref[...]  # (tile, n_arr) in VMEM

        def read(src):
            kind, v = src
            if kind == "rf":
                return rf[:, v]
            return jnp.full(rf.shape[0], jnp.int32(v))

        # The context's instruction list, fully unrolled: one DSP issue
        # per instruction, exactly as the hardware time-multiplexes.
        for j, (kind, op, s1, s2) in enumerate(instrs):
            if kind == "arith":
                res = _OPS[op](read(s1), read(s2)).astype(jnp.int32)
            else:  # bypass: route the RF word through unchanged
                res = read(s1)
            out_ref[:, j] = res

    def call(x):
        b = x.shape[0]
        assert x.shape == (b, n_arr), (x.shape, n_arr)
        tile = min(TILE_B, b)
        assert b % tile == 0, f"batch {b} not a multiple of tile {tile}"
        return pl.pallas_call(
            body,
            grid=(b // tile,),
            in_specs=[pl.BlockSpec((tile, n_arr), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((tile, n_out), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((b, n_out), jnp.int32),
            interpret=True,
        )(x)

    return call


def stage_reference(k: Kernel, s: Stage):
    """Plain-jnp reference for one stage (used by the kernel-vs-ref
    tests; the full-model oracle is kernels.ref.eval_dfg)."""
    instrs = _stage_instrs(k, s)

    def call(x):
        cols = []
        for kind, op, s1, s2 in instrs:
            def read(src):
                knd, v = src
                if knd == "rf":
                    return x[:, v]
                return jnp.full(x.shape[0], jnp.int32(v))

            if kind == "arith":
                cols.append(_OPS[op](read(s1), read(s2)).astype(jnp.int32))
            else:
                cols.append(read(s1))
        return jnp.stack(cols, axis=1)

    return call

