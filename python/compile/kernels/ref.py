"""Pure-jnp correctness oracle: direct topological evaluation of the DFG
with wrapping int32 semantics (identical to the Rust functional oracle
and the DSP48E1 model).
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.dfg import Kernel

_OPS = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "xor": lambda a, b: a ^ b,
}


def eval_dfg(k: Kernel, x: jnp.ndarray) -> jnp.ndarray:
    """Evaluate the kernel over a batch.

    x: int32[batch, n_inputs] -> int32[batch, n_outputs]
    """
    assert x.ndim == 2 and x.shape[1] == k.n_inputs, (x.shape, k.n_inputs)
    x = x.astype(jnp.int32)
    values: list[jnp.ndarray | None] = [None] * len(k.nodes)
    next_input = 0
    outs = []
    for i, n in enumerate(k.nodes):
        if n.kind == "input":
            values[i] = x[:, next_input]
            next_input += 1
        elif n.kind == "const":
            values[i] = jnp.full(x.shape[0], jnp.int32(n.value))
        elif n.kind == "op":
            a, b = values[n.args[0]], values[n.args[1]]
            values[i] = _OPS[n.op](a, b).astype(jnp.int32)
        else:  # output
            v = values[n.args[0]]
            values[i] = v
            outs.append(v)
    return jnp.stack(outs, axis=1)
