"""AOT lowering: JAX (+Pallas) -> HLO **text** -> artifacts/.

For every kernel in ``benchmarks/dfg/`` this emits
``artifacts/<name>.hlo.txt`` plus a ``manifest.json`` describing the
entry points (shapes, II, FU counts) for the Rust runtime.

HLO *text* is the interchange format, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids
(see /opt/xla-example/README.md and gen_hlo.py there).

Python runs ONCE, at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import dfg
from compile.model import build_model

# Batch buckets the artifacts are compiled for; the Rust runtime picks
# the smallest bucket that fits a request batch (bucketed batching, like
# serving systems use) and zero-pads to it.
BATCHES = (8, 64, 256)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_kernel(k: dfg.Kernel, batch: int) -> str:
    model = build_model(k, use_pallas=True)
    spec = jax.ShapeDtypeStruct((batch, k.n_inputs), jax.numpy.int32)
    return to_hlo_text(jax.jit(model).lower(spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--dfg-dir", default=dfg.default_dfg_dir())
    ap.add_argument(
        "--batches",
        default=",".join(str(b) for b in BATCHES),
        help="comma-separated batch buckets",
    )
    ap.add_argument("--only", help="comma-separated kernel subset")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    kernels = dfg.load_all(args.dfg_dir)
    if args.only:
        keep = set(args.only.split(","))
        kernels = {n: k for n, k in kernels.items() if n in keep}

    batches = sorted(int(b) for b in str(args.batches).split(","))
    manifest = {"batch": batches[-1], "batches": batches, "kernels": {}}
    for name, k in sorted(kernels.items()):
        artifacts = {}
        for b in batches:
            hlo = lower_kernel(k, b)
            fname = f"{name}.b{b}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(hlo)
            digest = hashlib.sha256(hlo.encode()).hexdigest()[:16]
            artifacts[str(b)] = {"file": fname, "sha256_16": digest}
            print(f"lowered {name} (batch {b}): {len(hlo)} chars of HLO")
        manifest["kernels"][name] = {
            "artifacts": artifacts,
            "n_inputs": k.n_inputs,
            "n_outputs": k.n_outputs,
            "n_ops": k.n_ops,
            "n_fus": k.n_fus,
            "ii": k.ii,
            "latency": k.latency,
            "context_bytes": 5 * sum(s.n_execs for s in k.stages),
        }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')} "
          f"({len(manifest['kernels'])} kernels, batches {batches})")


if __name__ == "__main__":
    main()
