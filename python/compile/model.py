"""L2 — the scheduled overlay program as a JAX computation.

The model composes the per-stage Pallas FU kernels linearly, exactly
mirroring the hardware dataflow the Rust scheduler produced: the
emissions of stage *s* are the arrivals of stage *s+1* (the Rust side
asserts this with ``Program::check_dataflow``; the Python loader
re-checks it on load). The final stage's emissions are projected onto
the named outputs via the schedule's ``output_order``.

This function is what ``aot.py`` lowers to HLO text; the Rust runtime
executes it on the request path through PJRT.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.dfg import Kernel
from compile.kernels import fu


def build_model(k: Kernel, use_pallas: bool = True):
    """Return f(int32[B, n_inputs]) -> int32[B, n_outputs]."""
    builders = fu.stage_kernel if use_pallas else fu.stage_reference
    stage_fns = [builders(k, s) for s in k.stages]
    out_pos = [pos for (_, pos) in k.output_order]

    def model(x: jnp.ndarray) -> jnp.ndarray:
        assert x.ndim == 2 and x.shape[1] == k.n_inputs, (x.shape, k.n_inputs)
        data = x.astype(jnp.int32)
        # The linear FU cascade. Stage 1's arrivals are the primary
        # inputs in declaration order (= FIFO order).
        for fn in stage_fns:
            data = fn(data)
        # Output FIFO projection.
        return data[:, jnp.array(out_pos, dtype=jnp.int32)]

    return model


def batched_shape(k: Kernel, batch: int):
    return (batch, k.n_inputs)
