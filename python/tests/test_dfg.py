"""Loader + interchange validation for benchmarks/dfg/*.json."""

import numpy as np
import pytest

from compile import dfg

KERNELS = dfg.load_all(dfg.default_dfg_dir())

PAPER_II = {
    "chebyshev": 6,
    "sgfilter": 10,
    "mibench": 11,
    "qspline": 18,
    "poly5": 14,
    "poly6": 17,
    "poly7": 17,
    "poly8": 15,
    "gradient": 11,
}

PAPER_OPS = {
    "chebyshev": 7,
    "sgfilter": 18,
    "mibench": 13,
    "qspline": 26,
    "poly5": 27,
    "poly6": 44,
    "poly7": 39,
    "poly8": 32,
    "gradient": 11,
}


def test_all_nine_kernels_present():
    assert set(KERNELS) == set(PAPER_II)


@pytest.mark.parametrize("name", sorted(PAPER_II))
def test_ii_matches_paper(name):
    assert KERNELS[name].ii == PAPER_II[name]


@pytest.mark.parametrize("name", sorted(PAPER_OPS))
def test_op_counts_match_paper(name):
    assert KERNELS[name].n_ops == PAPER_OPS[name]


@pytest.mark.parametrize("name", sorted(PAPER_II))
def test_stage_dataflow_chains(name):
    k = KERNELS[name]
    for a, b in zip(k.stages, k.stages[1:]):
        assert a.emissions == b.arrivals


def test_gradient_structure():
    g = KERNELS["gradient"]
    assert g.n_inputs == 5
    assert g.n_outputs == 1
    assert g.n_fus == 4
    assert [len(s.ops) for s in g.stages] == [4, 4, 2, 1]
    assert [s.n_loads for s in g.stages] == [5, 4, 4, 2]


def test_rf_capacity_respected():
    for k in KERNELS.values():
        for s in k.stages:
            assert s.n_loads + len(s.consts) <= 32, (k.name, s.stage)
            assert s.n_execs <= 32, (k.name, s.stage)


def test_loader_rejects_corrupt_json(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(
        '{"dfg": {"name": "x", "nodes": [{"kind": "input", "name": "a"},'
        '{"kind": "op", "op": "add", "args": [0, 5]},'
        '{"kind": "output", "name": "o", "args": [1]}]},'
        '"schedule": {"n_stages": 1, "ii": 3, "latency": 4, "stages": [],'
        '"output_order": []}}'
    )
    with pytest.raises(AssertionError):
        dfg.load(str(bad))


def test_numpy_int32_wrapping_assumption():
    # The whole stack relies on int32 wrap-around; verify the platform.
    a = np.int32(2**31 - 1)
    with np.errstate(over="ignore"):
        assert np.int32(a + np.int32(1)) == np.int32(-(2**31))
