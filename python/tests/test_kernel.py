"""L1 correctness: the Pallas FU stage kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compute layer: every stage
of every benchmark, swept over batch shapes and adversarial int32 data
(hypothesis), must agree bit-for-bit with the reference.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import dfg
from compile.kernels import fu, ref
from compile.model import build_model

KERNELS = dfg.load_all(dfg.default_dfg_dir())
NAMES = sorted(KERNELS)

i32 = st.integers(min_value=-(2**31), max_value=2**31 - 1)


def rand_batch(rng, b, n):
    return rng.integers(-(2**31), 2**31, size=(b, n), dtype=np.int64).astype(np.int32)


@pytest.mark.parametrize("name", NAMES)
def test_every_stage_kernel_matches_reference(name):
    k = KERNELS[name]
    rng = np.random.default_rng(42)
    for s in k.stages:
        x = rand_batch(rng, 32, s.n_loads)
        got = np.asarray(fu.stage_kernel(k, s)(jnp.asarray(x)))
        want = np.asarray(fu.stage_reference(k, s)(jnp.asarray(x)))
        np.testing.assert_array_equal(got, want, err_msg=f"{name} stage {s.stage}")


@pytest.mark.parametrize("name", NAMES)
def test_full_model_matches_dfg_oracle(name):
    k = KERNELS[name]
    rng = np.random.default_rng(7)
    x = jnp.asarray(rand_batch(rng, 64, k.n_inputs))
    got = np.asarray(build_model(k, use_pallas=True)(x))
    want = np.asarray(ref.eval_dfg(k, x))
    np.testing.assert_array_equal(got, want)


def test_model_handles_extreme_values():
    k = KERNELS["poly6"]
    x = jnp.asarray(
        np.array(
            [
                [2**31 - 1, -(2**31), -1],
                [0, 0, 0],
                [1, -1, 2**30],
                [-(2**31), 2**31 - 1, 2**31 - 1],
            ],
            dtype=np.int32,
        )
    )
    got = np.asarray(build_model(k)(x))
    want = np.asarray(ref.eval_dfg(k, x))
    np.testing.assert_array_equal(got, want)


def test_gradient_known_value():
    k = KERNELS["gradient"]
    x = jnp.asarray(np.array([[3, 5, 2, 7, 1]], dtype=np.int32))
    out = np.asarray(build_model(k)(x))
    assert out.shape == (1, 1)
    assert out[0, 0] == (3 - 2) ** 2 + (5 - 2) ** 2 + (2 - 7) ** 2 + (2 - 1) ** 2


def test_chebyshev_polynomial_identity():
    k = KERNELS["chebyshev"]
    xs = np.arange(-8, 9, dtype=np.int32).reshape(-1, 1)
    out = np.asarray(build_model(k)(jnp.asarray(xs)))[:, 0]
    x64 = xs[:, 0].astype(np.int64)
    want = (16 * x64**5 - 20 * x64**3 + 5 * x64).astype(np.int32)
    np.testing.assert_array_equal(out, want)


@settings(max_examples=25, deadline=None)
@given(
    data=st.lists(st.tuples(i32, i32, i32), min_size=1, max_size=8),
    name=st.sampled_from(["mibench", "poly5", "poly8"]),
)
def test_hypothesis_trivariate_kernels(data, name):
    """Adversarial int32 inputs on the 3-input kernels."""
    k = KERNELS[name]
    x = jnp.asarray(np.array(data, dtype=np.int64).astype(np.int32))
    got = np.asarray(build_model(k)(x))
    want = np.asarray(ref.eval_dfg(k, x))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(batch=st.sampled_from([1, 2, 3, 5, 8, 16, 64, 256, 512]))
def test_hypothesis_batch_shapes(batch):
    """The kernel must handle any batch size (tiling under TILE_B, grid
    over it)."""
    k = KERNELS["sgfilter"]
    rng = np.random.default_rng(batch)
    x = jnp.asarray(rand_batch(rng, batch, k.n_inputs))
    got = np.asarray(build_model(k)(x))
    want = np.asarray(ref.eval_dfg(k, x))
    np.testing.assert_array_equal(got, want)


def test_bypass_instructions_are_identity_lanes():
    """Bypassed values must come through the stage kernel unchanged."""
    k = KERNELS["chebyshev"]
    s = k.stages[1]  # stage 2 has arrivals [h1, x] and a bypass of x
    assert len(s.bypasses) == 1
    x = jnp.asarray(np.array([[7, 11], [-3, 5]], dtype=np.int32))
    out = np.asarray(fu.stage_kernel(k, s)(x))
    # emission order: [op result, bypassed x]
    bypass_col = out[:, 1]
    slot = s.arrivals.index(s.bypasses[0])
    np.testing.assert_array_equal(bypass_col, np.asarray(x)[:, slot])
