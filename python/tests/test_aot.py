"""AOT lowering contract: HLO text format, manifest consistency, and
the guarantee that the lowered computation (what the Rust runtime
executes) matches the oracle numerically.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, dfg
from compile.kernels import ref
from compile.model import build_model

KERNELS = dfg.load_all(dfg.default_dfg_dir())


def test_hlo_text_emits_for_small_kernel():
    k = KERNELS["gradient"]
    hlo = aot.lower_kernel(k, batch=8)
    # HLO text header + int32 typed entry computation.
    assert "HloModule" in hlo
    assert "s32[8,5]" in hlo, hlo[:400]
    # return_tuple=True -> tuple root.
    assert "s32[8,1]" in hlo


def test_lowered_computation_matches_oracle():
    """Execute the exact jitted function that aot lowers (CPU PJRT here,
    the Rust runtime loads the same HLO) and compare with the oracle."""
    k = KERNELS["mibench"]
    model = jax.jit(build_model(k))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-(2**31), 2**31, size=(16, k.n_inputs),
                                 dtype=np.int64).astype(np.int32))
    np.testing.assert_array_equal(np.asarray(model(x)), np.asarray(ref.eval_dfg(k, x)))


def test_manifest_matches_kernels_if_built():
    """When `make artifacts` has run, the manifest must agree with the
    committed schedules."""
    art = os.path.join(os.path.dirname(dfg.default_dfg_dir()), "..", "artifacts")
    man_path = os.path.normpath(os.path.join(art, "manifest.json"))
    if not os.path.exists(man_path):
        pytest.skip("artifacts not built")
    with open(man_path) as f:
        man = json.load(f)
    assert man["batch"] >= 1
    assert man["batches"] == sorted(man["batches"])
    assert set(man["kernels"]) == set(KERNELS)
    for name, e in man["kernels"].items():
        k = KERNELS[name]
        assert e["n_inputs"] == k.n_inputs
        assert e["n_outputs"] == k.n_outputs
        assert e["ii"] == k.ii
        assert e["n_fus"] == k.n_fus
        assert set(int(b) for b in e["artifacts"]) == set(man["batches"])
        for b, a in e["artifacts"].items():
            hlo_path = os.path.normpath(os.path.join(art, a["file"]))
            assert os.path.exists(hlo_path), hlo_path
            with open(hlo_path) as f:
                head = f.read(4096)
            assert "HloModule" in head
            assert f"s32[{b}," in head


def test_pallas_and_reference_models_lower_identically_shaped_hlo():
    """Both model variants must produce the same output shape/dtype."""
    k = KERNELS["chebyshev"]
    spec = jax.ShapeDtypeStruct((8, k.n_inputs), jnp.int32)
    out_p = jax.eval_shape(build_model(k, use_pallas=True), spec)
    out_r = jax.eval_shape(build_model(k, use_pallas=False), spec)
    assert out_p.shape == out_r.shape == (8, k.n_outputs)
    assert out_p.dtype == out_r.dtype == jnp.int32
