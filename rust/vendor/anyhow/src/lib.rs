//! Minimal, offline-vendored reimplementation of the `anyhow` API
//! surface this workspace uses.
//!
//! The build image has no crates.io access, so the crate is vendored as
//! a path dependency. It provides: [`Error`] (a boxed dynamic error
//! with a context chain), [`Result`], the [`Context`] extension trait
//! for `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics match upstream `anyhow` for this subset, except
//! that `Display` shows the full context chain (`ctx: cause`) rather
//! than only the outermost layer — strictly more informative for the
//! CLI and test output this repo produces.

use std::fmt;

/// A dynamic error: a message plus an optional source chain, cheap to
/// construct and `Send + Sync` so it can cross worker threads.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error {
            msg: msg.to_string(),
            source: None,
        }
    }

    /// Wrap an underlying error with a context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error {
            msg: format!("{ctx}: {}", self.msg),
            source: self.source,
        }
    }

    /// The root-cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn std::error::Error + 'static)> {
        let mut next = self
            .source
            .as_deref()
            .map(|e| e as &(dyn std::error::Error + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        for cause in self.chain() {
            write!(f, "\n\ncaused by: {cause}")?;
        }
        Ok(())
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`;
// exactly like upstream `anyhow`, that keeps the blanket `From` below
// coherent (no overlap with the reflexive `From<T> for T`).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error {
            msg: e.to_string(),
            source: Some(Box::new(e)),
        }
    }
}

/// `anyhow::Result<T>` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
        assert_eq!(e.chain().count(), 1);
    }

    #[test]
    fn context_wraps_message() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading manifest").unwrap_err();
        assert_eq!(e.to_string(), "loading manifest: missing");
    }

    #[test]
    fn with_context_is_lazy() {
        let mut called = false;
        let ok: std::result::Result<i32, std::io::Error> = Ok(7);
        let v = ok
            .with_context(|| {
                called = true;
                "ctx"
            })
            .unwrap_or_default();
        assert_eq!(v, 7);
        assert!(!called, "context closure ran on the Ok path");
    }

    #[test]
    fn option_context() {
        let none: Option<i32> = None;
        assert_eq!(none.context("empty").unwrap_err().to_string(), "empty");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 41;
        let e = anyhow!("value {}", x + 1);
        assert_eq!(e.to_string(), "value 42");
        let e = anyhow!(io_err());
        assert_eq!(e.to_string(), "missing");
        fn f(flag: bool) -> Result<u8> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable for true? no: always bails");
        }
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        assert!(f(true).is_err());
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
