//! Regenerates the paper's Table III: area (e-Slices) and throughput
//! (GOPS) for the proposed overlay vs SCFU-SCN [13] vs Vivado HLS.

use tmfu_overlay::report::table3;
use tmfu_overlay::util::bench::section;

fn main() -> anyhow::Result<()> {
    section("Table III: area & throughput");
    print!("{}", table3::render()?);
    println!("\nnotes:");
    println!(" - proposed Tput/Area reproduce the paper exactly (ops*f/II; FUs*141 e-Slices)");
    println!(" - SCFU-SCN area uses OUR structural mapping model (no placement slack),");
    println!("   so it lower-bounds the paper's island-grid numbers; paper column shown beside");
    println!(" - HLS areas come from our binding estimator; fmax is the calibrated table");
    Ok(())
}
