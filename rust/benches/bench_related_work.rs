//! Regenerates the paper's §II related-work comparison: FU cost,
//! instruction storage and context-switch mechanism for CARBON, SCGRA,
//! reMORPH, TILT and this paper's FU.

use tmfu_overlay::baseline::related::{self, RELATED};
use tmfu_overlay::resources::ZYNQ_Z7020;
use tmfu_overlay::util::bench::section;
use tmfu_overlay::util::table::Table;

fn main() {
    section("§II related-work FU comparison");
    let mut t = Table::new("Per-FU cost (as reported by the respective papers)").header(&[
        "overlay", "platform", "LUT/ALM", "FF", "DSP", "BRAM kb", "fmax MHz", "IM depth",
        "instr bits", "IM bits", "switch path",
    ]);
    for r in &RELATED {
        t.row(&[
            r.name.to_string(),
            r.platform.to_string(),
            r.luts_or_alms.to_string(),
            r.ffs.to_string(),
            r.dsps.to_string(),
            format!("{:.1}", r.bram_kbits),
            format!("{:.0}", r.fmax_mhz),
            r.im_depth.to_string(),
            r.instr_bits.to_string(),
            r.instr_storage_bits().to_string(),
            format!("{:?}", r.switch),
        ]);
    }
    print!("{}", t.render());
    println!("\ninstruction-storage blow-up vs this paper's 32x32b IM:");
    for r in &RELATED[..4] {
        println!(
            "  {:<14} {:>6.0}x",
            r.name,
            related::instruction_storage_ratio(r)
        );
    }
    println!(
        "\nTILT system datapoint: 8-core TILT {} eALMs / {} Minputs/s vs OpenCL HLS {} eALMs / {} Minputs/s",
        related::TILT_8CORE_EALMS,
        related::TILT_8CORE_MINPUTS,
        related::TILT_HLS_EALMS,
        related::TILT_HLS_MINPUTS
    );
    println!(
        "this paper's FU on the common scale: {} e-Slices @ 325 MHz",
        RELATED[4].eslices(&ZYNQ_Z7020)
    );
}
