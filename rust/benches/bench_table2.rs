//! Regenerates the paper's Table II: DFG characteristics of the
//! benchmark set (measured by our frontend + scheduler vs paper).

use tmfu_overlay::report::table2;
use tmfu_overlay::util::bench::{section, Bench};
use tmfu_overlay::{bench_suite, frontend};

fn main() -> anyhow::Result<()> {
    section("Table II: DFG characteristics");
    print!("{}", table2::render()?);
    println!("(measured II matches the paper on all 8 rows; edges are within ±10% —");
    println!(" the paper's edge-count convention is unspecified, see EXPERIMENTS.md)");

    section("frontend microbenchmark");
    let b = Bench::from_env();
    let (_, src) = bench_suite::KERNEL_SOURCES
        .iter()
        .find(|(n, _)| *n == "poly6")
        .unwrap();
    let m = b.run("frontend::compile(poly6, 44 ops)", || {
        frontend::compile(src).unwrap()
    });
    println!("{}", m.report_line());
    Ok(())
}
