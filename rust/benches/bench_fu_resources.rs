//! Regenerates the paper's §III.A synthesis results from the
//! structural resource/fmax models (FU, 8-FU pipeline, Virtex-7).

use tmfu_overlay::report::resources_report;
use tmfu_overlay::util::bench::section;

fn main() {
    section("§III.A resources & frequency");
    print!("{}", resources_report::render());
}
