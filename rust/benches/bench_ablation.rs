//! Ablation studies for the design choices DESIGN.md calls out, plus
//! the paper's §VI future work ("architectural modifications to reduce
//! the II") implemented and measured:
//!
//!  A. double-buffered-RF FU: II / throughput / area trade-off
//!  B. pipeline replication (Fig. 4): effective II vs resources
//!  C. SCFU-SCN interconnect reach sweep (baseline sensitivity)
//!  D. instruction-memory depth: IM sizing vs kernel fit + context time

use tmfu_overlay::arch::{fu_db, PipelineDb};
use tmfu_overlay::bench_suite::{self, constants::PROPOSED_FREQ_MHZ};
use tmfu_overlay::dfg::Levels;
use tmfu_overlay::resources::{estimate, ZYNQ_Z7020};
use tmfu_overlay::sched::{Program, Routing, Timing};
use tmfu_overlay::util::bench::section;
use tmfu_overlay::util::table::Table;

fn main() -> anyhow::Result<()> {
    let dev = &ZYNQ_Z7020;

    // ----------------------------------------------------------------
    section("A. double-buffered RF (§VI future work, implemented)");
    let fu_base = estimate::fu().eslices(dev);
    let fu_db_es = estimate::fu_double_buffered().eslices(dev);
    println!(
        "FU cost: single-bank {fu_base} e-Slices; double-buffered {fu_db_es} e-Slices (+{:.0}%)\n",
        (fu_db_es as f64 / fu_base as f64 - 1.0) * 100.0
    );
    let mut t = Table::new("II / throughput / efficiency (measured, cycle-accurate)").header(&[
        "benchmark",
        "II base",
        "II db",
        "tput base GOPS",
        "tput db GOPS",
        "area db",
        "MOPS/eSl base",
        "MOPS/eSl db",
    ]);
    for name in bench_suite::table2_names() {
        let g = bench_suite::load(name)?;
        let p = Program::schedule(&g)?;
        let base = Timing::of(&p);
        let ii_db = fu_db::ii_double_buffered(&p);
        // Verify the analytical II dynamically.
        let mut pl = PipelineDb::new(&p, 4096)?;
        let packets: Vec<Vec<i32>> = (0..8).map(|k| vec![k as i32; g.inputs().len()]).collect();
        let measured = pl.measure_ii(&packets)?;
        assert!((measured - ii_db as f64).abs() < 1e-9, "{name}");
        let ops = g.n_ops();
        let tput_base = base.gops(ops, PROPOSED_FREQ_MHZ);
        let tput_db = ops as f64 * PROPOSED_FREQ_MHZ * 1e6 / ii_db as f64 / 1e9;
        let area_base = p.n_fus() * fu_base;
        let area_db = p.n_fus() * fu_db_es;
        t.row(&[
            name.to_string(),
            base.ii.to_string(),
            ii_db.to_string(),
            format!("{tput_base:.2}"),
            format!("{tput_db:.2}"),
            area_db.to_string(),
            format!("{:.2}", tput_base * 1e3 / area_base as f64),
            format!("{:.2}", tput_db * 1e3 / area_db as f64),
        ]);
    }
    print!("{}", t.render());
    println!("(double buffering removes the flush+drain serialization: II = max(loads, execs))");

    // ----------------------------------------------------------------
    section("B. pipeline replication (Fig. 4)");
    let mut t = Table::new("gradient: replicas vs effective II and resources").header(&[
        "replicas",
        "eff II",
        "GOPS",
        "DSPs",
        "LUTs",
        "BRAMs",
        "Zynq util %",
    ]);
    let g = bench_suite::load("gradient")?;
    let p = Program::schedule(&g)?;
    let base = Timing::of(&p);
    for r in [1u32, 2, 4, 8, 16] {
        let eff_ii = base.ii as f64 / r as f64;
        let gops = g.n_ops() as f64 * PROPOSED_FREQ_MHZ * 1e6 / eff_ii / 1e9;
        let res = estimate::overlay(r, p.n_fus());
        t.row(&[
            r.to_string(),
            format!("{eff_ii:.2}"),
            format!("{gops:.2}"),
            res.dsps.to_string(),
            res.luts.to_string(),
            res.bram36.to_string(),
            format!("{:.1}", ZYNQ_Z7020.utilization(&res) * 100.0),
        ]);
    }
    print!("{}", t.render());

    // ----------------------------------------------------------------
    section("C. SCFU-SCN interconnect reach sweep (baseline sensitivity)");
    let mut t = Table::new("pass-through FUs under different interconnect reach").header(&[
        "benchmark", "ops", "R=1", "R=2 (model)", "R=3", "R=4", "paper",
    ]);
    for row in &bench_suite::PAPER_ROWS {
        let g = bench_suite::load(row.name)?;
        let levels = Levels::of(&g);
        let routing = Routing::of(&g, &levels);
        let fus_at = |reach: u32| -> u32 {
            let mut pass = 0u32;
            for route in routing.routes.values() {
                let last = route
                    .consumer_stages
                    .iter()
                    .copied()
                    .filter(|&c| c <= levels.depth)
                    .max()
                    .unwrap_or(route.producer);
                let mut cur = route.producer;
                while last > cur + reach {
                    cur += reach;
                    pass += 1;
                }
            }
            g.n_ops() as u32 + pass
        };
        t.row(&[
            row.name.to_string(),
            row.ops.to_string(),
            fus_at(1).to_string(),
            fus_at(2).to_string(),
            fus_at(3).to_string(),
            fus_at(4).to_string(),
            row.fus_scfu.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("(R=2 is the model used for Fig. 5/Table III; paper counts include island-grid");
    println!(" placement slack our model does not charge)");

    // ----------------------------------------------------------------
    section("D. instruction-memory depth");
    let mut t = Table::new("IM sizing: worst-case instructions per FU").header(&[
        "benchmark",
        "max instrs/FU",
        "fits IM16",
        "fits IM32 (paper)",
        "ctx bytes",
        "switch us @300MHz",
    ]);
    for name in bench_suite::table2_names() {
        let g = bench_suite::load(name)?;
        let p = Program::schedule(&g)?;
        let worst = p.stages.iter().map(|s| s.n_execs()).max().unwrap();
        let img = p.context_image()?;
        t.row(&[
            name.to_string(),
            worst.to_string(),
            (worst <= 16).to_string(),
            (worst <= 32).to_string(),
            img.size_bytes_instr_only().to_string(),
            format!("{:.3}", img.size_bytes_instr_only() as f64 / 5.0 / 300.0),
        ]);
    }
    print!("{}", t.render());
    println!("(every benchmark fits a 16-entry IM; the paper's 32-entry IM doubles headroom");
    println!(" at zero BRAM cost because RAM32M is natively 32 deep)");

    // ----------------------------------------------------------------
    section("E. ASAP vs ALAP stage allocation");
    let mut t = Table::new("scheduling policy: II and context size").header(&[
        "benchmark",
        "II asap",
        "II alap",
        "ctx B asap",
        "ctx B alap",
        "bypasses asap",
        "bypasses alap",
    ]);
    for name in bench_suite::table2_names() {
        let g = bench_suite::load(name)?;
        let asap = Program::schedule(&g)?;
        let alap = Program::schedule_alap(&g)?;
        let byp = |p: &Program| p.stages.iter().map(|s| s.bypasses.len()).sum::<usize>();
        t.row(&[
            name.to_string(),
            Timing::of(&asap).ii.to_string(),
            Timing::of(&alap).ii.to_string(),
            asap.context_image()?.size_bytes_instr_only().to_string(),
            alap.context_image()?.size_bytes_instr_only().to_string(),
            byp(&asap).to_string(),
            byp(&alap).to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("(the paper uses ASAP; ALAP sinks ops toward consumers, trading bypass");
    println!(" instructions between stages — useful when a kernel overflows one FU's IM)");
    Ok(())
}
