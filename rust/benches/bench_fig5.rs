//! Regenerates the paper's Fig. 5: number of FUs required per
//! benchmark (proposed linear overlay vs SCFU-SCN [13]).

use tmfu_overlay::report::fig5;
use tmfu_overlay::util::bench::section;

fn main() -> anyhow::Result<()> {
    section("Fig. 5: FUs required");
    print!("{}", fig5::render()?);
    Ok(())
}
