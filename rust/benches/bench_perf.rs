//! Performance benchmarks for the serving hot paths:
//!
//!   B1   backend packets/s per kernel (ref vs turbo, flat batches;
//!        sim at a smaller batch — it simulates every fabric cycle),
//!        plus an allocation audit proving the turbo hot path stays
//!        allocation-free per packet
//!   B2   cycle-accurate simulator inner loop (simulated cycles/s)
//!   B3   scheduler + context + tape generation (compilations/s)
//!   B4   service dispatch through `KernelHandle` (requests/s
//!        end-to-end, ids pre-resolved once)
//!   B5   wire loopback: the same calls through `tmfu listen` framing
//!        over a unix socket vs the in-process handle — the JSON
//!        reports the per-call and per-packet framing overhead
//!   B7   router forwarding: the same call through `tmfu router`
//!        fronting the wire backend — the JSON reports the added
//!        per-call store-and-forward overhead of the fault-tolerant
//!        hop
//!   B8   tenant fairness: a polite tenant's serial calls while a
//!        greedy tenant floods the same single-worker service — the
//!        JSON reports the fair tenant's p99 under abuse, which the
//!        smoke gate bounds against the flooder's own mean
//!   B9   deadline shedding: the p99 latency of a typed refusal under
//!        a 64k-row overload vs the unbudgeted backlog wait, plus the
//!        per-call cost of reclaiming a cancelled call's slot — the
//!        smoke gate bounds the shed p99 against the no-shed baseline
//!   L2/L1 PJRT batch execution (artifact-gated)
//!
//! Run `TMFU_BENCH_FAST=1 cargo bench` for a quick pass. With
//! `-- --json <path>` the measurements (plus the headline
//! turbo-vs-ref speedup on poly6 at batch 1024) are written as JSON —
//! `make bench` uses this to produce the checked-in perf trajectory
//! baseline (`BENCH_PR10.json`).

use tmfu_overlay::arch::Pipeline;
use tmfu_overlay::bench_suite;
use tmfu_overlay::client::OverlayClient;
use tmfu_overlay::exec::{
    Backend, BackendKind, FlatBatch, KernelRegistry, RefBackend, SimBackend, TurboBackend,
};
use tmfu_overlay::router::{Router, RouterConfig};
use tmfu_overlay::runtime::Engine;
use tmfu_overlay::sched::Program;
use tmfu_overlay::service::{KernelHandle, OverlayService};
use tmfu_overlay::wire::server::WireServer;
use tmfu_overlay::wire::ListenAddr;
use tmfu_overlay::util::bench::{
    alloc_count, black_box, json_path_from_args, os_thread_count, section, thread_alloc_count,
    Bench, BenchReport, CountingAlloc,
};
use tmfu_overlay::util::json;
use tmfu_overlay::util::prng::Rng;

/// Count heap allocations so the hot-path audit below can assert the
/// steady state allocates per *batch*, not per packet.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// The headline batch size: large enough to amortize dispatch and let
/// the turbo backend's lane chunking matter.
const BATCH: usize = 1024;
/// Headline kernel (the suite's largest: 44 ops, depth 11).
const HEADLINE_KERNEL: &str = "poly6";
/// Acceptance floor for this PR: the SIMD-lowered turbo interpreter
/// must be >= 20x ref on poly6 @ 1024 (raised from the 10x contract
/// the scalar chunked interpreter shipped under).
const HEADLINE_FLOOR: f64 = 20.0;

fn random_batch(rng: &mut Rng, arity: usize, rows: usize) -> FlatBatch {
    let mut b = FlatBatch::with_capacity(arity, rows);
    for _ in 0..rows {
        b.push_iter((0..arity).map(|_| rng.next_i32()));
    }
    b
}

fn main() -> anyhow::Result<()> {
    let b = Bench::from_env();
    let mut report = BenchReport::new();
    report.set_meta("harness", json::s("cargo-bench (util::bench self-timed)"));
    report.set_meta("batch", json::i(BATCH as i64));
    report.set_meta(
        "fast_mode",
        json::s(if std::env::var("TMFU_BENCH_FAST").as_deref() == Ok("1") {
            "1"
        } else {
            "0"
        }),
    );
    let reg = KernelRegistry::compile_bench_suite()?;
    let mut rng = Rng::new(3);

    section("B1 backend packets/s (flat batches)");
    for name in ["gradient", "chebyshev", "poly6", "qspline"] {
        let k = reg.get(name).unwrap().clone();
        let batch = random_batch(&mut rng, k.n_inputs, BATCH);
        let mut rb = RefBackend::new();
        let m = b.run_with_items(&format!("ref::execute({name}, batch {BATCH})"), BATCH as f64, || {
            rb.execute(&k, black_box(&batch)).unwrap()
        });
        println!("{}   (items = packets)", report.record(m).report_line());
        let mut tb = TurboBackend::new();
        let m = b.run_with_items(
            &format!("turbo::execute({name}, batch {BATCH})"),
            BATCH as f64,
            || tb.execute(&k, black_box(&batch)).unwrap(),
        );
        println!("{}   (items = packets)", report.record(m).report_line());
        // The cycle-accurate substrate pays for every fabric cycle;
        // bench it at a batch it can sustain in the measure window.
        let sim_batch_n = 64;
        let sim_batch = random_batch(&mut rng, k.n_inputs, sim_batch_n);
        let mut sb = SimBackend::new(1, 4096)?;
        let m = b.run_with_items(
            &format!("sim::execute({name}, batch {sim_batch_n})"),
            sim_batch_n as f64,
            || sb.execute(&k, black_box(&sim_batch)).unwrap(),
        );
        println!("{}   (items = packets)", report.record(m).report_line());
    }

    // Headline: the PR 2 acceptance ratio.
    let ref_tput = report
        .get(&format!("ref::execute({HEADLINE_KERNEL}, batch {BATCH})"))
        .and_then(|m| m.throughput())
        .unwrap_or(0.0);
    let turbo_tput = report
        .get(&format!("turbo::execute({HEADLINE_KERNEL}, batch {BATCH})"))
        .and_then(|m| m.throughput())
        .unwrap_or(0.0);
    let speedup = if ref_tput > 0.0 { turbo_tput / ref_tput } else { 0.0 };
    report.set_meta("headline_kernel", json::s(HEADLINE_KERNEL));
    report.set_meta("turbo_speedup_vs_ref", json::f(speedup));
    // Same ratio under its PR 6 name: the turbo interpreter's lane
    // loops are now lowered to explicit 8-wide chunk kernels, so the
    // headline measures the SIMD interpreter against scalar ref.
    report.set_meta("turbo_simd_speedup_vs_ref", json::f(speedup));
    report.set_meta("turbo_speedup_floor", json::f(HEADLINE_FLOOR));
    println!(
        "\nheadline: turbo {turbo_tput:.0} pkt/s vs ref {ref_tput:.0} pkt/s on \
         {HEADLINE_KERNEL} @ {BATCH} -> {speedup:.1}x (floor {HEADLINE_FLOOR:.0}x: {})",
        if speedup >= HEADLINE_FLOOR { "PASS" } else { "MISS" }
    );

    // Allocation audit: in steady state the turbo execute path must
    // allocate O(1) per *batch* (the output buffer), never per packet.
    // Single-threaded here — no service workers are running yet.
    {
        let k = reg.get(HEADLINE_KERNEL).unwrap().clone();
        let mut rng2 = Rng::new(17);
        let batch = random_batch(&mut rng2, k.n_inputs, BATCH);
        let mut tb = TurboBackend::new();
        for _ in 0..3 {
            black_box(tb.execute(&k, black_box(&batch)).unwrap());
        }
        let audit_iters = 16u64;
        let before = alloc_count();
        for _ in 0..audit_iters {
            black_box(tb.execute(&k, black_box(&batch)).unwrap());
        }
        let per_batch = (alloc_count() - before) as f64 / audit_iters as f64;
        println!(
            "allocation audit: {per_batch:.1} heap allocations per {BATCH}-packet \
             turbo batch (bound: < 1 per 32 packets)"
        );
        report.set_meta("turbo_allocs_per_batch", json::f(per_batch));
        assert!(
            per_batch < (BATCH / 32) as f64,
            "turbo hot path allocated {per_batch:.1} times per {BATCH}-packet batch — \
             the allocation-free steady state regressed"
        );
    }

    section("B2 cycle-accurate simulator (simulated cycles/s)");
    for name in ["gradient", "chebyshev", "poly6"] {
        let g = bench_suite::load(name)?;
        let p = Program::schedule(&g)?;
        let n_in = g.inputs().len();
        let packets: Vec<Vec<i32>> = (0..64).map(|k| vec![k as i32; n_in]).collect();
        // cycles per packet ~= II in steady state; count items = cycles.
        let mut probe = Pipeline::new(&p, 4096)?;
        let before = probe.cycle;
        probe.run(&packets, 1_000_000)?;
        let cycles_per_run = (probe.cycle - before) as f64;
        let m = b.run_with_items(&format!("sim::cycles({name}, 64 packets)"), cycles_per_run, || {
            let mut pl = Pipeline::new(&p, 4096).unwrap();
            pl.run(black_box(&packets), 1_000_000).unwrap()
        });
        println!(
            "{}   (items = simulated cycles)",
            report.record(m).report_line()
        );
    }

    section("B3 compiler path");
    let (_, src) = bench_suite::KERNEL_SOURCES
        .iter()
        .find(|(n, _)| *n == "poly7")
        .unwrap();
    let m = b.run("frontend+schedule+context+tape(poly7)", || {
        let g = tmfu_overlay::frontend::compile(src).unwrap();
        let k = tmfu_overlay::exec::CompiledKernel::compile(g).unwrap();
        black_box(k.tape.len())
    });
    println!("{}", report.record(m).report_line());

    section("B4 service dispatch through KernelHandle (zero artifacts)");
    for kind in [BackendKind::Sim, BackendKind::Turbo] {
        let service = OverlayService::builder()
            .backend(kind)
            .pipelines(2)
            .max_batch(32)
            .build()?;
        // Sessions resolve names and arities exactly once, outside the
        // measured loop; inputs are pre-built so the measured path is
        // submit + dispatch + reply.
        let handles: Vec<KernelHandle> = service.handles();
        let inputs: Vec<Vec<i32>> = handles.iter().map(|h| vec![1i32; h.arity()]).collect();
        let m = b.run_with_items(&format!("service::call x32 ({kind})"), 32.0, || {
            for i in 0..32usize {
                let j = i % handles.len();
                handles[j].call(black_box(&inputs[j])).unwrap();
            }
        });
        println!(
            "{}   (items = requests, serial round-trip)",
            report.record(m).report_line()
        );
        service.shutdown()?;
    }

    section("B5 wire loopback (unix socket) vs in-process KernelHandle");
    {
        let service = std::sync::Arc::new(
            OverlayService::builder()
                .backend(BackendKind::Turbo)
                .pipelines(2)
                .max_batch(32)
                .build()?,
        );
        let sock =
            std::env::temp_dir().join(format!("tmfu-bench-wire-{}.sock", std::process::id()));
        let addr = ListenAddr::Unix(sock.clone());
        let server = WireServer::bind(std::sync::Arc::clone(&service), &addr)?;
        let client = OverlayClient::connect(&format!("unix:{}", sock.display()))?;
        let local = service.kernel("gradient")?;
        let remote = client.kernel("gradient")?;
        let inputs = [3, 5, 2, 7, 1];

        // Same request, same service, same workers — the only delta is
        // framing + socket + request-id correlation.
        let m_local = b.run_with_items("service::call(gradient) in-process", 1.0, || {
            local.call(black_box(&inputs)).unwrap()
        });
        println!("{}   (items = requests)", report.record(m_local.clone()).report_line());
        let m_wire = b.run_with_items("wire::call(gradient) unix loopback", 1.0, || {
            remote.call(black_box(&inputs)).unwrap()
        });
        println!("{}   (items = requests)", report.record(m_wire.clone()).report_line());
        let call_overhead_us = (m_wire.mean_ns - m_local.mean_ns) / 1e3;
        report.set_meta("wire_call_overhead_us", json::f(call_overhead_us));

        // Batch path: 256 rows amortize the framing to a per-packet
        // overhead (rows cross as one contiguous buffer each way).
        let wire_batch_n = 256usize;
        let mut rngw = Rng::new(23);
        let batch = random_batch(&mut rngw, local.arity(), wire_batch_n);
        let m_local_b = b.run_with_items(
            &format!("service::call_batch(gradient, {wire_batch_n}) in-process"),
            wire_batch_n as f64,
            || local.call_batch(black_box(&batch)).unwrap(),
        );
        println!("{}   (items = packets)", report.record(m_local_b.clone()).report_line());
        let m_wire_b = b.run_with_items(
            &format!("wire::call_batch(gradient, {wire_batch_n}) unix loopback"),
            wire_batch_n as f64,
            || remote.call_batch(black_box(&batch)).unwrap(),
        );
        println!("{}   (items = packets)", report.record(m_wire_b.clone()).report_line());
        let batch_overhead_us =
            (m_wire_b.mean_ns - m_local_b.mean_ns) / 1e3 / wire_batch_n as f64;
        report.set_meta("wire_batch_overhead_us_per_packet", json::f(batch_overhead_us));
        println!(
            "\nwire overhead: {call_overhead_us:.1} us/call single, \
             {batch_overhead_us:.3} us/packet at batch {wire_batch_n} \
             (framing + unix socket + correlation)"
        );

        drop(remote);
        drop(client);
        server.shutdown();
        service.shutdown()?;
    }

    section("B6 in-flight scaling (completion-slab reactor)");
    {
        const INFLIGHT: usize = 10_000;
        let service = std::sync::Arc::new(
            OverlayService::builder()
                .backend(BackendKind::Turbo)
                .pipelines(2)
                .max_batch(256)
                .queue_depth(2 * INFLIGHT)
                .build()?,
        );
        let h = service.kernel("gradient")?;

        // 10k concurrent submits in-process: every reply is a slab
        // ticket, so the burst costs slots, not channels or threads.
        let mut pendings = Vec::with_capacity(INFLIGHT);
        let mut out = Vec::new();
        let m = b.run_with_items(
            &format!("service::submit {INFLIGHT} in-flight (turbo)"),
            INFLIGHT as f64,
            || {
                for i in 0..INFLIGHT {
                    pendings.push(h.submit(black_box(&[3, 5, 2, 7, i as i32])).unwrap());
                }
                for mut p in pendings.drain(..) {
                    p.wait_into(&mut out).unwrap();
                }
                black_box(out.len())
            },
        );
        println!("{}   (items = requests)", report.record(m.clone()).report_line());
        report.set_meta("inflight_10k_items_per_s", json::f(m.throughput().unwrap_or(0.0)));

        // Allocation audit: after warm-up, a submit -> wait_into round
        // trip must perform exactly zero heap allocations on the
        // calling thread (the slab slot, its buffers, the queue entry
        // and the reply buffer all recycle). Thread-local counting
        // keeps concurrent worker-side bookkeeping out of the audit.
        {
            for i in 0..2048i32 {
                let mut p = h.submit(&[3, 5, 2, 7, i]).unwrap();
                p.wait_into(&mut out).unwrap();
            }
            let audit_calls = 4096u64;
            let before = thread_alloc_count();
            for i in 0..audit_calls {
                let mut p = h.submit(black_box(&[3, 5, 2, 7, i as i32])).unwrap();
                p.wait_into(&mut out).unwrap();
            }
            let allocs = thread_alloc_count() - before;
            let per_call = allocs as f64 / audit_calls as f64;
            println!(
                "allocation audit: {allocs} heap allocations on the submit thread across \
                 {audit_calls} submit->wait round trips ({per_call:.4}/call; bound: 0)"
            );
            report.set_meta("submit_allocs_per_call", json::f(per_call));
            assert_eq!(
                allocs, 0,
                "steady-state submit->wait allocated {allocs} times in {audit_calls} calls — \
                 the allocation-free completion slab regressed"
            );
        }

        // Worker-side audit: the dispatch path (take -> gather ->
        // execute_into -> reply) must also be allocation-free in
        // steady state. Each worker publishes its own thread-local
        // allocation delta per batch into the metrics; once warm,
        // that counter must not move. 512-row batches against
        // max_batch 256 also exercise the span-splitting path.
        {
            let mut rngb = Rng::new(41);
            let batch = random_batch(&mut rngb, h.arity(), 512);
            for _ in 0..8 {
                h.call_batch(&batch).unwrap();
            }
            let before = service.metrics().worker_allocs;
            let audit_batches = 64u64;
            for _ in 0..audit_batches {
                h.call_batch(black_box(&batch)).unwrap();
            }
            let allocs = service.metrics().worker_allocs - before;
            let per_batch = allocs as f64 / audit_batches as f64;
            println!(
                "worker allocation audit: {allocs} heap allocations on worker dispatch \
                 paths across {audit_batches} 512-row batches ({per_batch:.4}/batch; bound: 0)"
            );
            report.set_meta("worker_allocs_per_batch", json::f(per_batch));
            assert_eq!(
                allocs, 0,
                "steady-state worker loop allocated {allocs} times across \
                 {audit_batches} batches — the zero-alloc dispatch path regressed"
            );
        }

        // The same burst through one wire connection: the reactor
        // drains completions from the slab, so 10k in-flight calls
        // hold 10k slots — and O(workers + connections) threads, not
        // a waiter thread per call.
        let sock = std::env::temp_dir()
            .join(format!("tmfu-bench-slab-{}.sock", std::process::id()));
        let addr = ListenAddr::Unix(sock.clone());
        let server = WireServer::bind(std::sync::Arc::clone(&service), &addr)?;
        let client = OverlayClient::connect(&format!("unix:{}", sock.display()))?;
        let remote = client.kernel("gradient")?;
        let mut peak_threads = 0usize;
        let m = b.run_with_items(
            &format!("wire::submit {INFLIGHT} in-flight (unix loopback)"),
            INFLIGHT as f64,
            || {
                let mut replies = Vec::with_capacity(INFLIGHT);
                for i in 0..INFLIGHT {
                    replies.push(remote.submit(black_box(&[3, 5, 2, 7, i as i32])).unwrap());
                }
                if let Some(t) = os_thread_count() {
                    peak_threads = peak_threads.max(t);
                }
                for p in replies {
                    p.wait().unwrap();
                }
            },
        );
        println!("{}   (items = requests)", report.record(m.clone()).report_line());
        report.set_meta(
            "wire_inflight_10k_items_per_s",
            json::f(m.throughput().unwrap_or(0.0)),
        );
        if peak_threads > 0 {
            // main + 2 workers + acceptor + per-conn reader/reactor +
            // client reader ≈ 7; anything near the in-flight count
            // means the reactor regressed to thread-per-call.
            println!(
                "peak threads with {INFLIGHT} calls in flight: {peak_threads} \
                 (bound: O(workers + connections) < 32)"
            );
            report.set_meta("peak_threads_10k_inflight", json::i(peak_threads as i64));
            assert!(
                peak_threads < 32,
                "{peak_threads} threads with {INFLIGHT} in-flight wire calls — \
                 per-call threads are back"
            );
        }
        drop(remote);
        drop(client);
        server.shutdown();
        service.shutdown()?;
    }

    section("B7 router forwarding (router hop vs direct wire)");
    {
        let service = std::sync::Arc::new(
            OverlayService::builder()
                .backend(BackendKind::Turbo)
                .pipelines(2)
                .max_batch(32)
                .build()?,
        );
        let sock = std::env::temp_dir()
            .join(format!("tmfu-bench-router-be-{}.sock", std::process::id()));
        let addr = ListenAddr::Unix(sock.clone());
        let server = WireServer::bind(std::sync::Arc::clone(&service), &addr)?;
        let direct = OverlayClient::connect(&format!("unix:{}", sock.display()))?;
        let dk = direct.kernel("gradient")?;
        let inputs = [3, 5, 2, 7, 1];
        let m_direct = b.run_with_items("wire::call(gradient) direct to backend", 1.0, || {
            dk.call(black_box(&inputs)).unwrap()
        });
        println!("{}   (items = requests)", report.record(m_direct.clone()).report_line());

        // The router adds one full store-and-forward hop: a second
        // socket, a second framing pass, and the forwarding ledger
        // (admission, deadline timer, retry bookkeeping).
        let rsock = std::env::temp_dir()
            .join(format!("tmfu-bench-router-{}.sock", std::process::id()));
        let cfg = RouterConfig::new(vec![format!("unix:{}", sock.display())]);
        let router = Router::start(cfg, &ListenAddr::Unix(rsock.clone()))?;
        let client = OverlayClient::connect(&format!("unix:{}", rsock.display()))?;
        let rk = client.kernel("gradient")?;
        let m_routed = b.run_with_items("router::call(gradient) through the router", 1.0, || {
            rk.call(black_box(&inputs)).unwrap()
        });
        println!("{}   (items = requests)", report.record(m_routed.clone()).report_line());
        let router_overhead_us = (m_routed.mean_ns - m_direct.mean_ns) / 1e3;
        report.set_meta("router_call_overhead_us", json::f(router_overhead_us));
        println!(
            "\nrouter overhead: {router_overhead_us:.1} us/call over the direct wire path \
             (one extra socket hop + forwarding ledger)"
        );

        drop(rk);
        drop(client);
        router.shutdown();
        drop(dk);
        drop(direct);
        server.shutdown();
        service.shutdown()?;
    }

    section("B8 tenant fairness (fair-tenant p99 under an abusive flood)");
    {
        // One worker so the DRR scheduler is the only thing standing
        // between the polite tenant and the flood; equal weights, so
        // the isolation measured is round-robin fairness alone.
        let service = OverlayService::builder()
            .backend(BackendKind::Turbo)
            .pipelines(1)
            .max_batch(4)
            .queue_depth(1 << 17)
            .tenant("greedy")
            .tenant("polite")
            .build()?;
        let greedy = service.kernel_for("gradient", "greedy")?;
        let polite = service.kernel_for("gradient", "polite")?;
        let inputs = [3, 5, 2, 7, 1];
        let flood_rows = 256usize;
        let flood = FlatBatch::from_rows(
            inputs.len(),
            &vec![inputs.to_vec(); flood_rows],
        );
        // Dump the abuse up front (64 batches, 16k rows), then run the
        // polite tenant's serial round trips against the backlog.
        let pending: Vec<_> = (0..64)
            .map(|_| greedy.submit_batch(&flood))
            .collect::<Result<_, _>>()?;
        let m = b.run_with_items("service::call(gradient) fair tenant under flood", 1.0, || {
            polite.call(black_box(&inputs)).unwrap()
        });
        println!("{}   (items = requests)", report.record(m).report_line());
        for p in pending {
            p.wait()?;
        }
        let snap = service.metrics();
        let polite_t = snap
            .per_tenant
            .iter()
            .find(|t| t.name == "polite")
            .expect("polite tenant in snapshot");
        let greedy_t = snap
            .per_tenant
            .iter()
            .find(|t| t.name == "greedy")
            .expect("greedy tenant in snapshot");
        let p99 = polite_t.latency_us.as_ref().map_or(0.0, |l| l.p99);
        let abusive_mean = greedy_t.latency_us.as_ref().map_or(0.0, |l| l.mean);
        report.set_meta("fair_tenant_p99_under_abuse_us", json::f(p99));
        report.set_meta(
            "fair_tenant_rejections",
            // cast-ok: a rejection count is bounded far below i64::MAX.
            json::i(polite_t.rejected as i64),
        );
        report.set_meta("abusive_tenant_mean_us", json::f(abusive_mean));
        println!(
            "\nfair-tenant p99 under abuse: {p99:.1} us (abusive tenant mean \
             {abusive_mean:.1} us, fair rejections {})",
            polite_t.rejected
        );
        service.shutdown()?;
    }

    section("B9 deadline shed under overload + cancel slot reclaim");
    {
        use std::time::{Duration, Instant};
        // One worker, tiny dispatch quantum: the queue is the story.
        let service = OverlayService::builder()
            .backend(BackendKind::Turbo)
            .pipelines(1)
            .max_batch(4)
            .queue_depth(1 << 17)
            .build()?;
        let h = service.kernel("gradient")?;
        let inputs = [3, 5, 2, 7, 1];
        // Prime the per-kernel service-rate EWMA so the admission
        // feasibility check has a sample to refuse with.
        h.call(&inputs)?;
        let flood_rows = 256usize;
        let flood = FlatBatch::from_rows(inputs.len(), &vec![inputs.to_vec(); flood_rows]);
        let dump = |n: usize| {
            (0..n).map(|_| h.submit_batch(&flood)).collect::<Result<Vec<_>, _>>()
        };

        // No-shed baseline: an unbudgeted call queued behind a 16k-row
        // overload pays for the whole backlog before its own row runs.
        let pending = dump(64)?;
        let t0 = Instant::now();
        h.call(&inputs)?;
        let no_shed_us = t0.elapsed().as_secs_f64() * 1e6;
        for p in pending {
            p.wait()?;
        }

        // Shed path: the same call under a 100 us budget against a 64k-row
        // backlog is refused typed — at admission (feasibility: queued rows
        // x service-rate EWMA already exceed the budget) or by the bounded
        // wait — without its row ever executing. The refusal latency is
        // what an overloaded caller actually experiences.
        let shed_calls = 256usize;
        let pending = dump(256)?;
        let mut lat_us = Vec::with_capacity(shed_calls);
        for _ in 0..shed_calls {
            let t = Instant::now();
            let r = h.call_with_deadline(&inputs, Duration::from_micros(100));
            lat_us.push(t.elapsed().as_secs_f64() * 1e6);
            assert!(
                matches!(r, Err(tmfu_overlay::service::ServiceError::DeadlineExceeded { .. })),
                "a 100 us budget against a 64k-row single-worker backlog must be \
                 shed typed, got {r:?}"
            );
        }
        lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let shed_p99_us = lat_us[(lat_us.len() * 99) / 100 - 1];

        // Cancel reclaim: withdrawing a queued call releases its slab
        // slot and purges its rows synchronously; measure the per-call
        // cost of that reclaim while the flood still occupies the queue.
        let cancels = 256usize;
        let mut victims = Vec::with_capacity(cancels);
        for _ in 0..cancels {
            victims.push(h.submit(&inputs)?);
        }
        let t0 = Instant::now();
        for mut v in victims {
            v.cancel();
        }
        let cancel_reclaim_us = t0.elapsed().as_secs_f64() * 1e6 / cancels as f64;
        for p in pending {
            p.wait()?;
        }

        let snap = service.metrics();
        assert_eq!(
            snap.admitted(),
            snap.completed + snap.failed + snap.cancelled,
            "B9 ledger out of balance after shed + cancel churn"
        );
        report.set_meta("no_shed_overload_wait_us", json::f(no_shed_us));
        report.set_meta("shed_under_overload_p99_us", json::f(shed_p99_us));
        report.set_meta("cancel_reclaim_us", json::f(cancel_reclaim_us));
        println!(
            "overload shed: typed refusal p99 {shed_p99_us:.1} us vs {no_shed_us:.0} us \
             unbudgeted backlog wait; cancel reclaim {cancel_reclaim_us:.2} us/call \
             (cancelled {}, expired-in-queue {}, shed-at-admission {})",
            snap.cancelled,
            snap.expired_in_queue,
            snap.shed_at_admission
        );
        service.shutdown()?;
    }

    if let Some(path) = json_path_from_args() {
        report.write(&path)?;
        println!("\nwrote {path}");
    }

    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("\nartifacts not built; skipping PJRT benches");
        return Ok(());
    }

    section("L2/L1 PJRT batch execution (per artifact)");
    let engine = Engine::load(&artifacts)?;
    for name in ["gradient", "chebyshev", "poly6", "qspline"] {
        let entry = engine.entry(name)?;
        let batch: Vec<Vec<i32>> = (0..engine.batch)
            .map(|_| (0..entry.n_inputs).map(|_| rng.next_i32()).collect())
            .collect();
        let m = b.run_with_items(
            &format!("pjrt::execute({name}, batch {})", engine.batch),
            engine.batch as f64,
            || engine.execute(name, black_box(&batch)).unwrap(),
        );
        println!("{}   (items = packets)", m.report_line());
    }
    // Single-packet latency: exercises the smallest batch bucket.
    let one = vec![vec![1i32; engine.entry("gradient")?.n_inputs]];
    let m = b.run_with_items("pjrt::execute(gradient, single packet)", 1.0, || {
        engine.execute("gradient", black_box(&one)).unwrap()
    });
    println!("{}   (items = packets)", m.report_line());

    section("L3.d service end-to-end, pjrt backend (2 workers, mixed kernels)");
    let service = OverlayService::builder()
        .backend(BackendKind::Pjrt)
        .artifacts_dir(artifacts.as_path())
        .pipelines(2)
        .max_batch(32)
        .build()?;
    let handles: Vec<KernelHandle> = service.handles();
    let inputs: Vec<Vec<i32>> = handles.iter().map(|h| vec![1i32; h.arity()]).collect();
    let m = b.run_with_items("service::call x32 (pjrt, round-robin)", 32.0, || {
        for i in 0..32usize {
            let j = i % handles.len();
            handles[j].call(black_box(&inputs[j])).unwrap();
        }
    });
    println!("{}   (items = requests, serial round-trip)", m.report_line());
    println!("\n{}", service.metrics().render());
    service.shutdown()?;
    Ok(())
}
