//! Performance microbenchmarks for the hot paths (EXPERIMENTS.md §Perf):
//!
//!   L3.a  cycle-accurate simulator inner loop (cycles/s)
//!   L3.b  scheduler + context generation (compilations/s)
//!   L3.c  coordinator dispatch (requests/s, with and without PJRT)
//!   L2/L1 PJRT batch execution (packets/s per kernel artifact)
//!
//! Run `TMFU_BENCH_FAST=1 cargo bench` for a quick pass.

use tmfu_overlay::arch::Pipeline;
use tmfu_overlay::bench_suite;
use tmfu_overlay::coordinator::{Coordinator, CoordinatorConfig};
use tmfu_overlay::exec::BackendKind;
use tmfu_overlay::runtime::Engine;
use tmfu_overlay::sched::Program;
use tmfu_overlay::util::bench::{black_box, section, Bench};
use tmfu_overlay::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let b = Bench::from_env();

    section("L3.a cycle-accurate simulator");
    for name in ["gradient", "chebyshev", "poly6"] {
        let g = bench_suite::load(name)?;
        let p = Program::schedule(&g)?;
        let n_in = g.inputs().len();
        let packets: Vec<Vec<i32>> = (0..64).map(|k| vec![k as i32; n_in]).collect();
        // cycles per packet ~= II in steady state; count items = cycles.
        let mut probe = Pipeline::new(&p, 4096)?;
        let before = probe.cycle;
        probe.run(&packets, 1_000_000)?;
        let cycles_per_run = (probe.cycle - before) as f64;
        let m = b.run_with_items(&format!("sim::run({name}, 64 packets)"), cycles_per_run, || {
            let mut pl = Pipeline::new(&p, 4096).unwrap();
            pl.run(black_box(&packets), 1_000_000).unwrap()
        });
        println!("{}   (items = simulated cycles)", m.report_line());
    }

    section("L3.b compiler path");
    let (_, src) = bench_suite::KERNEL_SOURCES
        .iter()
        .find(|(n, _)| *n == "poly7")
        .unwrap();
    let m = b.run("frontend+schedule+context(poly7)", || {
        let g = tmfu_overlay::frontend::compile(src).unwrap();
        let p = Program::schedule(&g).unwrap();
        p.context_image().unwrap()
    });
    println!("{}", m.report_line());

    section("L3.c coordinator dispatch, sim backend (zero artifacts)");
    {
        let mut cfg = CoordinatorConfig::new(BackendKind::Sim);
        cfg.workers = 2;
        cfg.max_batch = 32;
        let coord = Coordinator::start_with(cfg)?;
        let names = bench_suite::all_names();
        let m = b.run_with_items("coordinator::call x32 (sim, round-robin)", 32.0, || {
            for i in 0..32usize {
                let kernel = names[i % names.len()];
                let n_in = coord.registry().get(kernel).unwrap().n_inputs;
                coord.call(kernel, vec![1i32; n_in]).unwrap();
            }
        });
        println!("{}   (items = requests, serial round-trip)", m.report_line());
        coord.shutdown()?;
    }

    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("\nartifacts not built; skipping PJRT + coordinator benches");
        return Ok(());
    }

    section("L2/L1 PJRT batch execution (per artifact)");
    let engine = Engine::load(&artifacts)?;
    let mut rng = Rng::new(3);
    for name in ["gradient", "chebyshev", "poly6", "qspline"] {
        let entry = engine.entry(name)?;
        let batch: Vec<Vec<i32>> = (0..engine.batch)
            .map(|_| (0..entry.n_inputs).map(|_| rng.next_i32()).collect())
            .collect();
        let m = b.run_with_items(
            &format!("pjrt::execute({name}, batch {})", engine.batch),
            engine.batch as f64,
            || engine.execute(name, black_box(&batch)).unwrap(),
        );
        println!("{}   (items = packets)", m.report_line());
    }
    // Single-packet latency: exercises the smallest batch bucket.
    let one = vec![vec![1i32; engine.entry("gradient")?.n_inputs]];
    let m = b.run_with_items("pjrt::execute(gradient, single packet)", 1.0, || {
        engine.execute("gradient", black_box(&one)).unwrap()
    });
    println!("{}   (items = packets)", m.report_line());

    section("L3.d coordinator end-to-end, pjrt backend (2 workers, mixed kernels)");
    let coord = Coordinator::start(artifacts.to_str().unwrap(), 2, 32)?;
    let names = bench_suite::all_names();
    let m = b.run_with_items("coordinator::call x32 (round-robin kernels)", 32.0, || {
        for i in 0..32usize {
            let kernel = names[i % names.len()];
            let g = bench_suite::load(kernel).unwrap();
            let inputs = vec![1i32; g.inputs().len()];
            coord.call(kernel, inputs).unwrap();
        }
    });
    println!("{}   (items = requests, serial round-trip)", m.report_line());
    println!("\n{}", coord.metrics_report());
    coord.shutdown()?;
    Ok(())
}
