//! Regenerates the paper's Table I: the first 32 cycles of the
//! 'gradient' schedule (II = 11), plus the static-vs-dynamic
//! cross-check and the schedule-generation microbenchmark.

use tmfu_overlay::bench_suite;
use tmfu_overlay::sched::{Program, ScheduleTable, Timing};
use tmfu_overlay::sim;
use tmfu_overlay::util::bench::{section, Bench};

fn main() -> anyhow::Result<()> {
    section("Table I: first 32 cycles of the 'gradient' schedule");
    let g = bench_suite::load("gradient")?;
    let p = Program::schedule(&g)?;
    let t = ScheduleTable::generate(&p, 32);
    print!("{}", t.render());
    let timing = Timing::of(&p);
    println!(
        "II = {} (paper: 11); arrivals at cycles {:?} (paper: 1/8/14/20); backpressure {:?} (paper: 6-11)",
        timing.ii,
        timing.t_arrive,
        t.backpressure_window(&p)
    );

    section("dynamic cross-check (cycle-accurate simulator)");
    for name in bench_suite::all_names() {
        sim::validate_against_schedule(&Program::schedule(&bench_suite::load(name)?)?, 6)?;
        println!("{name:<10} dynamic II/latency match the static schedule");
    }

    section("microbenchmarks");
    let b = Bench::from_env();
    let m = b.run("schedule(gradient)", || Program::schedule(&g).unwrap());
    println!("{}", m.report_line());
    let m = b.run("table1_generate(32 cycles)", || {
        ScheduleTable::generate(&p, 32)
    });
    println!("{}", m.report_line());
    Ok(())
}
