//! Regenerates the paper's Fig. 6: area comparison across the three
//! implementations.

use tmfu_overlay::report::fig6;
use tmfu_overlay::util::bench::section;

fn main() -> anyhow::Result<()> {
    section("Fig. 6: area comparison");
    print!("{}", fig6::render()?);
    Ok(())
}
