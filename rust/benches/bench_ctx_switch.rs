//! Regenerates the paper's §V context-switch comparison: 40-bit context
//! streams vs SCFU-SCN external configuration vs partial
//! reconfiguration, plus the config-port load microbenchmark.

use tmfu_overlay::arch::config_port;
use tmfu_overlay::bench_suite;
use tmfu_overlay::report::ctx_switch;
use tmfu_overlay::sched::Program;
use tmfu_overlay::util::bench::{section, Bench};

fn main() -> anyhow::Result<()> {
    section("context switching");
    print!("{}", ctx_switch::render()?);

    section("config-port microbenchmark (simulated daisy-chain load)");
    let g = bench_suite::load("poly6")?;
    let img = Program::schedule(&g)?.context_image()?;
    let words = img.words().map_err(|e| anyhow::anyhow!("{e}"))?;
    let b = Bench::from_env();
    let m = b.run_with_items("load_context(poly6)", words.len() as f64, || {
        config_port::load_context(&words, img.n_fus()).unwrap()
    });
    println!("{}", m.report_line());
    Ok(())
}
