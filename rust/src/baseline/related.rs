//! Related-work FU cost models (paper §II): CARBON, SCGRA, reMORPH and
//! TILT, used by `bench_related_work` to regenerate the paper's
//! qualitative comparison (instruction storage blow-up, context switch
//! path, FU frequency).

use crate::resources::Device;

/// How a design switches kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwitchMechanism {
    /// Local context memory clocked in at fabric speed (this paper).
    LocalContext,
    /// Instruction memories rewritten from external memory.
    ExternalMemory,
    /// Full- or partial-bitstream reconfiguration.
    Reconfiguration,
}

/// One related-work overlay FU datapoint (from §II, normalized to
/// per-FU numbers as reported by the respective papers).
#[derive(Debug, Clone, Copy)]
pub struct RelatedFu {
    pub name: &'static str,
    pub platform: &'static str,
    /// LUTs (Xilinx) or ALMs (Altera) — the bench labels the unit.
    pub luts_or_alms: u32,
    pub ffs: u32,
    pub dsps: u32,
    pub bram_kbits: f64,
    pub fmax_mhz: f64,
    /// Instruction storage depth per FU.
    pub im_depth: u32,
    /// Instruction width in bits.
    pub instr_bits: u32,
    pub switch: SwitchMechanism,
}

/// §II datapoints, plus this paper's FU for comparison.
pub const RELATED: [RelatedFu; 5] = [
    RelatedFu {
        name: "CARBON [5]",
        platform: "Stratix III",
        luts_or_alms: 3000,
        ffs: 304,
        dsps: 4,
        bram_kbits: 15.6,
        fmax_mhz: 90.0,
        im_depth: 256,
        instr_bits: 64,
        switch: SwitchMechanism::ExternalMemory,
    },
    RelatedFu {
        name: "SCGRA [18,19]",
        platform: "Zynq",
        luts_or_alms: 0, // dominated by BRAM; LUT count not reported
        ffs: 0,
        dsps: 1,
        bram_kbits: 72.0 * 1.0 + 256.0 * 32.0 / 1024.0, // instr ROM + data mem
        fmax_mhz: 250.0,
        im_depth: 1024,
        instr_bits: 72,
        switch: SwitchMechanism::Reconfiguration,
    },
    RelatedFu {
        name: "reMORPH [20]",
        platform: "7-series",
        luts_or_alms: 196,
        ffs: 41,
        dsps: 1,
        bram_kbits: 3.0 * 36.0,
        fmax_mhz: 200.0,
        im_depth: 512,
        instr_bits: 72,
        switch: SwitchMechanism::Reconfiguration,
    },
    RelatedFu {
        name: "TILT [21]",
        platform: "Stratix IV",
        luts_or_alms: 1500, // 12K eALMs / 8 cores
        ffs: 0,
        dsps: 2,
        bram_kbits: 40.0,
        fmax_mhz: 200.0,
        im_depth: 256,
        instr_bits: 64,
        switch: SwitchMechanism::ExternalMemory,
    },
    RelatedFu {
        name: "this paper",
        platform: "Zynq Z7020",
        luts_or_alms: 160,
        ffs: 293,
        dsps: 1,
        bram_kbits: 0.0, // IM is 4 RAM32M LUTRAM primitives
        fmax_mhz: 325.0,
        im_depth: 32,
        instr_bits: 32,
        switch: SwitchMechanism::LocalContext,
    },
];

impl RelatedFu {
    /// Instruction storage per FU in bits.
    pub fn instr_storage_bits(&self) -> u64 {
        self.im_depth as u64 * self.instr_bits as u64
    }

    /// Rough e-Slices (LUT-based synthesis on the Zynq exchange rate;
    /// Altera datapoints are approximate by design — labelled in the
    /// bench output).
    pub fn eslices(&self, dev: &Device) -> u32 {
        let slices = (self.luts_or_alms as f64 / 4.0 / 0.494).round() as u32;
        slices + self.dsps * dev.slices_per_dsp()
    }
}

/// The headline §II comparison: this paper's FU stores 32×32 b = 1 Kb
/// of instructions vs 16–72 Kb for the others.
pub fn instruction_storage_ratio(other: &RelatedFu) -> f64 {
    let ours = RELATED[4].instr_storage_bits() as f64;
    other.instr_storage_bits() as f64 / ours
}

/// TILT system-level datapoint (§II): 8-core TILT = 12K eALMs and
/// 30 M inputs/s vs Altera OpenCL HLS at 51K eALMs and 240 M inputs/s.
pub const TILT_8CORE_EALMS: u32 = 12_000;
pub const TILT_8CORE_MINPUTS: f64 = 30.0;
pub const TILT_HLS_EALMS: u32 = 51_000;
pub const TILT_HLS_MINPUTS: f64 = 240.0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::ZYNQ_Z7020;

    #[test]
    fn our_fu_has_smallest_instruction_storage() {
        let ours = RELATED[4].instr_storage_bits();
        assert_eq!(ours, 1024);
        for r in &RELATED[..4] {
            assert!(
                r.instr_storage_bits() >= 16 * ours,
                "{} storage too small",
                r.name
            );
        }
    }

    #[test]
    fn our_fu_is_fastest() {
        let ours = RELATED[4].fmax_mhz;
        for r in &RELATED[..4] {
            assert!(ours > r.fmax_mhz, "{}", r.name);
        }
    }

    #[test]
    fn carbon_is_the_largest_fu() {
        let carbon = RELATED[0].eslices(&ZYNQ_Z7020);
        let ours = RELATED[4].eslices(&ZYNQ_Z7020);
        assert!(carbon > 5 * ours);
    }

    #[test]
    fn only_this_paper_switches_via_local_context() {
        let locals = RELATED
            .iter()
            .filter(|r| r.switch == SwitchMechanism::LocalContext)
            .count();
        assert_eq!(locals, 1);
    }

    #[test]
    fn tilt_hls_gap_matches_paper() {
        // 8x throughput at 4.25x area (paper: "8x higher throughput ...
        // 4x higher area").
        assert!((TILT_HLS_MINPUTS / TILT_8CORE_MINPUTS - 8.0).abs() < 1e-9);
        let area_ratio = TILT_HLS_EALMS as f64 / TILT_8CORE_EALMS as f64;
        assert!((3.5..=4.5).contains(&area_ratio));
    }
}
