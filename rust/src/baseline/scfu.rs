//! SCFU-SCN baseline: the spatially-configured DSP-block overlay of
//! Jain et al. [13] (FCCM'15), the paper's main comparison point.
//!
//! In an SCFU-SCN overlay every DFG op occupies its own FU and runs at
//! II = 1; values whose consumers sit more than the interconnect reach
//! below their producer additionally occupy *pass-through* FUs for
//! pipeline balancing. Constants from the paper's Table III:
//! 190 e-Slices per FU and a 335 MHz fabric (back-derived identities —
//! both are asserted by tests against every Table III row).
//!
//! The paper gives no mapping algorithm for [13]; our structural model
//! (1 FU/op + shared pass chains, reach 2) reproduces the chebyshev FU
//! count exactly and tracks the remaining rows from below (the paper's
//! island-style grid adds placement slack our model does not charge);
//! benches print both columns. See EXPERIMENTS.md §Fig5.

use crate::dfg::{Dfg, Levels};
use crate::sched::Routing;

/// e-Slices per SCFU-SCN functional unit (from [13] / Table III).
pub const FU_ESLICES: u32 = 190;
/// SCFU-SCN overlay operating frequency implied by Table III (MHz).
pub const FREQ_MHZ: f64 = 335.0;
/// Interconnect reach: a value registered at level L can feed
/// consumers up to L + REACH without an intermediate pass FU
/// ([13]'s island interconnect registers every second hop).
pub const REACH: u32 = 2;

/// Mapping result for one kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScfuMapping {
    pub op_fus: u32,
    pub pass_fus: u32,
}

impl ScfuMapping {
    pub fn total_fus(&self) -> u32 {
        self.op_fus + self.pass_fus
    }

    pub fn area_eslices(&self) -> u32 {
        self.total_fus() * FU_ESLICES
    }
}

/// Map a DFG onto the spatial overlay: one FU per op plus shared
/// pass-through chains for reach-limited routing.
pub fn map(g: &Dfg) -> ScfuMapping {
    let levels = Levels::of(g);
    let routing = Routing::of(g, &levels);
    let op_fus = g.n_ops() as u32;
    let mut pass_fus = 0u32;
    for route in routing.routes.values() {
        // Greedy shared chain: place a pass FU every REACH levels until
        // the farthest consumer is within reach. The virtual output
        // stage (depth+1) does not need balancing FUs: outputs exit
        // through the egress ports.
        let last_consumer = route
            .consumer_stages
            .iter()
            .copied()
            .filter(|&c| c <= levels.depth)
            .max()
            .unwrap_or(route.producer);
        let mut current = route.producer;
        while last_consumer > current + REACH {
            current += REACH;
            pass_fus += 1;
        }
    }
    ScfuMapping { op_fus, pass_fus }
}

/// Throughput in GOPS: II = 1 ⇒ every op fires each cycle.
pub fn gops(n_ops: usize) -> f64 {
    n_ops as f64 * FREQ_MHZ * 1e6 / 1e9
}

/// Context switch: [13] has no local context memory; configuration
/// streams from external memory. The paper quotes 13 µs for the worst
/// case 323 B of configuration data — an effective ~25 MB/s path.
pub fn context_switch_us(config_bytes: usize) -> f64 {
    const EFFECTIVE_MBPS: f64 = 25.0;
    config_bytes as f64 / EFFECTIVE_MBPS
}

/// Worst-case configuration size from the paper (§V).
pub const WORST_CASE_CONFIG_BYTES: usize = 323;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::{self, PAPER_ROWS};

    #[test]
    fn chebyshev_fu_count_matches_fig5_exactly() {
        let g = bench_suite::load("chebyshev").unwrap();
        let m = map(&g);
        // 7 op FUs + 3 pass FUs on the shared x chain = 10 (Fig. 5).
        assert_eq!(m.op_fus, 7);
        assert_eq!(m.pass_fus, 3);
        assert_eq!(m.total_fus(), 10);
        assert_eq!(m.area_eslices(), 1900); // Table III row 1
    }

    #[test]
    fn model_never_exceeds_paper_fu_counts() {
        // Our balancing model charges no placement slack, so it must
        // lower-bound the paper's island-grid counts on every row.
        for row in &PAPER_ROWS {
            let g = bench_suite::load(row.name).unwrap();
            let m = map(&g);
            assert!(
                m.total_fus() <= row.fus_scfu,
                "{}: model {} > paper {}",
                row.name,
                m.total_fus(),
                row.fus_scfu
            );
            assert!(
                m.total_fus() >= row.ops as u32,
                "{}: fewer FUs than ops",
                row.name
            );
        }
    }

    #[test]
    fn throughput_matches_table3_scfu_column() {
        for row in &PAPER_ROWS {
            let t = gops(row.ops);
            assert!(
                (t - row.tput_scfu).abs() < 0.01,
                "{}: {t:.3} vs paper {}",
                row.name,
                row.tput_scfu
            );
        }
    }

    #[test]
    fn paper_area_identity() {
        for row in &PAPER_ROWS {
            assert_eq!(row.fus_scfu * FU_ESLICES, row.area_scfu, "{}", row.name);
        }
    }

    #[test]
    fn context_switch_matches_paper_13us() {
        let t = context_switch_us(WORST_CASE_CONFIG_BYTES);
        assert!((t - 13.0).abs() < 0.2, "t = {t}");
    }
}
