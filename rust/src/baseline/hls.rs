//! Vivado-HLS-like baseline: a fully pipelined (II = 1) custom datapath
//! per kernel, as the paper generated with Vivado HLS 2014.2.
//!
//! The estimator binds each DFG op to a dedicated operator:
//!
//! * variable × variable multiply → 1 DSP48E1 + pipeline registers
//!   (HLS range analysis keeps the benchmark data inside the 25×18
//!   multiplier; this is what makes chebyshev land at 265 e-Slices);
//! * constant multiply → shift-add network (one CSD adder per extra
//!   set bit — Vivado strength-reduces these, no DSP);
//! * add/sub → 32-bit carry chain (8 slices);
//! * logic ops → LUT pairs (4 slices);
//!
//! plus per-kernel pipeline/control overhead. The per-benchmark fmax
//! is a calibrated table (implied by the paper's Table III throughput =
//! `ops × fmax`), since HLS timing closure is not derivable from
//! structure alone. Our estimator's area is printed next to the
//! paper's in `bench_table3`.

use crate::dfg::{Dfg, NodeKind, OpKind};
use crate::resources::Device;
use crate::util::bits::popcount_u64;

/// Slices for a 32-bit carry-chain adder/subtractor + output register.
const ADDSUB_SLICES: u32 = 8;
/// Slices for a 32-bit logic op.
const LOGIC_SLICES: u32 = 4;
/// Slices of pipeline registers around each DSP multiplier.
const MUL_REG_SLICES: u32 = 4;
/// Fixed control/FSM + AXIS interface overhead per kernel.
const CONTROL_SLICES: u32 = 12;

/// Estimated HLS implementation of one kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HlsImpl {
    pub dsps: u32,
    pub slices: u32,
    pub fmax_mhz: f64,
}

impl HlsImpl {
    pub fn eslices(&self, dev: &Device) -> u32 {
        self.slices + self.dsps * dev.slices_per_dsp()
    }

    /// GOPS at II = 1.
    pub fn gops(&self, n_ops: usize) -> f64 {
        n_ops as f64 * self.fmax_mhz * 1e6 / 1e9
    }
}

/// Per-benchmark fmax implied by Table III (`tput / ops`), MHz.
/// Unlisted kernels get a conservative default.
pub fn fmax_mhz(kernel: &str) -> f64 {
    match kernel {
        "chebyshev" => 315.0,
        "sgfilter" => 255.0,
        "mibench" => 270.0,
        "qspline" => 235.0,
        "poly5" => 260.0,
        "poly6" => 270.0,
        "poly7" => 280.0,
        "poly8" => 260.0,
        _ => 270.0,
    }
}

/// Estimate the HLS datapath for a kernel.
pub fn estimate(g: &Dfg) -> HlsImpl {
    let mut dsps = 0u32;
    let mut slices = CONTROL_SLICES + g.inputs().len() as u32 * 2; // I/O regs
    for id in g.ids() {
        let n = g.node(id);
        if let NodeKind::Op { op } = n.kind {
            let const_arg = n.args.iter().find_map(|&a| match g.node(a).kind {
                NodeKind::Const { value } => Some(value),
                _ => None,
            });
            match (op, const_arg) {
                (OpKind::Mul, Some(c)) => {
                    // Shift-add network: one adder per extra set bit.
                    let bits = popcount_u64(c.unsigned_abs() as u64).max(1);
                    slices += (bits - 1) * ADDSUB_SLICES + 4;
                }
                (OpKind::Mul, None) => {
                    dsps += 1;
                    slices += MUL_REG_SLICES;
                }
                (OpKind::Add | OpKind::Sub, _) => slices += ADDSUB_SLICES,
                (OpKind::And | OpKind::Or | OpKind::Xor, _) => slices += LOGIC_SLICES,
            }
        }
    }
    HlsImpl {
        dsps,
        slices,
        fmax_mhz: fmax_mhz(&g.name),
    }
}

/// Partial-reconfiguration context switch (§V): a 75 kB PR bitstream
/// through the Zynq PCAP takes ~200 µs.
pub const PR_BITSTREAM_BYTES: usize = 75 * 1024;

pub fn context_switch_us(bitstream_bytes: usize) -> f64 {
    // PCAP effective throughput ~ 384 MB/s ⇒ 75 kB in ~200 µs.
    const PCAP_BYTES_PER_US: f64 = 384.0;
    bitstream_bytes as f64 / PCAP_BYTES_PER_US
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::{self, PAPER_ROWS};
    use crate::resources::ZYNQ_Z7020;

    #[test]
    fn chebyshev_lands_near_paper_area() {
        let g = bench_suite::load("chebyshev").unwrap();
        let h = estimate(&g);
        // 4 variable multiplies -> 4 DSPs; paper area 265 e-Slices.
        assert_eq!(h.dsps, 4);
        let es = h.eslices(&ZYNQ_Z7020);
        assert!(
            (200..=340).contains(&es),
            "chebyshev HLS estimate {es} vs paper 265"
        );
    }

    #[test]
    fn throughput_matches_table3_hls_column() {
        for row in &PAPER_ROWS {
            let g = bench_suite::load(row.name).unwrap();
            let h = estimate(&g);
            let t = h.gops(row.ops);
            let delta = (t - row.tput_hls).abs() / row.tput_hls;
            assert!(
                delta < 0.05,
                "{}: {t:.2} vs paper {} GOPS",
                row.name,
                row.tput_hls
            );
        }
    }

    #[test]
    fn estimates_same_order_of_magnitude_as_paper() {
        for row in &PAPER_ROWS {
            let g = bench_suite::load(row.name).unwrap();
            let es = estimate(&g).eslices(&ZYNQ_Z7020);
            let ratio = es as f64 / row.area_hls as f64;
            assert!(
                (0.3..=2.0).contains(&ratio),
                "{}: estimate {es} vs paper {} (ratio {ratio:.2})",
                row.name,
                row.area_hls
            );
        }
    }

    #[test]
    fn pr_switch_time_near_200us() {
        let t = context_switch_us(PR_BITSTREAM_BYTES);
        assert!((t - 200.0).abs() < 10.0, "t = {t}");
    }

    #[test]
    fn hls_wins_area_vs_overlay_loses_flexibility() {
        // Table III's qualitative claim: HLS area < proposed overlay
        // area for most kernels (the overlay pays for programmability).
        let mut hls_smaller = 0;
        for row in &PAPER_ROWS {
            let g = bench_suite::load(row.name).unwrap();
            let es = estimate(&g).eslices(&ZYNQ_Z7020);
            if es < row.area_proposed {
                hls_smaller += 1;
            }
        }
        assert!(hls_smaller >= 6, "only {hls_smaller}/8 smaller");
    }
}
