//! Baseline implementations the paper compares against: the SCFU-SCN
//! spatial overlay [13], Vivado-HLS-style custom datapaths, and the
//! related-work FU cost models of §II.

pub mod hls;
pub mod related;
pub mod scfu;

pub use hls::HlsImpl;
pub use scfu::ScfuMapping;
