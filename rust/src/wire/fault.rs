//! Deterministic fault injection for the wire layer (test-only).
//!
//! The chaos paths this PR guards — a backend dying mid-burst, a
//! stalled peer, a corrupted length prefix — are awkward to provoke
//! with real `kill -9` timing races in unit tests. This module makes
//! them deterministic: the server consults a [`FaultPlan`] parsed from
//! `TMFU_FAULT_*` environment variables and injects the failure at an
//! exact frame count, so a test (or `tools/router_smoke.sh`) can
//! reproduce "connection dropped after the 3rd request" bit-for-bit.
//!
//! Knobs (all optional; unset means no fault):
//!
//! * `TMFU_FAULT_DROP_AFTER=<n>` — hard-close the connection after
//!   reading `n` frames (post-handshake), simulating a process kill or
//!   network cut mid-conversation.
//! * `TMFU_FAULT_DELAY_REPLY_MS=<ms>` — sleep before every reply
//!   write, simulating a slow backend (lets clients exercise read
//!   timeouts and the router its per-call deadline).
//! * `TMFU_FAULT_CORRUPT_LEN=<n>` — replace the length prefix of the
//!   `n`-th reply frame with an over-`MAX_PAYLOAD` value and close,
//!   simulating stream corruption (the peer must surface a typed
//!   transport error, never wedge).
//!
//! The plan is read once per connection; counters are per-connection,
//! so every accepted socket observes the same deterministic script.
//! Production deployments simply leave the variables unset — the
//! inactive plan is a handful of `None` checks per frame.

use std::time::Duration;

/// Parsed `TMFU_FAULT_*` script. Inactive (all `None`) in production.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Hard-close after this many frames read on the connection.
    pub drop_after_frames: Option<u64>,
    /// Sleep this long before each reply write.
    pub delay_reply: Option<Duration>,
    /// Corrupt the length prefix of the n-th reply written (1-based).
    pub corrupt_len_at: Option<u64>,
}

fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok().and_then(|v| v.parse().ok())
}

impl FaultPlan {
    /// Read the fault script from the environment. Unparseable values
    /// are treated as unset (faults are a test convenience, not an
    /// interface worth failing startup over).
    pub fn from_env() -> FaultPlan {
        FaultPlan {
            drop_after_frames: env_u64("TMFU_FAULT_DROP_AFTER"),
            delay_reply: env_u64("TMFU_FAULT_DELAY_REPLY_MS").map(Duration::from_millis),
            corrupt_len_at: env_u64("TMFU_FAULT_CORRUPT_LEN"),
        }
    }

    /// Whether any fault is scripted.
    pub fn is_active(&self) -> bool {
        self.drop_after_frames.is_some()
            || self.delay_reply.is_some()
            || self.corrupt_len_at.is_some()
    }
}

/// Per-connection fault progress: the plan plus read/write counters.
#[derive(Debug, Default)]
pub struct FaultState {
    plan: FaultPlan,
    frames_read: u64,
    replies_written: u64,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> FaultState {
        FaultState {
            plan,
            frames_read: 0,
            replies_written: 0,
        }
    }

    /// Record one frame read. Returns `true` when the scripted drop
    /// point is reached — the caller must hard-close the connection.
    pub fn frame_read(&mut self) -> bool {
        self.frames_read += 1;
        matches!(self.plan.drop_after_frames, Some(n) if self.frames_read > n)
    }

    /// Sleep out the scripted reply delay (no-op when unset).
    pub fn before_reply(&self) {
        if let Some(d) = self.plan.delay_reply {
            std::thread::sleep(d);
        }
    }

    /// Record one reply write. Returns `true` when this exact write
    /// must carry a corrupted length prefix (after which the caller
    /// closes the connection).
    pub fn corrupt_this_reply(&mut self) -> bool {
        self.replies_written += 1;
        self.plan.corrupt_len_at == Some(self.replies_written)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_plan_injects_nothing() {
        let plan = FaultPlan::default();
        assert!(!plan.is_active());
        let mut st = FaultState::new(plan);
        for _ in 0..100 {
            assert!(!st.frame_read());
            assert!(!st.corrupt_this_reply());
        }
        st.before_reply(); // no sleep
    }

    #[test]
    fn drop_fires_exactly_after_n_frames() {
        let mut st = FaultState::new(FaultPlan {
            drop_after_frames: Some(3),
            ..FaultPlan::default()
        });
        assert!(!st.frame_read());
        assert!(!st.frame_read());
        assert!(!st.frame_read());
        assert!(st.frame_read()); // the 4th read crosses the script
        assert!(st.frame_read()); // and stays tripped
    }

    #[test]
    fn corrupt_fires_on_the_exact_write() {
        let mut st = FaultState::new(FaultPlan {
            corrupt_len_at: Some(2),
            ..FaultPlan::default()
        });
        assert!(!st.corrupt_this_reply());
        assert!(st.corrupt_this_reply());
        assert!(!st.corrupt_this_reply());
    }
}
