//! Tenant keyring: shared-secret authentication for the wire
//! handshake.
//!
//! `tmfu listen --tenants <file>` loads one secret per tenant; from
//! then on every Hello must carry a [`TenantToken`] signed with one of
//! those secrets (see `docs/PROTOCOL.md`, "Tenant authentication").
//! Verification happens once per connection, before the `HelloOk`, and
//! a failure is a typed `Unauthorized` error followed by hangup — the
//! server never panics and the next connection is unaffected.
//!
//! The keyring also carries each tenant's scheduling parameters
//! (weight, quota) so the listener can build the service's tenant
//! lanes from the same file: entry order here is lane order there.

use super::TenantToken;
use crate::util::sync::LockExt;
use std::collections::{HashMap, HashSet};
use std::sync::Mutex;

/// One configured tenant: identity, shared secret, and the scheduling
/// parameters its queue lane is built with.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantEntry {
    pub name: String,
    pub secret: Vec<u8>,
    /// Deficit-round-robin weight (relative drain share), >= 1.
    pub weight: u32,
    /// Admission quota: max queued rows across all kernels, >= 1.
    pub quota: usize,
}

/// The server-side keyring: configured tenants plus a replay cache of
/// `(tenant, nonce)` pairs already accepted. A nonce is burned on
/// first successful verification, so replaying a sniffed token on a
/// new connection fails even though the signature is valid. The cache
/// grows by one entry per authenticated connection; at overlay scale
/// (thousands of connections) that is bounded and cheap.
#[derive(Debug)]
pub struct TenantKeyring {
    entries: Vec<TenantEntry>,
    index: HashMap<String, usize>,
    seen: Mutex<HashSet<(String, u64)>>,
}

impl TenantKeyring {
    /// Build from explicit entries. Fails on an empty list or a
    /// duplicated tenant name.
    pub fn new(entries: Vec<TenantEntry>) -> Result<TenantKeyring, String> {
        if entries.is_empty() {
            return Err("tenant keyring is empty".to_string());
        }
        let mut index = HashMap::new();
        for (i, e) in entries.iter().enumerate() {
            if e.name.is_empty() {
                return Err("tenant name is empty".to_string());
            }
            if index.insert(e.name.clone(), i).is_some() {
                return Err(format!("duplicate tenant '{}'", e.name));
            }
        }
        Ok(TenantKeyring {
            entries,
            index,
            seen: Mutex::new(HashSet::new()),
        })
    }

    /// Parse a tenants file: one `name:secret[:weight[:quota]]` per
    /// line, `#` comments and blank lines ignored. Weight and quota
    /// default to 1 and unlimited.
    pub fn parse(text: &str) -> Result<TenantKeyring, String> {
        let mut entries = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split(':');
            let name = parts.next().unwrap_or("").trim();
            let secret = parts.next().map(str::trim);
            let err = |what: &str| format!("tenants file line {}: {what}", lineno + 1);
            let secret = match secret {
                Some(s) if !s.is_empty() => s,
                _ => return Err(err("expected name:secret[:weight[:quota]]")),
            };
            if name.is_empty() {
                return Err(err("tenant name is empty"));
            }
            let weight = match parts.next() {
                None => 1,
                Some(w) => match w.trim().parse::<u32>() {
                    Ok(w) if w >= 1 => w,
                    _ => return Err(err("weight must be an integer >= 1")),
                },
            };
            let quota = match parts.next() {
                None => usize::MAX,
                Some(q) => match q.trim().parse::<usize>() {
                    Ok(q) if q >= 1 => q,
                    _ => return Err(err("quota must be an integer >= 1")),
                },
            };
            if parts.next().is_some() {
                return Err(err("too many fields"));
            }
            entries.push(TenantEntry {
                name: name.to_string(),
                secret: secret.as_bytes().to_vec(),
                weight,
                quota,
            });
        }
        TenantKeyring::new(entries)
    }

    /// The configured tenants, in file/lane order.
    pub fn entries(&self) -> &[TenantEntry] {
        &self.entries
    }

    /// Verify one token: the tenant must be configured, the MAC must
    /// validate under its secret, and the `(tenant, nonce)` pair must
    /// be fresh. On success the nonce is burned and the matching entry
    /// returned; on failure the message is what the `Unauthorized`
    /// wire error carries.
    pub fn verify(&self, token: &TenantToken) -> Result<&TenantEntry, String> {
        let Some(&i) = self.index.get(&token.tenant) else {
            return Err(format!("unknown tenant '{}'", token.tenant));
        };
        let entry = &self.entries[i];
        if !token.verify(&entry.secret) {
            return Err("bad tenant signature".to_string());
        }
        let mut seen = self.seen.lock_unpoisoned();
        if !seen.insert((token.tenant.clone(), token.nonce)) {
            return Err("replayed tenant nonce".to_string());
        }
        Ok(entry)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring() -> TenantKeyring {
        TenantKeyring::parse("acme:opensesame:2:64\npolite:hunter2\n").unwrap()
    }

    #[test]
    fn parse_reads_fields_and_defaults() {
        let ring = TenantKeyring::parse(
            "# comment\n\nacme:opensesame:2:64\n  polite : hunter2 \n",
        )
        .unwrap();
        let e = ring.entries();
        assert_eq!(e.len(), 2);
        assert_eq!(e[0].name, "acme");
        assert_eq!(e[0].secret, b"opensesame");
        assert_eq!(e[0].weight, 2);
        assert_eq!(e[0].quota, 64);
        assert_eq!(e[1].name, "polite");
        assert_eq!(e[1].weight, 1);
        assert_eq!(e[1].quota, usize::MAX);
    }

    #[test]
    fn parse_rejects_bad_lines() {
        for (text, needle) in [
            ("acme", "name:secret"),
            ("acme:", "name:secret"),
            (":opensesame", "name is empty"),
            ("acme:s:zero", "weight"),
            ("acme:s:0", "weight"),
            ("acme:s:1:0", "quota"),
            ("acme:s:1:2:3", "too many"),
            ("", "empty"),
            ("acme:a\nacme:b", "duplicate"),
        ] {
            let err = TenantKeyring::parse(text).unwrap_err();
            assert!(err.contains(needle), "{text:?}: {err}");
        }
    }

    #[test]
    fn verify_accepts_a_fresh_signed_token() {
        let ring = ring();
        let t = TenantToken::sign("acme", b"opensesame", 1);
        let e = ring.verify(&t).unwrap();
        assert_eq!(e.name, "acme");
        assert_eq!(e.weight, 2);
    }

    #[test]
    fn verify_names_each_failure() {
        let ring = ring();
        let err = ring
            .verify(&TenantToken::sign("nonesuch", b"x", 1))
            .unwrap_err();
        assert!(err.contains("unknown tenant"), "{err}");
        let err = ring
            .verify(&TenantToken::sign("acme", b"wrong-secret", 1))
            .unwrap_err();
        assert_eq!(err, "bad tenant signature");
    }

    #[test]
    fn verify_burns_nonces_per_tenant() {
        let ring = ring();
        let t = TenantToken::sign("acme", b"opensesame", 7);
        ring.verify(&t).unwrap();
        // Replaying the same token (even on a "new connection" — the
        // cache is server-wide) is refused.
        assert_eq!(ring.verify(&t).unwrap_err(), "replayed tenant nonce");
        // A fresh nonce from the same tenant is fine.
        ring.verify(&TenantToken::sign("acme", b"opensesame", 8))
            .unwrap();
        // Another tenant may use the same nonce value.
        ring.verify(&TenantToken::sign("polite", b"hunter2", 7))
            .unwrap();
        // A failed MAC does not burn the nonce.
        let bad = TenantToken::sign("acme", b"wrong", 9);
        ring.verify(&bad).unwrap_err();
        ring.verify(&TenantToken::sign("acme", b"opensesame", 9))
            .unwrap();
    }
}
