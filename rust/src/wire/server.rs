//! Wire-protocol server: `tmfu listen` and the test harnesses drive an
//! [`OverlayService`] from decoded frames.
//!
//! Thread shape (std threads; **two per connection, regardless of
//! in-flight calls** — the completion-slab reactor of DESIGN.md §10):
//!
//! * one **acceptor** thread per bound address; every accepted socket
//!   gets its own connection thread;
//! * each **connection** (reader) thread performs the Hello handshake,
//!   builds one pre-resolved [`KernelHandle`] per registry kernel (so
//!   `Call` frames index a vector — no name lookups on the request
//!   path), then decodes frames in a loop. `Call` / `CallBatch`
//!   submit through the service's non-blocking ports with a
//!   completion **doorbell** attached, so admission (and its typed
//!   errors) happens on the reader while nobody ever blocks per call;
//! * one **reactor** thread per connection owns the socket's write
//!   half. It parks on the connection doorbell and wakes when the
//!   reader queues an immediate frame (handshake, resolve, metrics,
//!   submit errors) or when a worker completes an in-flight call —
//!   the slab rings the doorbell with the request id, the reactor
//!   takes the finished result straight out of the slot and writes
//!   the Reply frame. 10k in-flight calls on one socket cost 10k slab
//!   slots and zero extra threads. (The previous design spawned a
//!   short-lived waiter thread per in-flight call — and only reaped
//!   finished waiters when the *next* frame arrived, so an
//!   idle-after-burst connection pinned every completed waiter's
//!   stack indefinitely. Both failure modes are structurally gone.)
//!
//! Replies are correlated by request id and may arrive out of
//! submission order, exactly as before.
//!
//! Failure containment: a malformed frame gets a typed
//! [`WireError::Malformed`] reply and the connection is closed; a
//! client that disconnects mid-call only makes the reactor's reply
//! write fail — the in-flight slots recycle via their drop-abandon
//! path and the service, the other connections and the acceptor never
//! notice.

use super::{read_frame, write_frame, Frame, ListenAddr, WireError, WireStream};
use crate::coordinator::completion::Wake;
use crate::service::{KernelHandle, OverlayService, Pending, PendingBatch, ServiceError};
use crate::wire::{WIRE_VERSION_MAX, WIRE_VERSION_MIN};
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{self, BufReader, BufWriter, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// A bound, accepting wire server. Dropping the value does **not**
/// stop it — call [`WireServer::shutdown`] (tests, embedders) or
/// [`WireServer::wait`] (`tmfu listen`).
pub struct WireServer {
    addr: ListenAddr,
    unix_path: Option<std::path::PathBuf>,
    stop: Arc<AtomicBool>,
    acceptor: Option<thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    /// Control clones of live connection sockets, keyed by connection
    /// id; entries are removed by the connection thread on exit so a
    /// long-lived server does not leak file descriptors.
    streams: Arc<Mutex<HashMap<u64, WireStream>>>,
}

enum Listener {
    Tcp(std::net::TcpListener),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixListener),
}

impl Listener {
    /// The listener itself runs nonblocking (the acceptor polls a
    /// stop flag between attempts, so shutdown never depends on a
    /// wake-up connection reaching a blocked `accept`); accepted
    /// streams are switched back to blocking for the reader/reactor
    /// threads.
    fn set_nonblocking(&self) -> io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(true),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(true),
        }
    }

    fn accept(&self) -> io::Result<WireStream> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                s.set_nodelay(true)?;
                Ok(WireStream::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                Ok(WireStream::Unix(s))
            }
        }
    }
}

impl WireServer {
    /// Bind and start accepting. TCP addresses may use port 0 to get
    /// an ephemeral port (see [`WireServer::addr`] for the resolved
    /// one); a Unix path is created fresh (any stale socket file from
    /// a previous run is removed first) and unlinked again on
    /// shutdown.
    pub fn bind(service: Arc<OverlayService>, addr: &ListenAddr) -> Result<WireServer> {
        WireServer::bind_with_limit(service, addr, None)
    }

    /// [`WireServer::bind`], but the acceptor exits by itself after
    /// `limit` connections (smoke tests, `tmfu listen --max-conns`).
    pub fn bind_with_limit(
        service: Arc<OverlayService>,
        addr: &ListenAddr,
        limit: Option<usize>,
    ) -> Result<WireServer> {
        let (listener, resolved, unix_path) = match addr {
            ListenAddr::Tcp(a) => {
                let l = std::net::TcpListener::bind(a)
                    .with_context(|| format!("bind tcp {a}"))?;
                let actual = l.local_addr().context("tcp local addr")?;
                (Listener::Tcp(l), ListenAddr::Tcp(actual.to_string()), None)
            }
            #[cfg(unix)]
            ListenAddr::Unix(p) => {
                // A crashed previous server leaves the file behind;
                // rebinding is the expected recovery.
                let _ = std::fs::remove_file(p);
                let l = std::os::unix::net::UnixListener::bind(p)
                    .with_context(|| format!("bind unix socket {}", p.display()))?;
                (Listener::Unix(l), addr.clone(), Some(p.clone()))
            }
            #[cfg(not(unix))]
            ListenAddr::Unix(_) => {
                anyhow::bail!("unix sockets are not available on this platform")
            }
        };
        listener.set_nonblocking().context("listener nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let streams: Arc<Mutex<HashMap<u64, WireStream>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let acceptor = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let streams = Arc::clone(&streams);
            thread::Builder::new()
                .name("wire-accept".to_string())
                .spawn(move || {
                    let mut accepted = 0u64;
                    loop {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        if let Some(limit) = limit {
                            if accepted >= limit as u64 {
                                break;
                            }
                        }
                        let stream = match listener.accept() {
                            Ok(s) => s,
                            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                                // Nonblocking poll: nothing waiting.
                                thread::sleep(std::time::Duration::from_millis(5));
                                continue;
                            }
                            // Transient accept failures (EMFILE,
                            // aborted handshakes) must not spin.
                            Err(_) => {
                                thread::sleep(std::time::Duration::from_millis(10));
                                continue;
                            }
                        };
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        accepted += 1;
                        let conn_id = accepted;
                        let control = match stream.try_clone() {
                            Ok(c) => c,
                            Err(_) => continue,
                        };
                        streams.lock().unwrap().insert(conn_id, control);
                        let service = Arc::clone(&service);
                        let conn_streams = Arc::clone(&streams);
                        let spawned = thread::Builder::new()
                            .name(format!("wire-conn-{conn_id}"))
                            .spawn(move || {
                                connection(service, stream);
                                conn_streams.lock().unwrap().remove(&conn_id);
                            });
                        match spawned {
                            Ok(handle) => {
                                // Reap finished connections so a
                                // long-lived server does not
                                // accumulate join handles.
                                let mut cs = conns.lock().unwrap();
                                cs.retain(|h| !h.is_finished());
                                cs.push(handle);
                            }
                            // Thread exhaustion: shed this connection
                            // (close it) instead of killing the
                            // acceptor — same policy as the accept
                            // error arm above.
                            Err(_) => {
                                if let Some(s) = streams.lock().unwrap().remove(&conn_id) {
                                    s.shutdown_both();
                                }
                                accepted -= 1;
                                thread::sleep(std::time::Duration::from_millis(10));
                            }
                        }
                    }
                })
                .context("spawn acceptor")?
        };
        Ok(WireServer {
            addr: resolved,
            unix_path,
            stop,
            acceptor: Some(acceptor),
            conns,
            streams,
        })
    }

    /// The resolved listen address (ephemeral TCP ports filled in) —
    /// pass its string form straight to `OverlayClient::connect`.
    pub fn addr(&self) -> &ListenAddr {
        &self.addr
    }

    /// Block until the acceptor exits on its own (connection limit
    /// reached), then drain connection threads and clean up. Without a
    /// limit this blocks until the process dies — the `tmfu listen`
    /// foreground mode.
    pub fn wait(mut self) {
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        self.finish(false);
    }

    /// Stop accepting, close every connection socket, join all
    /// threads, remove the Unix socket file. Bounded: the acceptor
    /// polls the stop flag (nonblocking accept), so this never waits
    /// on a wake-up connection.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        self.finish(true);
    }

    fn finish(&mut self, force_close: bool) {
        if force_close {
            for s in self.streams.lock().unwrap().values() {
                s.shutdown_both();
            }
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for c in conns {
            let _ = c.join();
        }
        self.streams.lock().unwrap().clear();
        if let Some(p) = self.unix_path.take() {
            let _ = std::fs::remove_file(&p);
        }
    }
}

// ---------------------------------------------------------------------
// Per-connection reactor
// ---------------------------------------------------------------------

/// One in-flight request handed from the reader to the reactor.
enum InFlight {
    Call(Pending),
    Batch(PendingBatch),
}

/// State shared by a connection's reader thread, its reactor thread,
/// and (through the [`Wake`] doorbell registered with every
/// submission) the engine workers completing its requests.
struct ConnShared {
    m: Mutex<ConnState>,
    cv: Condvar,
}

struct ConnState {
    /// Immediate outbound frames from the reader (handshake, resolve
    /// and metrics replies, submit-time errors). Written before any
    /// completion replies in the same wake-up so per-connection frame
    /// order follows the reader's decisions.
    outbox: VecDeque<Frame>,
    /// New in-flight registrations (request id → pending reply),
    /// handed to the reactor, which owns the id map.
    submitted: Vec<(u64, InFlight)>,
    /// Request ids whose slab slot became ready (rung by workers).
    ready: Vec<u64>,
    /// The reader exited (peer hung up or broke protocol). The
    /// reactor drains in-flight work, then exits.
    reader_done: bool,
    /// The reactor's socket write failed; everything else stops.
    dead: bool,
}

impl ConnShared {
    fn new() -> ConnShared {
        ConnShared {
            m: Mutex::new(ConnState {
                outbox: VecDeque::new(),
                submitted: Vec::new(),
                ready: Vec::new(),
                reader_done: false,
                dead: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Reader-side: queue one immediate frame for the reactor to write.
    fn push_frame(&self, frame: Frame) {
        let mut st = self.m.lock().unwrap();
        st.outbox.push_back(frame);
        drop(st);
        self.cv.notify_all();
    }

    /// Reader-side: hand a pending reply to the reactor. The worker
    /// may ring the doorbell for this id *before* the registration is
    /// processed — the reactor's carry list absorbs that race.
    fn register(&self, id: u64, inflight: InFlight) {
        let mut st = self.m.lock().unwrap();
        st.submitted.push((id, inflight));
        drop(st);
        self.cv.notify_all();
    }

    /// Reader-side: the conversation is over.
    fn finish_reader(&self) {
        let mut st = self.m.lock().unwrap();
        st.reader_done = true;
        drop(st);
        self.cv.notify_all();
    }
}

impl Wake for ConnShared {
    /// Worker-side doorbell: a slab slot for this connection became
    /// ready. Never called under a slab lock, so taking the
    /// connection lock here is safe.
    fn ring(&self, tag: u64) {
        let mut st = self.m.lock().unwrap();
        st.ready.push(tag);
        drop(st);
        self.cv.notify_all();
    }
}

fn connection(service: Arc<OverlayService>, stream: WireStream) {
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let control = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let conn = Arc::new(ConnShared::new());
    let reactor_conn = Arc::clone(&conn);
    let spawned = thread::Builder::new()
        .name("wire-react".to_string())
        .spawn(move || reactor_loop(reactor_conn, write_half));
    let Ok(reactor) = spawned else {
        // Thread exhaustion: shed the connection rather than panic.
        control.shutdown_both();
        return;
    };

    let mut reader = BufReader::new(stream);
    serve_connection(&service, &mut reader, &conn);

    // In-flight replies still get written after the reader is done
    // (the peer may have half-closed); the reactor exits once its
    // in-flight map and the outbox are empty.
    conn.finish_reader();
    let _ = reactor.join();
    control.shutdown_both();
}

/// The per-connection reactor: parks on the doorbell, writes the
/// reader's immediate frames, and drains completed in-flight replies
/// straight out of the completion slab. One loop, zero per-call
/// threads.
fn reactor_loop(conn: Arc<ConnShared>, stream: WireStream) {
    let mut w = BufWriter::new(stream);
    // id → pending reply. Bounded by the peer's in-flight window (and
    // transitively by the service's queue depth).
    let mut inflight: HashMap<u64, InFlight> = HashMap::new();
    // Doorbell tags that arrived before their registration (the
    // ring-vs-register race); retried next wake-up.
    let mut carry: Vec<u64> = Vec::new();
    loop {
        let (mut frames, new_inflight, rung) = {
            let mut st = conn.m.lock().unwrap();
            loop {
                if st.dead {
                    return;
                }
                let idle =
                    st.outbox.is_empty() && st.submitted.is_empty() && st.ready.is_empty();
                if !idle {
                    break;
                }
                if st.reader_done && inflight.is_empty() {
                    // Fully drained: no registration is pending (the
                    // idle check above covers `submitted`) and no new
                    // one can arrive, so any still-carried tag is a
                    // duplicate-id artifact that can never resolve —
                    // exit rather than wait for it.
                    return;
                }
                st = conn.cv.wait(st).unwrap();
            }
            (
                std::mem::take(&mut st.outbox),
                std::mem::take(&mut st.submitted),
                std::mem::take(&mut st.ready),
            )
        };
        for (id, p) in new_inflight {
            inflight.insert(id, p);
        }
        let mut write_err = false;
        // Reader-ordered frames first (a reply can never overtake the
        // handshake or its own admission error).
        for frame in frames.drain(..) {
            if write_frame(&mut w, &frame).is_err() {
                write_err = true;
                break;
            }
        }
        // Completions: retry the carried tags now that registrations
        // have landed, then the freshly rung ones.
        let tags: Vec<u64> = carry.drain(..).chain(rung).collect();
        for tag in tags {
            let Some(p) = inflight.remove(&tag) else {
                // Rung before registered: the registration's notify
                // re-wakes us right after it lands.
                carry.push(tag);
                continue;
            };
            let frame = completed_frame(tag, p);
            if !write_err && write_frame(&mut w, &frame).is_err() {
                write_err = true;
            }
        }
        if !write_err && w.flush().is_err() {
            write_err = true;
        }
        if write_err {
            // The peer is unreachable. Unblock our reader, mark the
            // connection dead, and drop the in-flight map — dropping
            // the pendings abandons their slots, which recycle the
            // moment the workers finish.
            if let Ok(inner) = w.get_ref().try_clone() {
                inner.shutdown_both();
            }
            conn.m.lock().unwrap().dead = true;
            return;
        }
    }
}

/// Turn a rung (ready) in-flight entry into its reply frame. The poll
/// cannot block: the doorbell only rings when the slot is ready.
fn completed_frame(id: u64, inflight: InFlight) -> Frame {
    match inflight {
        InFlight::Call(mut p) => match p.poll() {
            // A reply row is exactly the kernel's output arity wide.
            Some(Ok(row)) => Frame::Reply {
                id,
                batch: crate::exec::FlatBatch::from_flat(row.len(), row),
            },
            Some(Err(e)) => Frame::Error {
                id,
                err: WireError::Service(e),
            },
            None => rung_but_not_ready(id),
        },
        InFlight::Batch(mut p) => match p.poll() {
            Some(Ok(batch)) => Frame::Reply { id, batch },
            Some(Err(e)) => Frame::Error {
                id,
                err: WireError::Service(e),
            },
            None => rung_but_not_ready(id),
        },
    }
}

/// Structurally unreachable (the doorbell rings only on ready slots);
/// kept as a typed reply so a protocol invariant bug degrades to one
/// failed request instead of a wedged connection.
fn rung_but_not_ready(id: u64) -> Frame {
    Frame::Error {
        id,
        err: WireError::Service(ServiceError::Backend {
            backend: "wire".to_string(),
            message: "completion doorbell rang without a ready result".to_string(),
        }),
    }
}

/// Decode-and-dispatch loop for one connection. Returns when the peer
/// disconnects or breaks protocol.
fn serve_connection(
    service: &OverlayService,
    reader: &mut BufReader<WireStream>,
    conn: &Arc<ConnShared>,
) {
    // --- handshake -------------------------------------------------
    let hello = match read_frame(reader) {
        Ok(Some(f)) => f,
        Ok(None) => return,
        Err(e) => {
            conn.push_frame(malformed(0, &e));
            return;
        }
    };
    match hello {
        Frame::Hello { id, min, max } => {
            let lo = min.max(WIRE_VERSION_MIN);
            let hi = max.min(WIRE_VERSION_MAX);
            if lo > hi {
                conn.push_frame(Frame::Error {
                    id,
                    err: WireError::VersionMismatch {
                        min: WIRE_VERSION_MIN,
                        max: WIRE_VERSION_MAX,
                    },
                });
                return;
            }
            conn.push_frame(Frame::HelloOk {
                id,
                version: hi,
                backend: service.backend().name().to_string(),
            });
        }
        other => {
            conn.push_frame(malformed(
                other.request_id(),
                &format!("expected Hello, got {}", frame_name(&other)),
            ));
            return;
        }
    }

    // One session handle per registry kernel, resolved once — `Call`
    // frames carry the dense id and index this vector directly.
    let handles: Vec<KernelHandle> = service.handles();

    // --- request loop ----------------------------------------------
    loop {
        let frame = match read_frame(reader) {
            Ok(Some(f)) => f,
            // Clean disconnect, or mid-frame cut: either way the
            // conversation is over. In-flight replies drain through
            // the reactor on their own.
            Ok(None) => return,
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                // Undecodable bytes: tell the peer, then hang up (the
                // stream is no longer frame-aligned).
                conn.push_frame(malformed(0, &e));
                return;
            }
            Err(_) => return,
        };
        match frame {
            Frame::Resolve { id, name } => {
                let reply = match service.kernel(&name) {
                    Ok(h) => Frame::KernelInfo {
                        id,
                        kernel: h.id().0,
                        n_inputs: h.arity() as u16,
                        n_outputs: h.n_outputs() as u16,
                    },
                    Err(e) => Frame::Error {
                        id,
                        err: WireError::Service(e),
                    },
                };
                conn.push_frame(reply);
            }
            Frame::Call { id, kernel, inputs } => {
                let Some(h) = handles.get(kernel as usize) else {
                    conn.push_frame(unknown_kernel(id, kernel));
                    continue;
                };
                // Admission (and its typed errors) happens here on the
                // reader thread; the reply waits in the slab until the
                // doorbell rings the reactor — no thread per call.
                let waker: Arc<dyn Wake> = Arc::clone(conn);
                match h.submit_tagged(&inputs, (waker, id)) {
                    Ok(pending) => conn.register(id, InFlight::Call(pending)),
                    Err(e) => conn.push_frame(Frame::Error {
                        id,
                        err: WireError::Service(e),
                    }),
                }
            }
            Frame::CallBatch { id, kernel, batch } => {
                let Some(h) = handles.get(kernel as usize) else {
                    conn.push_frame(unknown_kernel(id, kernel));
                    continue;
                };
                // The whole batch is one slab reservation; its
                // doorbell rings when the last row lands.
                let waker: Arc<dyn Wake> = Arc::clone(conn);
                match h.submit_batch_tagged(&batch, (waker, id)) {
                    Ok(pending) => conn.register(id, InFlight::Batch(pending)),
                    Err(e) => conn.push_frame(Frame::Error {
                        id,
                        err: WireError::Service(e),
                    }),
                }
            }
            Frame::GetMetrics { id } => {
                let json = service.metrics().to_json().to_string_compact();
                conn.push_frame(Frame::Metrics { id, json });
            }
            other => {
                // Server-to-client opcodes (or a second Hello) are a
                // protocol breach: reply typed, then hang up.
                conn.push_frame(malformed(
                    other.request_id(),
                    &format!("unexpected {} frame from a client", frame_name(&other)),
                ));
                return;
            }
        }
    }
}

fn malformed(id: u64, msg: &impl ToString) -> Frame {
    Frame::Error {
        id,
        err: WireError::Malformed {
            message: msg.to_string(),
        },
    }
}

fn unknown_kernel(id: u64, kernel: u32) -> Frame {
    Frame::Error {
        id,
        err: WireError::Service(ServiceError::UnknownKernel(format!("kernel#{kernel}"))),
    }
}

fn frame_name(f: &Frame) -> &'static str {
    match f {
        Frame::Hello { .. } => "Hello",
        Frame::HelloOk { .. } => "HelloOk",
        Frame::Resolve { .. } => "Resolve",
        Frame::KernelInfo { .. } => "KernelInfo",
        Frame::Call { .. } => "Call",
        Frame::CallBatch { .. } => "CallBatch",
        Frame::Reply { .. } => "Reply",
        Frame::Error { .. } => "Error",
        Frame::GetMetrics { .. } => "GetMetrics",
        Frame::Metrics { .. } => "Metrics",
    }
}
