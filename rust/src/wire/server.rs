//! Wire-protocol server: `tmfu listen` and the test harnesses drive an
//! [`OverlayService`] from decoded frames.
//!
//! Thread shape (std threads; the async reactor is a ROADMAP item):
//!
//! * one **acceptor** thread per bound address; every accepted socket
//!   gets its own connection thread;
//! * each **connection** thread performs the Hello handshake, builds
//!   one pre-resolved [`KernelHandle`] per registry kernel (so `Call`
//!   frames index a vector — no name lookups on the request path),
//!   then decodes frames in a loop;
//! * `Call` / `CallBatch` submit through the service's non-blocking
//!   ports and hand the [`Pending`](crate::service::Pending) reply to
//!   a short-lived **waiter** thread, so one socket carries many
//!   in-flight requests; replies are correlated by request id and may
//!   arrive out of submission order;
//! * a per-connection **writer** thread owns the socket's write half
//!   and serializes every outbound frame (`KernelInfo`, `Reply`,
//!   `Error`, `Metrics`) through one channel.
//!
//! Failure containment: a malformed frame gets a typed
//! [`WireError::Malformed`] reply and the connection is closed; a
//! client that disconnects mid-call only makes the pending reply's
//! channel send fail — the service, the other connections and the
//! acceptor never notice.

use super::{read_frame, write_frame, Frame, ListenAddr, WireError, WireStream};
use crate::exec::FlatBatch;
use crate::service::{KernelHandle, OverlayService, ServiceError};
use crate::wire::{WIRE_VERSION_MAX, WIRE_VERSION_MIN};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

/// A bound, accepting wire server. Dropping the value does **not**
/// stop it — call [`WireServer::shutdown`] (tests, embedders) or
/// [`WireServer::wait`] (`tmfu listen`).
pub struct WireServer {
    addr: ListenAddr,
    unix_path: Option<std::path::PathBuf>,
    stop: Arc<AtomicBool>,
    acceptor: Option<thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    /// Control clones of live connection sockets, keyed by connection
    /// id; entries are removed by the connection thread on exit so a
    /// long-lived server does not leak file descriptors.
    streams: Arc<Mutex<HashMap<u64, WireStream>>>,
}

enum Listener {
    Tcp(std::net::TcpListener),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixListener),
}

impl Listener {
    /// The listener itself runs nonblocking (the acceptor polls a
    /// stop flag between attempts, so shutdown never depends on a
    /// wake-up connection reaching a blocked `accept`); accepted
    /// streams are switched back to blocking for the reader/writer
    /// threads.
    fn set_nonblocking(&self) -> io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(true),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(true),
        }
    }

    fn accept(&self) -> io::Result<WireStream> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                s.set_nodelay(true)?;
                Ok(WireStream::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                Ok(WireStream::Unix(s))
            }
        }
    }
}

impl WireServer {
    /// Bind and start accepting. TCP addresses may use port 0 to get
    /// an ephemeral port (see [`WireServer::addr`] for the resolved
    /// one); a Unix path is created fresh (any stale socket file from
    /// a previous run is removed first) and unlinked again on
    /// shutdown.
    pub fn bind(service: Arc<OverlayService>, addr: &ListenAddr) -> Result<WireServer> {
        WireServer::bind_with_limit(service, addr, None)
    }

    /// [`WireServer::bind`], but the acceptor exits by itself after
    /// `limit` connections (smoke tests, `tmfu listen --max-conns`).
    pub fn bind_with_limit(
        service: Arc<OverlayService>,
        addr: &ListenAddr,
        limit: Option<usize>,
    ) -> Result<WireServer> {
        let (listener, resolved, unix_path) = match addr {
            ListenAddr::Tcp(a) => {
                let l = std::net::TcpListener::bind(a)
                    .with_context(|| format!("bind tcp {a}"))?;
                let actual = l.local_addr().context("tcp local addr")?;
                (Listener::Tcp(l), ListenAddr::Tcp(actual.to_string()), None)
            }
            #[cfg(unix)]
            ListenAddr::Unix(p) => {
                // A crashed previous server leaves the file behind;
                // rebinding is the expected recovery.
                let _ = std::fs::remove_file(p);
                let l = std::os::unix::net::UnixListener::bind(p)
                    .with_context(|| format!("bind unix socket {}", p.display()))?;
                (Listener::Unix(l), addr.clone(), Some(p.clone()))
            }
            #[cfg(not(unix))]
            ListenAddr::Unix(_) => {
                anyhow::bail!("unix sockets are not available on this platform")
            }
        };
        listener.set_nonblocking().context("listener nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let streams: Arc<Mutex<HashMap<u64, WireStream>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let acceptor = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let streams = Arc::clone(&streams);
            thread::Builder::new()
                .name("wire-accept".to_string())
                .spawn(move || {
                    let mut accepted = 0u64;
                    loop {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        if let Some(limit) = limit {
                            if accepted >= limit as u64 {
                                break;
                            }
                        }
                        let stream = match listener.accept() {
                            Ok(s) => s,
                            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                                // Nonblocking poll: nothing waiting.
                                thread::sleep(std::time::Duration::from_millis(5));
                                continue;
                            }
                            // Transient accept failures (EMFILE,
                            // aborted handshakes) must not spin.
                            Err(_) => {
                                thread::sleep(std::time::Duration::from_millis(10));
                                continue;
                            }
                        };
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        accepted += 1;
                        let conn_id = accepted;
                        let control = match stream.try_clone() {
                            Ok(c) => c,
                            Err(_) => continue,
                        };
                        streams.lock().unwrap().insert(conn_id, control);
                        let service = Arc::clone(&service);
                        let conn_streams = Arc::clone(&streams);
                        let spawned = thread::Builder::new()
                            .name(format!("wire-conn-{conn_id}"))
                            .spawn(move || {
                                connection(service, stream);
                                conn_streams.lock().unwrap().remove(&conn_id);
                            });
                        match spawned {
                            Ok(handle) => {
                                // Reap finished connections so a
                                // long-lived server does not
                                // accumulate join handles.
                                let mut cs = conns.lock().unwrap();
                                cs.retain(|h| !h.is_finished());
                                cs.push(handle);
                            }
                            // Thread exhaustion: shed this connection
                            // (close it) instead of killing the
                            // acceptor — same policy as the accept
                            // error arm above.
                            Err(_) => {
                                if let Some(s) = streams.lock().unwrap().remove(&conn_id) {
                                    s.shutdown_both();
                                }
                                accepted -= 1;
                                thread::sleep(std::time::Duration::from_millis(10));
                            }
                        }
                    }
                })
                .context("spawn acceptor")?
        };
        Ok(WireServer {
            addr: resolved,
            unix_path,
            stop,
            acceptor: Some(acceptor),
            conns,
            streams,
        })
    }

    /// The resolved listen address (ephemeral TCP ports filled in) —
    /// pass its string form straight to `OverlayClient::connect`.
    pub fn addr(&self) -> &ListenAddr {
        &self.addr
    }

    /// Block until the acceptor exits on its own (connection limit
    /// reached), then drain connection threads and clean up. Without a
    /// limit this blocks until the process dies — the `tmfu listen`
    /// foreground mode.
    pub fn wait(mut self) {
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        self.finish(false);
    }

    /// Stop accepting, close every connection socket, join all
    /// threads, remove the Unix socket file. Bounded: the acceptor
    /// polls the stop flag (nonblocking accept), so this never waits
    /// on a wake-up connection.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        self.finish(true);
    }

    fn finish(&mut self, force_close: bool) {
        if force_close {
            for s in self.streams.lock().unwrap().values() {
                s.shutdown_both();
            }
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for c in conns {
            let _ = c.join();
        }
        self.streams.lock().unwrap().clear();
        if let Some(p) = self.unix_path.take() {
            let _ = std::fs::remove_file(&p);
        }
    }
}

/// Outbound half of one connection: every producer (reader loop,
/// waiter threads) sends frames here; one writer thread owns the
/// socket's write half.
type Outbox = mpsc::Sender<Frame>;

fn connection(service: Arc<OverlayService>, stream: WireStream) {
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let control = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (tx, rx) = mpsc::channel::<Frame>();
    let spawned = thread::Builder::new()
        .name("wire-write".to_string())
        .spawn(move || {
            let mut w = BufWriter::new(write_half);
            for frame in rx {
                if write_frame(&mut w, &frame).and_then(|()| w.flush()).is_err() {
                    // The peer is gone; unblock our reader too.
                    if let Ok(inner) = w.get_ref().try_clone() {
                        inner.shutdown_both();
                    }
                    break;
                }
            }
        });
    let Ok(writer) = spawned else {
        // Thread exhaustion: shed the connection rather than panic.
        control.shutdown_both();
        return;
    };

    let mut reader = BufReader::new(stream);
    let mut waiters: Vec<thread::JoinHandle<()>> = Vec::new();
    serve_connection(&service, &mut reader, &tx, &mut waiters);

    // Reply channels close once the waiters finish; the writer then
    // drains and exits. Join order matters: waiters hold tx clones.
    for wtr in waiters {
        let _ = wtr.join();
    }
    drop(tx);
    let _ = writer.join();
    control.shutdown_both();
}

/// Decode-and-dispatch loop for one connection. Returns when the peer
/// disconnects or breaks protocol.
fn serve_connection(
    service: &OverlayService,
    reader: &mut BufReader<WireStream>,
    tx: &Outbox,
    waiters: &mut Vec<thread::JoinHandle<()>>,
) {
    // --- handshake -------------------------------------------------
    let hello = match read_frame(reader) {
        Ok(Some(f)) => f,
        Ok(None) => return,
        Err(e) => {
            let _ = tx.send(malformed(0, &e));
            return;
        }
    };
    match hello {
        Frame::Hello { id, min, max } => {
            let lo = min.max(WIRE_VERSION_MIN);
            let hi = max.min(WIRE_VERSION_MAX);
            if lo > hi {
                let _ = tx.send(Frame::Error {
                    id,
                    err: WireError::VersionMismatch {
                        min: WIRE_VERSION_MIN,
                        max: WIRE_VERSION_MAX,
                    },
                });
                return;
            }
            let _ = tx.send(Frame::HelloOk {
                id,
                version: hi,
                backend: service.backend().name().to_string(),
            });
        }
        other => {
            let _ = tx.send(malformed(
                other.request_id(),
                &format!("expected Hello, got {}", frame_name(&other)),
            ));
            return;
        }
    }

    // One session handle per registry kernel, resolved once — `Call`
    // frames carry the dense id and index this vector directly.
    let handles: Vec<KernelHandle> = service.handles();

    // --- request loop ----------------------------------------------
    loop {
        // Reap completed waiters so a long-lived connection does not
        // accumulate join handles.
        waiters.retain(|h| !h.is_finished());
        let frame = match read_frame(reader) {
            Ok(Some(f)) => f,
            // Clean disconnect, or mid-frame cut: either way the
            // conversation is over. In-flight waiters finish on their
            // own; their sends fail harmlessly once the writer is gone.
            Ok(None) => return,
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                // Undecodable bytes: tell the peer, then hang up (the
                // stream is no longer frame-aligned).
                let _ = tx.send(malformed(0, &e));
                return;
            }
            Err(_) => return,
        };
        match frame {
            Frame::Resolve { id, name } => {
                let reply = match service.kernel(&name) {
                    Ok(h) => Frame::KernelInfo {
                        id,
                        kernel: h.id().0,
                        n_inputs: h.arity() as u16,
                        n_outputs: h.n_outputs() as u16,
                    },
                    Err(e) => Frame::Error {
                        id,
                        err: WireError::Service(e),
                    },
                };
                let _ = tx.send(reply);
            }
            Frame::Call { id, kernel, inputs } => {
                let Some(h) = handles.get(kernel as usize) else {
                    let _ = tx.send(unknown_kernel(id, kernel));
                    continue;
                };
                // Admission (and its typed errors) happens here on the
                // reader thread; only the reply wait is offloaded.
                match h.submit(&inputs) {
                    Ok(pending) => {
                        let wtx = tx.clone();
                        let n_outputs = h.n_outputs();
                        match spawn_waiter(move || {
                            let frame = match pending.wait() {
                                Ok(row) => Frame::Reply {
                                    id,
                                    batch: FlatBatch::from_flat(n_outputs, row),
                                },
                                Err(e) => Frame::Error {
                                    id,
                                    err: WireError::Service(e),
                                },
                            };
                            let _ = wtx.send(frame);
                        }) {
                            Ok(w) => waiters.push(w),
                            Err(_) => {
                                let _ = tx.send(overloaded(id));
                            }
                        }
                    }
                    Err(e) => {
                        let _ = tx.send(Frame::Error {
                            id,
                            err: WireError::Service(e),
                        });
                    }
                }
            }
            Frame::CallBatch { id, kernel, batch } => {
                let Some(h) = handles.get(kernel as usize) else {
                    let _ = tx.send(unknown_kernel(id, kernel));
                    continue;
                };
                // `call_batch` blocks until every row replies, so the
                // whole call moves to a waiter; admission is still
                // atomic inside it.
                let wtx = tx.clone();
                let h = h.clone();
                match spawn_waiter(move || {
                    let frame = match h.call_batch(&batch) {
                        Ok(out) => Frame::Reply { id, batch: out },
                        Err(e) => Frame::Error {
                            id,
                            err: WireError::Service(e),
                        },
                    };
                    let _ = wtx.send(frame);
                }) {
                    Ok(w) => waiters.push(w),
                    Err(_) => {
                        let _ = tx.send(overloaded(id));
                    }
                }
            }
            Frame::GetMetrics { id } => {
                let json = service.metrics().to_json().to_string_compact();
                let _ = tx.send(Frame::Metrics { id, json });
            }
            other => {
                // Server-to-client opcodes (or a second Hello) are a
                // protocol breach: reply typed, then hang up.
                let _ = tx.send(malformed(
                    other.request_id(),
                    &format!("unexpected {} frame from a client", frame_name(&other)),
                ));
                return;
            }
        }
    }
}

/// Spawn failure (thread exhaustion) is a per-request error, reported
/// to the caller — never a server panic.
fn spawn_waiter(f: impl FnOnce() + Send + 'static) -> io::Result<thread::JoinHandle<()>> {
    thread::Builder::new().name("wire-wait".to_string()).spawn(f)
}

fn overloaded(id: u64) -> Frame {
    Frame::Error {
        id,
        err: WireError::Service(ServiceError::Backend {
            backend: "wire".to_string(),
            message: "server cannot spawn a reply waiter (thread exhaustion)".to_string(),
        }),
    }
}

fn malformed(id: u64, msg: &impl ToString) -> Frame {
    Frame::Error {
        id,
        err: WireError::Malformed {
            message: msg.to_string(),
        },
    }
}

fn unknown_kernel(id: u64, kernel: u32) -> Frame {
    Frame::Error {
        id,
        err: WireError::Service(ServiceError::UnknownKernel(format!("kernel#{kernel}"))),
    }
}

fn frame_name(f: &Frame) -> &'static str {
    match f {
        Frame::Hello { .. } => "Hello",
        Frame::HelloOk { .. } => "HelloOk",
        Frame::Resolve { .. } => "Resolve",
        Frame::KernelInfo { .. } => "KernelInfo",
        Frame::Call { .. } => "Call",
        Frame::CallBatch { .. } => "CallBatch",
        Frame::Reply { .. } => "Reply",
        Frame::Error { .. } => "Error",
        Frame::GetMetrics { .. } => "GetMetrics",
        Frame::Metrics { .. } => "Metrics",
    }
}
