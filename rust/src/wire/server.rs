//! Wire-protocol server: `tmfu listen` and the test harnesses drive an
//! [`OverlayService`] from decoded frames.
//!
//! Thread shape (std threads; **two per connection, regardless of
//! in-flight calls** — the completion-slab reactor of DESIGN.md §10):
//!
//! * one **acceptor** thread per bound address; every accepted socket
//!   gets its own connection thread;
//! * each **connection** (reader) thread performs the Hello handshake,
//!   builds one pre-resolved [`KernelHandle`] per registry kernel (so
//!   `Call` frames index a vector — no name lookups on the request
//!   path), then decodes frames in a loop. `Call` / `CallBatch`
//!   submit through the service's non-blocking ports with a
//!   completion **doorbell** attached, so admission (and its typed
//!   errors) happens on the reader while nobody ever blocks per call;
//! * one **reactor** thread per connection owns the socket's write
//!   half. It parks on the connection doorbell and wakes when the
//!   reader queues an immediate frame (handshake, resolve, metrics,
//!   submit errors) or when a worker completes an in-flight call —
//!   the slab rings the doorbell with the request id, the reactor
//!   takes the finished result straight out of the slot and writes
//!   the Reply frame. 10k in-flight calls on one socket cost 10k slab
//!   slots and zero extra threads. (The previous design spawned a
//!   short-lived waiter thread per in-flight call — and only reaped
//!   finished waiters when the *next* frame arrived, so an
//!   idle-after-burst connection pinned every completed waiter's
//!   stack indefinitely. Both failure modes are structurally gone.)
//!
//! Replies are correlated by request id and may arrive out of
//! submission order, exactly as before.
//!
//! Failure containment: a malformed frame gets a typed
//! [`WireError::Malformed`] reply and the connection is closed; a
//! client that disconnects mid-call only makes the reactor's reply
//! write fail — the in-flight slots recycle via their drop-abandon
//! path and the service, the other connections and the acceptor never
//! notice.

use super::auth::TenantKeyring;
use super::fault::{FaultPlan, FaultState};
use super::{
    read_frame_patient, write_frame, Frame, ListenAddr, PatientRead, WireError, WireStream,
};
use crate::coordinator::completion::Wake;
use crate::service::{KernelHandle, OverlayService, Pending, PendingBatch, ServiceError};
use crate::wire::{HEALTH_DRAINING, HEALTH_SERVING, WIRE_VERSION_MAX, WIRE_VERSION_MIN};
use crate::util::sync::LockExt;
use anyhow::{Context, Result};
use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{self, BufReader, BufWriter, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

/// Default mid-frame stall deadline: a peer that starts a frame and
/// then goes silent for this long is dropped (the stream can never
/// re-align). Overridable via `TMFU_WIRE_READ_DEADLINE_MS` so tests
/// can provoke the deadline in milliseconds instead of seconds.
const DEFAULT_READ_DEADLINE: Duration = Duration::from_secs(30);

fn read_deadline_from_env() -> Duration {
    std::env::var("TMFU_WIRE_READ_DEADLINE_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis)
        .filter(|d| !d.is_zero())
        .unwrap_or(DEFAULT_READ_DEADLINE)
}

// ---------------------------------------------------------------------
// Drain control
// ---------------------------------------------------------------------

/// Shared liveness/drain state for one server (or, in `tmfu listen`,
/// for *all* of a process's servers — pass one handle to every bind so
/// a `Drain` frame arriving on any transport drains them all).
///
/// Draining means: the acceptor stops accepting, every connection's
/// read half is shut down (no new requests), in-flight replies still
/// flush through the write halves, and [`WireServer::wait`] returns so
/// the process can exit 0.
#[derive(Debug)]
pub struct ServerCtl {
    draining: AtomicBool,
    inflight: AtomicU64,
    read_deadline: Mutex<Duration>,
    fault: Mutex<FaultPlan>,
    auth: Mutex<Option<Arc<TenantKeyring>>>,
}

impl Default for ServerCtl {
    fn default() -> ServerCtl {
        ServerCtl {
            draining: AtomicBool::new(false),
            inflight: AtomicU64::new(0),
            read_deadline: Mutex::new(read_deadline_from_env()),
            fault: Mutex::new(FaultPlan::from_env()),
            auth: Mutex::new(None),
        }
    }
}

impl ServerCtl {
    pub fn new() -> Arc<ServerCtl> {
        Arc::new(ServerCtl::default())
    }

    /// Override the mid-frame stall deadline (tests provoke it in
    /// milliseconds). Applies to connections accepted afterwards.
    pub fn set_read_deadline(&self, d: Duration) {
        *self.read_deadline.lock_unpoisoned() = d;
    }

    pub(crate) fn read_deadline(&self) -> Duration {
        *self.read_deadline.lock_unpoisoned()
    }

    /// Override the fault-injection script for connections accepted
    /// afterwards. The default comes from the `TMFU_FAULT_*`
    /// environment (process-global); tests running several servers in
    /// one process use this to script a fault on exactly one of them.
    pub fn set_fault_plan(&self, plan: FaultPlan) {
        *self.fault.lock_unpoisoned() = plan;
    }

    fn fault_plan(&self) -> FaultPlan {
        self.fault.lock_unpoisoned().clone()
    }

    /// Require tenant authentication: every Hello on connections
    /// accepted afterwards must carry a token that verifies against
    /// this keyring (missing/unknown/mis-signed/replayed ⇒ a typed
    /// `Unauthorized` error, then hangup). With no keyring set (the
    /// default) anonymous Hellos are accepted and any token present is
    /// used as an unverified attribution label.
    pub fn set_auth(&self, keyring: Arc<TenantKeyring>) {
        *self.auth.lock_unpoisoned() = Some(keyring);
    }

    fn auth(&self) -> Option<Arc<TenantKeyring>> {
        self.auth.lock_unpoisoned().clone()
    }

    pub(crate) fn inflight_add(&self, n: u64) {
        self.inflight.fetch_add(n, Ordering::SeqCst);
    }

    pub(crate) fn inflight_sub(&self, n: u64) {
        self.inflight.fetch_sub(n, Ordering::SeqCst);
    }

    /// Request a graceful drain (idempotent).
    pub fn drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Requests admitted to the engine whose replies have not yet been
    /// written back (across all connections sharing this control).
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::SeqCst)
    }
}

// SIGTERM → drain flag. The handler only performs an atomic store
// (async-signal-safe); the acceptor's poll loop notices within one
// tick and turns it into a `ServerCtl::drain`. Declared against the
// already-linked C library — no new dependency.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    pub(super) static DRAIN: AtomicBool = AtomicBool::new(false);

    const SIGTERM: i32 = 15;

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
    }

    extern "C" fn on_sigterm(_sig: i32) {
        DRAIN.store(true, Ordering::SeqCst);
    }

    pub(super) fn install() {
        unsafe {
            signal(SIGTERM, on_sigterm);
        }
    }
}

/// Install the SIGTERM → graceful-drain handler (no-op off Unix).
/// Call once from long-running foreground servers (`tmfu listen`,
/// `tmfu router`); embedders and tests drain via [`ServerCtl::drain`]
/// instead and never touch process signal state.
pub fn install_sigterm_drain() {
    #[cfg(unix)]
    sig::install();
}

pub(crate) fn sigterm_drain_requested() -> bool {
    #[cfg(unix)]
    {
        sig::DRAIN.load(Ordering::SeqCst)
    }
    #[cfg(not(unix))]
    {
        false
    }
}

/// A bound, accepting wire server. Dropping the value does **not**
/// stop it — call [`WireServer::shutdown`] (tests, embedders) or
/// [`WireServer::wait`] (`tmfu listen`).
pub struct WireServer {
    addr: ListenAddr,
    unix_path: Option<std::path::PathBuf>,
    stop: Arc<AtomicBool>,
    ctl: Arc<ServerCtl>,
    acceptor: Option<thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    /// Control clones of live connection sockets, keyed by connection
    /// id; entries are removed by the connection thread on exit so a
    /// long-lived server does not leak file descriptors.
    streams: Arc<Mutex<HashMap<u64, WireStream>>>,
}

pub(crate) enum Listener {
    Tcp(std::net::TcpListener),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixListener),
}

impl Listener {
    /// The listener itself runs nonblocking (the acceptor polls a
    /// stop flag between attempts, so shutdown never depends on a
    /// wake-up connection reaching a blocked `accept`); accepted
    /// streams are switched back to blocking for the reader/reactor
    /// threads.
    fn set_nonblocking(&self) -> io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(true),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(true),
        }
    }

    pub(crate) fn accept(&self) -> io::Result<WireStream> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                s.set_nodelay(true)?;
                Ok(WireStream::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                Ok(WireStream::Unix(s))
            }
        }
    }
}

/// Bind a poll-accept listener (shared by [`WireServer`] and the
/// router's upstream acceptor): resolves ephemeral TCP ports, recreates
/// stale Unix socket files, and switches the listener to nonblocking.
/// Returns the listener, the resolved address, and the Unix socket path
/// to unlink on shutdown (if any).
pub(crate) fn bind_listener(
    addr: &ListenAddr,
) -> Result<(Listener, ListenAddr, Option<std::path::PathBuf>)> {
    let (listener, resolved, unix_path) = match addr {
        ListenAddr::Tcp(a) => {
            let l = std::net::TcpListener::bind(a).with_context(|| format!("bind tcp {a}"))?;
            let actual = l.local_addr().context("tcp local addr")?;
            (Listener::Tcp(l), ListenAddr::Tcp(actual.to_string()), None)
        }
        #[cfg(unix)]
        ListenAddr::Unix(p) => {
            // A crashed previous server leaves the file behind;
            // rebinding is the expected recovery.
            let _ = std::fs::remove_file(p);
            let l = std::os::unix::net::UnixListener::bind(p)
                .with_context(|| format!("bind unix socket {}", p.display()))?;
            (Listener::Unix(l), addr.clone(), Some(p.clone()))
        }
        #[cfg(not(unix))]
        ListenAddr::Unix(_) => {
            anyhow::bail!("unix sockets are not available on this platform")
        }
    };
    listener.set_nonblocking().context("listener nonblocking")?;
    Ok((listener, resolved, unix_path))
}

impl WireServer {
    /// Bind and start accepting. TCP addresses may use port 0 to get
    /// an ephemeral port (see [`WireServer::addr`] for the resolved
    /// one); a Unix path is created fresh (any stale socket file from
    /// a previous run is removed first) and unlinked again on
    /// shutdown.
    pub fn bind(service: Arc<OverlayService>, addr: &ListenAddr) -> Result<WireServer> {
        WireServer::bind_with_limit(service, addr, None)
    }

    /// [`WireServer::bind`], but the acceptor exits by itself after
    /// `limit` connections (smoke tests, `tmfu listen --max-conns`).
    pub fn bind_with_limit(
        service: Arc<OverlayService>,
        addr: &ListenAddr,
        limit: Option<usize>,
    ) -> Result<WireServer> {
        WireServer::bind_with_ctl(service, addr, limit, ServerCtl::new())
    }

    /// [`WireServer::bind_with_limit`] with a caller-supplied
    /// [`ServerCtl`]. `tmfu listen` passes one control to every bound
    /// transport so a `Drain` frame (or SIGTERM) drains them together;
    /// tests drive drain deterministically through the same handle.
    pub fn bind_with_ctl(
        service: Arc<OverlayService>,
        addr: &ListenAddr,
        limit: Option<usize>,
        ctl: Arc<ServerCtl>,
    ) -> Result<WireServer> {
        let (listener, resolved, unix_path) = bind_listener(addr)?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let streams: Arc<Mutex<HashMap<u64, WireStream>>> = Arc::new(Mutex::new(HashMap::new()));
        let acceptor = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let streams = Arc::clone(&streams);
            let ctl = Arc::clone(&ctl);
            thread::Builder::new()
                .name("wire-accept".to_string())
                .spawn(move || {
                    let mut accepted = 0u64;
                    loop {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        if sigterm_drain_requested() {
                            ctl.drain();
                        }
                        if ctl.is_draining() {
                            break;
                        }
                        if let Some(limit) = limit {
                            if accepted >= limit as u64 {
                                break;
                            }
                        }
                        let stream = match listener.accept() {
                            Ok(s) => s,
                            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                                // Nonblocking poll: nothing waiting.
                                thread::sleep(std::time::Duration::from_millis(5));
                                continue;
                            }
                            // Transient accept failures (EMFILE,
                            // aborted handshakes) must not spin.
                            Err(_) => {
                                thread::sleep(std::time::Duration::from_millis(10));
                                continue;
                            }
                        };
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        accepted += 1;
                        let conn_id = accepted;
                        let control = match stream.try_clone() {
                            Ok(c) => c,
                            Err(_) => continue,
                        };
                        streams.lock_unpoisoned().insert(conn_id, control);
                        let service = Arc::clone(&service);
                        let conn_streams = Arc::clone(&streams);
                        let conn_ctl = Arc::clone(&ctl);
                        let spawned = thread::Builder::new()
                            .name(format!("wire-conn-{conn_id}"))
                            .spawn(move || {
                                connection(service, stream, conn_ctl);
                                conn_streams.lock_unpoisoned().remove(&conn_id);
                            });
                        match spawned {
                            Ok(handle) => {
                                // Reap finished connections so a
                                // long-lived server does not
                                // accumulate join handles.
                                let mut cs = conns.lock_unpoisoned();
                                cs.retain(|h| !h.is_finished());
                                cs.push(handle);
                            }
                            // Thread exhaustion: shed this connection
                            // (close it) instead of killing the
                            // acceptor — same policy as the accept
                            // error arm above.
                            Err(_) => {
                                if let Some(s) = streams.lock_unpoisoned().remove(&conn_id) {
                                    s.shutdown_both();
                                }
                                accepted -= 1;
                                thread::sleep(std::time::Duration::from_millis(10));
                            }
                        }
                    }
                })
                .context("spawn acceptor")?
        };
        Ok(WireServer {
            addr: resolved,
            unix_path,
            stop,
            ctl,
            acceptor: Some(acceptor),
            conns,
            streams,
        })
    }

    /// This server's drain/liveness control handle.
    pub fn ctl(&self) -> Arc<ServerCtl> {
        Arc::clone(&self.ctl)
    }

    /// The resolved listen address (ephemeral TCP ports filled in) —
    /// pass its string form straight to `OverlayClient::connect`.
    pub fn addr(&self) -> &ListenAddr {
        &self.addr
    }

    /// Block until the acceptor exits on its own (connection limit
    /// reached, drain requested via [`ServerCtl::drain`], a `Drain`
    /// frame, or SIGTERM), then drain connection threads and clean up.
    /// Without a limit or a drain this blocks until the process dies —
    /// the `tmfu listen` foreground mode.
    ///
    /// On a drain, every connection's **read** half is shut down (no
    /// new requests; blocked readers wake with EOF) while write halves
    /// keep flushing in-flight replies — then all threads are joined.
    /// The caller returning normally afterwards is what makes
    /// SIGTERM-drain exit the process with status 0.
    pub fn wait(mut self) {
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        if self.ctl.is_draining() {
            for s in self.streams.lock_unpoisoned().values() {
                s.shutdown_read();
            }
        }
        self.finish(false);
    }

    /// Stop accepting, close every connection socket, join all
    /// threads, remove the Unix socket file. Bounded: the acceptor
    /// polls the stop flag (nonblocking accept), so this never waits
    /// on a wake-up connection.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        self.finish(true);
    }

    fn finish(&mut self, force_close: bool) {
        if force_close {
            for s in self.streams.lock_unpoisoned().values() {
                s.shutdown_both();
            }
        }
        let conns = std::mem::take(&mut *self.conns.lock_unpoisoned());
        for c in conns {
            let _ = c.join();
        }
        self.streams.lock_unpoisoned().clear();
        if let Some(p) = self.unix_path.take() {
            let _ = std::fs::remove_file(&p);
        }
    }
}

// ---------------------------------------------------------------------
// Per-connection reactor
// ---------------------------------------------------------------------

/// One in-flight request handed from the reader to the reactor.
enum InFlight {
    Call(Pending),
    Batch(PendingBatch),
}

/// State shared by a connection's reader thread, its reactor thread,
/// and (through the [`Wake`] doorbell registered with every
/// submission) the engine workers completing its requests.
struct ConnShared {
    m: Mutex<ConnState>,
    cv: Condvar,
    /// Server-wide drain/in-flight accounting. `register` increments
    /// the in-flight count; the reactor decrements it once the reply
    /// (or the connection's death) settles the request, keeping
    /// `ServerCtl::inflight` an exact ledger for `HealthOk`.
    ctl: Arc<ServerCtl>,
}

struct ConnState {
    /// Immediate outbound frames from the reader (handshake, resolve
    /// and metrics replies, submit-time errors). Written before any
    /// completion replies in the same wake-up so per-connection frame
    /// order follows the reader's decisions.
    outbox: VecDeque<Frame>,
    /// New in-flight registrations (request id → pending reply),
    /// handed to the reactor, which owns the id map.
    submitted: Vec<(u64, InFlight)>,
    /// Request ids the client cancelled (v2 `Cancel` frames). The
    /// reactor settles them against its in-flight map — no reply
    /// frame is ever written for a cancelled id.
    cancels: Vec<u64>,
    /// Request ids whose slab slot became ready (rung by workers).
    ready: Vec<u64>,
    /// The reader exited (peer hung up or broke protocol). The
    /// reactor drains in-flight work, then exits.
    reader_done: bool,
    /// The reactor's socket write failed; everything else stops.
    dead: bool,
}

impl ConnShared {
    fn new(ctl: Arc<ServerCtl>) -> ConnShared {
        ConnShared {
            m: Mutex::new(ConnState {
                outbox: VecDeque::new(),
                submitted: Vec::new(),
                cancels: Vec::new(),
                ready: Vec::new(),
                reader_done: false,
                dead: false,
            }),
            cv: Condvar::new(),
            ctl,
        }
    }

    /// Reader-side: queue one immediate frame for the reactor to write.
    fn push_frame(&self, frame: Frame) {
        let mut st = self.m.lock_unpoisoned();
        st.outbox.push_back(frame);
        drop(st);
        self.cv.notify_all();
    }

    /// Reader-side: hand a pending reply to the reactor. The worker
    /// may ring the doorbell for this id *before* the registration is
    /// processed — the reactor's carry list absorbs that race.
    fn register(&self, id: u64, inflight: InFlight) {
        let mut st = self.m.lock_unpoisoned();
        if st.dead {
            // Torn down already: dropping the pending abandons its
            // slot; the request never enters the in-flight ledger.
            return;
        }
        // Counted under the lock so the reactor's dead-path drain sees
        // a consistent submitted-vs-counter view.
        self.ctl.inflight_add(1);
        st.submitted.push((id, inflight));
        drop(st);
        self.cv.notify_all();
    }

    /// Reader-side: the client cancelled this request id. The reactor
    /// (which owns the in-flight map) performs the actual
    /// cancellation; an unknown or already-settled id is a no-op.
    fn push_cancel(&self, id: u64) {
        let mut st = self.m.lock_unpoisoned();
        st.cancels.push(id);
        drop(st);
        self.cv.notify_all();
    }

    /// Reader-side: the conversation is over.
    fn finish_reader(&self) {
        let mut st = self.m.lock_unpoisoned();
        st.reader_done = true;
        drop(st);
        self.cv.notify_all();
    }
}

impl Wake for ConnShared {
    /// Worker-side doorbell: a slab slot for this connection became
    /// ready. Never called under a slab lock, so taking the
    /// connection lock here is safe.
    fn ring(&self, tag: u64) {
        let mut st = self.m.lock_unpoisoned();
        st.ready.push(tag);
        drop(st);
        self.cv.notify_all();
    }
}

fn connection(service: Arc<OverlayService>, stream: WireStream, ctl: Arc<ServerCtl>) {
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let control = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    // Arm the read deadline: a peer stalled mid-frame past it is
    // dropped; timeouts at a frame boundary are idle ticks, retried
    // forever (keep-alive connections are legal). Best-effort — a
    // socket that refuses the option just blocks as before.
    let _ = stream.set_read_timeout(Some(ctl.read_deadline()));
    let fault = ctl.fault_plan();
    let conn = Arc::new(ConnShared::new(ctl));
    let reactor_conn = Arc::clone(&conn);
    let reactor_fault = FaultState::new(fault.clone());
    let spawned = thread::Builder::new()
        .name("wire-react".to_string())
        .spawn(move || reactor_loop(reactor_conn, write_half, reactor_fault));
    let Ok(reactor) = spawned else {
        // Thread exhaustion: shed the connection rather than panic.
        control.shutdown_both();
        return;
    };

    let mut reader = BufReader::new(stream);
    serve_connection(&service, &mut reader, &conn, &control, FaultState::new(fault));

    // In-flight replies still get written after the reader is done
    // (the peer may have half-closed); the reactor exits once its
    // in-flight map and the outbox are empty.
    conn.finish_reader();
    let _ = reactor.join();
    control.shutdown_both();
}

/// The per-connection reactor: parks on the doorbell, writes the
/// reader's immediate frames, and drains completed in-flight replies
/// straight out of the completion slab. One loop, zero per-call
/// threads.
fn reactor_loop(conn: Arc<ConnShared>, stream: WireStream, mut fault: FaultState) {
    let mut w = BufWriter::new(stream);
    // id → pending reply. Bounded by the peer's in-flight window (and
    // transitively by the service's queue depth).
    let mut inflight: HashMap<u64, InFlight> = HashMap::new();
    // Doorbell tags that arrived before their registration (the
    // ring-vs-register race); retried next wake-up.
    let mut carry: Vec<u64> = Vec::new();
    // Ids cancelled after their result was already ready: the doorbell
    // rang (or is about to surface via `carry`), but the result was
    // consumed by the cancel — drop the stale ring when it arrives.
    // Bounded: every entry is drained by exactly one ring.
    let mut stale_rings: HashSet<u64> = HashSet::new();
    loop {
        let (mut frames, new_inflight, cancels, rung) = {
            let mut st = conn.m.lock_unpoisoned();
            loop {
                if st.dead {
                    let orphaned = std::mem::take(&mut st.submitted);
                    drop(st);
                    settle_remaining(&conn, inflight.len() + orphaned.len());
                    return;
                }
                let idle = st.outbox.is_empty()
                    && st.submitted.is_empty()
                    && st.cancels.is_empty()
                    && st.ready.is_empty();
                if !idle {
                    break;
                }
                if st.reader_done && inflight.is_empty() {
                    // Fully drained: no registration is pending (the
                    // idle check above covers `submitted`) and no new
                    // one can arrive, so any still-carried tag is a
                    // duplicate-id artifact that can never resolve —
                    // exit rather than wait for it.
                    return;
                }
                st = conn.cv.wait(st).unwrap();
            }
            (
                std::mem::take(&mut st.outbox),
                std::mem::take(&mut st.submitted),
                std::mem::take(&mut st.cancels),
                std::mem::take(&mut st.ready),
            )
        };
        for (id, p) in new_inflight {
            inflight.insert(id, p);
        }
        // Client cancellations settle without a reply. The reader
        // registers a Call before it can read the matching Cancel and
        // both hand-offs ride the same lock, so the registration is
        // always merged by the time its cancel is processed here. A
        // not-yet-ready request cancels engine-side (queued rows
        // purge, the slot abandons, its doorbell never rings); an
        // already-ready one has rung, so consume the result and
        // remember the id to drop the stale ring.
        for id in cancels {
            let Some(p) = inflight.remove(&id) else {
                // Already replied (or never submitted): nothing to do.
                continue;
            };
            let ready = match p {
                InFlight::Call(mut p) => {
                    let ready = p.poll().is_some();
                    if !ready {
                        p.cancel();
                    }
                    ready
                }
                InFlight::Batch(mut p) => {
                    let ready = p.poll().is_some();
                    if !ready {
                        p.cancel();
                    }
                    ready
                }
            };
            if ready {
                stale_rings.insert(id);
            }
            conn.ctl.inflight_sub(1);
        }
        let mut write_err = false;
        // Reader-ordered frames first (a reply can never overtake the
        // handshake or its own admission error).
        for frame in frames.drain(..) {
            if write_frame(&mut w, &frame).is_err() {
                write_err = true;
                break;
            }
        }
        // Completions: retry the carried tags now that registrations
        // have landed, then the freshly rung ones.
        let tags: Vec<u64> = carry.drain(..).chain(rung).collect();
        for tag in tags {
            if stale_rings.remove(&tag) {
                // The result behind this ring was consumed by a
                // cancel; the request is already settled.
                continue;
            }
            let Some(p) = inflight.remove(&tag) else {
                // Rung before registered: the registration's notify
                // re-wakes us right after it lands.
                carry.push(tag);
                continue;
            };
            let frame = completed_frame(tag, p);
            // Either way this request is settled: the reply is written
            // or dies with the connection.
            conn.ctl.inflight_sub(1);
            if !write_err {
                fault.before_reply();
                if fault.corrupt_this_reply() {
                    // Scripted corruption: an over-cap length prefix
                    // instead of the reply, then tear down.
                    let _ = w.write_all(&u32::MAX.to_le_bytes());
                    let _ = w.flush();
                    write_err = true;
                } else if write_frame(&mut w, &frame).is_err() {
                    write_err = true;
                }
            }
        }
        if !write_err && w.flush().is_err() {
            write_err = true;
        }
        if write_err {
            // The peer is unreachable. Unblock our reader, mark the
            // connection dead, and drop the in-flight map — dropping
            // the pendings abandons their slots, which recycle the
            // moment the workers finish.
            if let Ok(inner) = w.get_ref().try_clone() {
                inner.shutdown_both();
            }
            let mut st = conn.m.lock_unpoisoned();
            st.dead = true;
            let orphaned = std::mem::take(&mut st.submitted);
            drop(st);
            settle_remaining(&conn, inflight.len() + orphaned.len());
            return;
        }
    }
}

/// Account for in-flight requests a dying connection can never answer:
/// their replies are lost with the socket, so they leave the ledger
/// here (the pendings' drop-abandon recycles the slab slots).
fn settle_remaining(conn: &ConnShared, n: usize) {
    if n > 0 {
        conn.ctl.inflight_sub(n as u64);
    }
}

/// Turn a rung (ready) in-flight entry into its reply frame. The poll
/// cannot block: the doorbell only rings when the slot is ready.
fn completed_frame(id: u64, inflight: InFlight) -> Frame {
    match inflight {
        InFlight::Call(mut p) => match p.poll() {
            // A reply row is exactly the kernel's output arity wide.
            Some(Ok(row)) => Frame::Reply {
                id,
                batch: crate::exec::FlatBatch::from_flat(row.len(), row),
            },
            Some(Err(e)) => Frame::Error {
                id,
                err: WireError::Service(e),
            },
            None => rung_but_not_ready(id),
        },
        InFlight::Batch(mut p) => match p.poll() {
            Some(Ok(batch)) => Frame::Reply { id, batch },
            Some(Err(e)) => Frame::Error {
                id,
                err: WireError::Service(e),
            },
            None => rung_but_not_ready(id),
        },
    }
}

/// Structurally unreachable (the doorbell rings only on ready slots);
/// kept as a typed reply so a protocol invariant bug degrades to one
/// failed request instead of a wedged connection.
fn rung_but_not_ready(id: u64) -> Frame {
    Frame::Error {
        id,
        err: WireError::Service(ServiceError::Backend {
            backend: "wire".to_string(),
            message: "completion doorbell rang without a ready result".to_string(),
        }),
    }
}

/// Decode-and-dispatch loop for one connection. Returns when the peer
/// disconnects, breaks protocol, stalls past the read deadline, or a
/// scripted fault drops the line.
fn serve_connection(
    service: &OverlayService,
    reader: &mut BufReader<WireStream>,
    conn: &Arc<ConnShared>,
    control: &WireStream,
    mut fault: FaultState,
) {
    // --- handshake -------------------------------------------------
    // The handshake read stays patient through idle ticks too: a
    // client may open the socket early and greet later.
    let hello = loop {
        match read_frame_patient(reader) {
            Ok(PatientRead::Frame(f)) => break f,
            Ok(PatientRead::Eof) => return,
            Ok(PatientRead::Idle) => {
                if conn.ctl.is_draining() {
                    return;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                conn.push_frame(malformed(0, &e));
                return;
            }
            Err(_) => return,
        }
    };
    let (version, tenant) = match hello {
        Frame::Hello {
            id,
            min,
            max,
            token,
        } => {
            let lo = min.max(WIRE_VERSION_MIN);
            let hi = max.min(WIRE_VERSION_MAX);
            if lo > hi {
                conn.push_frame(Frame::Error {
                    id,
                    err: WireError::VersionMismatch {
                        min: WIRE_VERSION_MIN,
                        max: WIRE_VERSION_MAX,
                    },
                });
                return;
            }
            // Tenant resolution happens once per connection, before
            // the HelloOk: an auth-required server refuses every
            // unauthenticated Hello with a typed error and hangs up,
            // leaving the service (and the next connection) untouched.
            let tenant: Option<String> = match (conn.ctl.auth(), token) {
                (Some(keyring), Some(tok)) => {
                    if hi < 2 {
                        conn.push_frame(Frame::Error {
                            id,
                            err: WireError::Unauthorized {
                                message: "tenant tokens require protocol v2".to_string(),
                            },
                        });
                        return;
                    }
                    match keyring.verify(&tok) {
                        Ok(entry) => Some(entry.name.clone()),
                        Err(message) => {
                            conn.push_frame(Frame::Error {
                                id,
                                err: WireError::Unauthorized { message },
                            });
                            return;
                        }
                    }
                }
                (Some(_), None) => {
                    conn.push_frame(Frame::Error {
                        id,
                        err: WireError::Unauthorized {
                            message: "server requires a tenant token".to_string(),
                        },
                    });
                    return;
                }
                // Auth off: a token is an unverified attribution
                // label (unknown names fall back to the default lane).
                (None, Some(tok)) => Some(tok.tenant),
                (None, None) => None,
            };
            conn.push_frame(Frame::HelloOk {
                id,
                version: hi,
                backend: service.backend().name().to_string(),
            });
            (hi, tenant)
        }
        other => {
            conn.push_frame(malformed(
                other.request_id(),
                &format!("expected Hello, got {}", frame_name(&other)),
            ));
            return;
        }
    };

    // One session handle per registry kernel, resolved once — `Call`
    // frames carry the dense id and index this vector directly. The
    // handles are bound to the connection's tenant lane.
    let handles: Vec<KernelHandle> = match tenant.as_deref() {
        Some(name) => service.handles_for(name),
        None => service.handles(),
    };

    // --- request loop ----------------------------------------------
    loop {
        let frame = match read_frame_patient(reader) {
            Ok(PatientRead::Frame(f)) => f,
            // Clean disconnect: the conversation is over. In-flight
            // replies drain through the reactor on their own.
            Ok(PatientRead::Eof) => return,
            // Idle at a frame boundary is legal (keep-alive); under a
            // drain no further requests are accepted, so stop reading.
            Ok(PatientRead::Idle) => {
                if conn.ctl.is_draining() {
                    return;
                }
                continue;
            }
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                // Undecodable bytes: tell the peer, then hang up (the
                // stream is no longer frame-aligned).
                conn.push_frame(malformed(0, &e));
                return;
            }
            Err(e) if e.kind() == io::ErrorKind::TimedOut => {
                // Stalled mid-frame past the read deadline: the stream
                // can never re-align. Tear down both halves so the
                // reactor (and the stalled peer) unblock immediately.
                control.shutdown_both();
                return;
            }
            Err(_) => return,
        };
        if fault.frame_read() {
            // Scripted connection drop: simulate a kill -9 — both
            // halves die, in-flight replies are lost.
            control.shutdown_both();
            return;
        }
        match frame {
            Frame::Resolve { id, name } => {
                let reply = match service.kernel(&name) {
                    Ok(h) => Frame::KernelInfo {
                        id,
                        kernel: h.id().0,
                        n_inputs: u16::try_from(h.arity()).unwrap_or(u16::MAX),
                        n_outputs: u16::try_from(h.n_outputs()).unwrap_or(u16::MAX),
                    },
                    Err(e) => Frame::Error {
                        id,
                        err: WireError::Service(e),
                    },
                };
                conn.push_frame(reply);
            }
            Frame::Call {
                id,
                kernel,
                inputs,
                deadline_us,
            } => {
                if deadline_us.is_some() && version < 2 {
                    // The deadline suffix is a v2 extension; a v1 peer
                    // sending one is not frame-aligned the way it
                    // thinks it is. Breach, not best-effort.
                    conn.push_frame(deadline_requires_v2(id, version));
                    return;
                }
                let Some(h) = handles.get(kernel as usize) else {
                    conn.push_frame(unknown_kernel(id, kernel));
                    continue;
                };
                // Admission (and its typed errors) happens here on the
                // reader thread; the reply waits in the slab until the
                // doorbell rings the reactor — no thread per call.
                let waker: Arc<dyn Wake> = Arc::clone(conn);
                let deadline = deadline_us.map(Duration::from_micros);
                match h.submit_tagged(&inputs, deadline, (waker, id)) {
                    Ok(pending) => conn.register(id, InFlight::Call(pending)),
                    Err(e) => conn.push_frame(Frame::Error {
                        id,
                        err: WireError::Service(e),
                    }),
                }
            }
            Frame::CallBatch {
                id,
                kernel,
                batch,
                deadline_us,
            } => {
                if deadline_us.is_some() && version < 2 {
                    conn.push_frame(deadline_requires_v2(id, version));
                    return;
                }
                let Some(h) = handles.get(kernel as usize) else {
                    conn.push_frame(unknown_kernel(id, kernel));
                    continue;
                };
                // The whole batch is one slab reservation; its
                // doorbell rings when the last row lands.
                let waker: Arc<dyn Wake> = Arc::clone(conn);
                let deadline = deadline_us.map(Duration::from_micros);
                match h.submit_batch_tagged(&batch, deadline, (waker, id)) {
                    Ok(pending) => conn.register(id, InFlight::Batch(pending)),
                    Err(e) => conn.push_frame(Frame::Error {
                        id,
                        err: WireError::Service(e),
                    }),
                }
            }
            Frame::Cancel { id } if version >= 2 => {
                // Fire-and-forget: no reply frame is ever written for
                // a Cancel, whether or not the id was still in flight.
                // The reactor owns the in-flight map, so the actual
                // settlement (queued-row purge, slab-slot release)
                // happens there.
                conn.push_cancel(id);
            }
            Frame::GetMetrics { id } => {
                let json = service.metrics().to_json().to_string_compact();
                conn.push_frame(Frame::Metrics { id, json });
            }
            Frame::Health { id } if version >= 2 => {
                let status = if conn.ctl.is_draining() {
                    HEALTH_DRAINING
                } else {
                    HEALTH_SERVING
                };
                conn.push_frame(Frame::HealthOk {
                    id,
                    status,
                    inflight: u32::try_from(conn.ctl.inflight()).unwrap_or(u32::MAX),
                });
            }
            Frame::Drain { id } if version >= 2 => {
                // Graceful drain: flag the server (the acceptor stops,
                // `wait()` shuts read halves and joins), acknowledge,
                // and stop reading further requests on this
                // connection. In-flight replies still flush.
                conn.ctl.drain();
                conn.push_frame(Frame::HealthOk {
                    id,
                    status: HEALTH_DRAINING,
                    inflight: u32::try_from(conn.ctl.inflight()).unwrap_or(u32::MAX),
                });
                return;
            }
            other @ (Frame::Health { .. } | Frame::Drain { .. } | Frame::Cancel { .. }) => {
                // v2 opcodes on a v1-negotiated connection: breach.
                conn.push_frame(malformed(
                    other.request_id(),
                    &format!(
                        "{} requires protocol v2 (negotiated v{version})",
                        frame_name(&other)
                    ),
                ));
                return;
            }
            other => {
                // Server-to-client opcodes (or a second Hello) are a
                // protocol breach: reply typed, then hang up.
                conn.push_frame(malformed(
                    other.request_id(),
                    &format!("unexpected {} frame from a client", frame_name(&other)),
                ));
                return;
            }
        }
    }
}

pub(crate) fn malformed(id: u64, msg: &impl ToString) -> Frame {
    Frame::Error {
        id,
        err: WireError::Malformed {
            message: msg.to_string(),
        },
    }
}

pub(crate) fn deadline_requires_v2(id: u64, version: u16) -> Frame {
    malformed(
        id,
        &format!("deadline_us requires protocol v2 (negotiated v{version})"),
    )
}

pub(crate) fn unknown_kernel(id: u64, kernel: u32) -> Frame {
    Frame::Error {
        id,
        err: WireError::Service(ServiceError::UnknownKernel(format!("kernel#{kernel}"))),
    }
}

pub(crate) fn frame_name(f: &Frame) -> &'static str {
    match f {
        Frame::Hello { .. } => "Hello",
        Frame::HelloOk { .. } => "HelloOk",
        Frame::Resolve { .. } => "Resolve",
        Frame::KernelInfo { .. } => "KernelInfo",
        Frame::Call { .. } => "Call",
        Frame::CallBatch { .. } => "CallBatch",
        Frame::Reply { .. } => "Reply",
        Frame::Error { .. } => "Error",
        Frame::GetMetrics { .. } => "GetMetrics",
        Frame::Metrics { .. } => "Metrics",
        Frame::Health { .. } => "Health",
        Frame::HealthOk { .. } => "HealthOk",
        Frame::Drain { .. } => "Drain",
        Frame::Cancel { .. } => "Cancel",
    }
}
