// Frame codec discipline (DESIGN.md §12): a truncating `as` cast in
// the decode path is how a 16 MiB length prefix becomes a 0-byte read.
// Every width change below goes through `try_from`-backed helpers
// (`width_u16`/`width_u32`, `Dec::len_u32`/`Dec::len_u64`) or carries
// a `cast-ok` justification; `tools/source_lint.py` enforces the
// annotation textually and this module-level pedantic lint enforces
// it in clippy. Applies to the whole `wire::` subtree.
#![warn(clippy::cast_possible_truncation)]
//! Length-prefixed binary wire protocol for the overlay service
//! (DESIGN.md §9, `docs/PROTOCOL.md`).
//!
//! The typed service surface (PR 3) was shaped to serialize: kernel
//! sessions are (dense id, arity) pairs, every failure is a structured
//! [`ServiceError`], and metrics are JSON. This module is the missing
//! transport — a versioned, length-prefixed frame codec over TCP or
//! Unix stream sockets, in the style of tonic's length-delimited
//! framing, so tenants that do not link the crate can call the overlay.
//!
//! Layering:
//!
//! * this module — the **codec**: [`Frame`] (one enum variant per
//!   opcode), byte-exact [`Frame::encode`] / [`Frame::decode`], and
//!   the stream helpers [`read_frame`] / [`write_frame`]. Pure
//!   functions over byte slices; property-tested without sockets.
//! * [`server`] — `tmfu listen`: accepts connections and drives an
//!   [`OverlayService`](crate::service::OverlayService) from decoded
//!   frames (request-id correlation, many in-flight calls per socket).
//! * [`crate::client`] — `OverlayClient` / `RemoteKernel`, the thin
//!   client mirroring `KernelHandle`.
//!
//! Wire format (all integers little-endian; see `docs/PROTOCOL.md`
//! for the normative table):
//!
//! ```text
//! frame   := len:u32 payload            len = payload bytes, <= MAX_PAYLOAD
//! payload := opcode:u8 request_id:u64 body
//! string  := n:u32 utf8[n]
//! words   := i32 x count                contiguous, no per-row framing
//! ```
//!
//! Batches cross the wire exactly as [`FlatBatch`] stores them — one
//! contiguous row-major `i32` buffer — so encoding a `CallBatch` is a
//! single `extend_from_slice`-shaped copy, never a per-row allocation.
//!
//! Version negotiation: the client's `Hello` carries the inclusive
//! range of protocol versions it speaks; the server answers `HelloOk`
//! with the highest version both sides support, or a
//! [`WireError::VersionMismatch`] error frame (code 100) naming its
//! own range, then closes. Version 2 added the liveness opcodes
//! (`Health`/`HealthOk`/`Drain`), the `Unavailable` error code (9),
//! the `Cancel` opcode (0x0E), and the optional `deadline_us` suffix
//! on `Call`/`CallBatch`; a v1-negotiated connection must not carry
//! them (the server answers `Malformed` if it does). The codec itself
//! decodes every known opcode regardless of the negotiated version —
//! gating is the connection state machine's job, not the byte
//! parser's.

pub mod auth;
pub mod fault;
pub mod server;

use crate::exec::FlatBatch;
use crate::service::ServiceError;
use std::fmt;
use std::io::{self, Read, Write};

/// First four payload bytes of every `Hello`: `b"TMFU"`.
pub const WIRE_MAGIC: [u8; 4] = *b"TMFU";
/// Lowest protocol version this build speaks.
pub const WIRE_VERSION_MIN: u16 = 1;
/// Highest protocol version this build speaks. v2 added
/// `Health`/`HealthOk`/`Drain` and error code 9 (`Unavailable`).
pub const WIRE_VERSION_MAX: u16 = 2;
/// Hard cap on a frame payload (16 MiB). [`read_frame`] refuses larger
/// length prefixes before allocating, so a malformed or hostile peer
/// cannot request an unbounded buffer.
pub const MAX_PAYLOAD: usize = 1 << 24;

// Opcode bytes (one per `Frame` variant; stable wire contract).
const OP_HELLO: u8 = 0x01;
const OP_HELLO_OK: u8 = 0x02;
const OP_RESOLVE: u8 = 0x03;
const OP_KERNEL_INFO: u8 = 0x04;
const OP_CALL: u8 = 0x05;
const OP_CALL_BATCH: u8 = 0x06;
const OP_REPLY: u8 = 0x07;
const OP_ERROR: u8 = 0x08;
const OP_GET_METRICS: u8 = 0x09;
const OP_METRICS: u8 = 0x0A;
// v2 liveness opcodes.
const OP_HEALTH: u8 = 0x0B;
const OP_HEALTH_OK: u8 = 0x0C;
const OP_DRAIN: u8 = 0x0D;
// v2 cancellation opcode.
const OP_CANCEL: u8 = 0x0E;

/// `HealthOk.status`: accepting new work.
pub const HEALTH_SERVING: u8 = 0;
/// `HealthOk.status`: draining — finishing in-flight work, accepting
/// no new requests; remove this backend from routing tables.
pub const HEALTH_DRAINING: u8 = 1;

// Error codes (`Error` frame body). 1..=8 round-trip `ServiceError`;
// 100+ are transport-level conditions with no in-process analogue.
const EC_UNKNOWN_KERNEL: u16 = 1;
const EC_SHAPE_MISMATCH: u16 = 2;
const EC_EMPTY_BATCH: u16 = 3;
const EC_REJECTED: u16 = 4;
const EC_SHUT_DOWN: u16 = 5;
const EC_DEADLINE_EXCEEDED: u16 = 6;
const EC_DISCONNECTED: u16 = 7;
const EC_BACKEND: u16 = 8;
const EC_UNAVAILABLE: u16 = 9;
const EC_INVALID_KERNEL: u16 = 10;
const EC_VERSION_MISMATCH: u16 = 100;
const EC_MALFORMED: u16 = 101;
const EC_UNAUTHORIZED: u16 = 102;

/// Length of the HMAC-SHA256 tag carried by a [`TenantToken`].
pub const TOKEN_MAC_LEN: usize = 32;

/// Optional tenant credential carried as a `Hello` suffix:
/// `tenant:string nonce:u64 mac[32]` where
/// `mac = HMAC-SHA256(secret, tenant_bytes || nonce_le8)`. The nonce
/// is fresh per connection; an auth-required server remembers seen
/// `(tenant, nonce)` pairs and refuses replays. Absent on anonymous
/// Hellos — the v1 encoding is byte-identical to before tokens
/// existed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantToken {
    pub tenant: String,
    pub nonce: u64,
    pub mac: [u8; TOKEN_MAC_LEN],
}

impl TenantToken {
    /// Sign `tenant` with `secret` for one connection attempt.
    pub fn sign(tenant: &str, secret: &[u8], nonce: u64) -> TenantToken {
        TenantToken {
            tenant: tenant.to_string(),
            nonce,
            mac: crate::util::hmac::hmac_sha256(secret, &Self::message(tenant, nonce)),
        }
    }

    /// Whether `secret` produces this token's MAC (constant-time).
    pub fn verify(&self, secret: &[u8]) -> bool {
        let expect = crate::util::hmac::hmac_sha256(secret, &Self::message(&self.tenant, self.nonce));
        crate::util::hmac::mac_eq(&self.mac, &expect)
    }

    /// The signed message: tenant bytes then the nonce, little-endian.
    fn message(tenant: &str, nonce: u64) -> Vec<u8> {
        let mut m = Vec::with_capacity(tenant.len() + 8);
        m.extend_from_slice(tenant.as_bytes());
        m.extend_from_slice(&nonce.to_le_bytes());
        m
    }
}

/// Codec failure: a frame that cannot be encoded (out-of-range field)
/// or decoded (truncated, trailing bytes, unknown opcode/code).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrameError {
    pub msg: String,
}

impl FrameError {
    fn new(msg: impl Into<String>) -> FrameError {
        FrameError { msg: msg.into() }
    }
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "wire frame error: {}", self.msg)
    }
}

impl std::error::Error for FrameError {}

/// An error carried by an `Error` frame: either a round-tripped
/// [`ServiceError`] (codes 1..=8) or a transport-level condition.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// A service-layer failure, bit-exactly round-tripped.
    Service(ServiceError),
    /// Hello version ranges do not intersect; the peer names its own
    /// supported range and closes the connection.
    VersionMismatch { min: u16, max: u16 },
    /// The peer sent bytes that do not parse as a legal frame (or an
    /// opcode illegal in the current connection state).
    Malformed { message: String },
    /// An auth-required server refused the Hello: missing, unknown,
    /// mis-signed, or replayed tenant token. The server names the
    /// reason and closes the connection.
    Unauthorized { message: String },
}

impl WireError {
    /// Collapse to a client-visible [`ServiceError`]. Service variants
    /// pass through untouched; transport conditions surface as
    /// `Backend { backend: "wire", .. }`.
    pub fn into_service_error(self) -> ServiceError {
        match self {
            WireError::Service(e) => e,
            WireError::VersionMismatch { min, max } => ServiceError::Backend {
                backend: "wire".to_string(),
                message: format!("protocol version mismatch (server speaks v{min}..=v{max})"),
            },
            WireError::Malformed { message } => ServiceError::Backend {
                backend: "wire".to_string(),
                message: format!("malformed frame: {message}"),
            },
            WireError::Unauthorized { message } => ServiceError::Backend {
                backend: "auth".to_string(),
                message: format!("unauthorized: {message}"),
            },
        }
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Service(e) => write!(f, "{e}"),
            WireError::VersionMismatch { min, max } => {
                write!(f, "protocol version mismatch (peer speaks v{min}..=v{max})")
            }
            WireError::Malformed { message } => write!(f, "malformed frame: {message}"),
            WireError::Unauthorized { message } => write!(f, "unauthorized: {message}"),
        }
    }
}

/// One protocol frame (the payload of one length-prefixed record).
/// Every frame carries the `request_id` used for reply correlation;
/// handshake frames use id 0 by convention.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Frame {
    /// Client → server greeting: magic + supported version range,
    /// optionally followed by a [`TenantToken`] suffix (v2 feature; an
    /// anonymous Hello omits it and stays byte-identical to v1).
    Hello {
        id: u64,
        min: u16,
        max: u16,
        token: Option<TenantToken>,
    },
    /// Server → client: negotiated version + backend name banner.
    HelloOk {
        id: u64,
        version: u16,
        backend: String,
    },
    /// Client → server: resolve a kernel name to an id + arities.
    Resolve { id: u64, name: String },
    /// Server → client: successful resolve.
    KernelInfo {
        id: u64,
        kernel: u32,
        n_inputs: u16,
        n_outputs: u16,
    },
    /// Client → server: one blocking-call request (one input row).
    /// `deadline_us` is an optional relative budget in microseconds
    /// (v2 suffix; a deadline-free Call stays byte-identical to v1) —
    /// the server sheds or expires the request rather than execute it
    /// after the budget runs out.
    Call {
        id: u64,
        kernel: u32,
        inputs: Vec<i32>,
        deadline_us: Option<u64>,
    },
    /// Client → server: an atomically-admitted batch (row-major), with
    /// the same optional `deadline_us` suffix as `Call`.
    CallBatch {
        id: u64,
        kernel: u32,
        batch: FlatBatch,
        deadline_us: Option<u64>,
    },
    /// Server → client: output rows for a `Call` (1 row) or
    /// `CallBatch` (input row count, in order).
    Reply { id: u64, batch: FlatBatch },
    /// Server → client: typed failure for the correlated request.
    Error { id: u64, err: WireError },
    /// Client → server: request a metrics snapshot.
    GetMetrics { id: u64 },
    /// Server → client: `MetricsSnapshot` JSON text.
    Metrics { id: u64, json: String },
    /// Client → server (v2): liveness probe.
    Health { id: u64 },
    /// Server → client (v2): probe answer — [`HEALTH_SERVING`] or
    /// [`HEALTH_DRAINING`] plus the current in-flight request count.
    HealthOk { id: u64, status: u8, inflight: u32 },
    /// Client → server (v2): begin a graceful drain — stop accepting
    /// new connections and new work, finish in-flight requests, then
    /// exit. Acknowledged with a `HealthOk { status: DRAINING }`.
    Drain { id: u64 },
    /// Client → server (v2): abandon the in-flight request with this
    /// `id` — still-queued rows are evicted before they reach a
    /// backend and the completion-slab slot is released. Fire and
    /// forget: the server sends no reply for the cancelled id (a
    /// concurrent completion may still race one out).
    Cancel { id: u64 },
}

impl Frame {
    /// The correlation id this frame carries.
    pub fn request_id(&self) -> u64 {
        match self {
            Frame::Hello { id, .. }
            | Frame::HelloOk { id, .. }
            | Frame::Resolve { id, .. }
            | Frame::KernelInfo { id, .. }
            | Frame::Call { id, .. }
            | Frame::CallBatch { id, .. }
            | Frame::Reply { id, .. }
            | Frame::Error { id, .. }
            | Frame::GetMetrics { id }
            | Frame::Metrics { id, .. }
            | Frame::Health { id }
            | Frame::HealthOk { id, .. }
            | Frame::Drain { id }
            | Frame::Cancel { id } => *id,
        }
    }

    /// Encode to payload bytes (no length prefix). Fails only when a
    /// field exceeds its wire width (arity > u16, rows > u32, string
    /// length > u32).
    pub fn encode(&self) -> Result<Vec<u8>, FrameError> {
        let mut out = Vec::with_capacity(self.encoded_hint());
        match self {
            Frame::Hello {
                id,
                min,
                max,
                token,
            } => {
                head(&mut out, OP_HELLO, *id);
                out.extend_from_slice(&WIRE_MAGIC);
                put_u16(&mut out, *min);
                put_u16(&mut out, *max);
                if let Some(t) = token {
                    put_string(&mut out, &t.tenant)?;
                    put_u64(&mut out, t.nonce);
                    out.extend_from_slice(&t.mac);
                }
            }
            Frame::HelloOk {
                id,
                version,
                backend,
            } => {
                head(&mut out, OP_HELLO_OK, *id);
                put_u16(&mut out, *version);
                put_string(&mut out, backend)?;
            }
            Frame::Resolve { id, name } => {
                head(&mut out, OP_RESOLVE, *id);
                put_string(&mut out, name)?;
            }
            Frame::KernelInfo {
                id,
                kernel,
                n_inputs,
                n_outputs,
            } => {
                head(&mut out, OP_KERNEL_INFO, *id);
                put_u32(&mut out, *kernel);
                put_u16(&mut out, *n_inputs);
                put_u16(&mut out, *n_outputs);
            }
            Frame::Call {
                id,
                kernel,
                inputs,
                deadline_us,
            } => {
                head(&mut out, OP_CALL, *id);
                put_u32(&mut out, *kernel);
                put_u16(&mut out, width_u16(inputs.len(), "call arity")?);
                put_words(&mut out, inputs);
                if let Some(d) = deadline_us {
                    put_u64(&mut out, *d);
                }
            }
            Frame::CallBatch {
                id,
                kernel,
                batch,
                deadline_us,
            } => {
                head(&mut out, OP_CALL_BATCH, *id);
                put_u32(&mut out, *kernel);
                put_batch(&mut out, batch)?;
                if let Some(d) = deadline_us {
                    put_u64(&mut out, *d);
                }
            }
            Frame::Reply { id, batch } => {
                head(&mut out, OP_REPLY, *id);
                put_batch(&mut out, batch)?;
            }
            Frame::Error { id, err } => {
                head(&mut out, OP_ERROR, *id);
                put_error(&mut out, err)?;
            }
            Frame::GetMetrics { id } => {
                head(&mut out, OP_GET_METRICS, *id);
            }
            Frame::Metrics { id, json } => {
                head(&mut out, OP_METRICS, *id);
                put_string(&mut out, json)?;
            }
            Frame::Health { id } => {
                head(&mut out, OP_HEALTH, *id);
            }
            Frame::HealthOk {
                id,
                status,
                inflight,
            } => {
                head(&mut out, OP_HEALTH_OK, *id);
                out.push(*status);
                put_u32(&mut out, *inflight);
            }
            Frame::Drain { id } => {
                head(&mut out, OP_DRAIN, *id);
            }
            Frame::Cancel { id } => {
                head(&mut out, OP_CANCEL, *id);
            }
        }
        Ok(out)
    }

    /// Decode one payload (the bytes after the length prefix). Every
    /// malformed input — truncation, trailing bytes, unknown opcode or
    /// error code, bad magic, ragged batch — is a [`FrameError`],
    /// never a panic.
    pub fn decode(payload: &[u8]) -> Result<Frame, FrameError> {
        let mut d = Dec::new(payload);
        let opcode = d.u8("opcode")?;
        let id = d.u64("request id")?;
        let frame = match opcode {
            OP_HELLO => {
                let magic = d.bytes(4, "hello magic")?;
                if magic != &WIRE_MAGIC[..] {
                    return Err(FrameError::new(format!(
                        "bad hello magic {magic:02x?} (expected {WIRE_MAGIC:02x?})"
                    )));
                }
                let min = d.u16("hello min version")?;
                let max = d.u16("hello max version")?;
                // An anonymous Hello ends here; any remaining bytes
                // must be a complete tenant token suffix.
                let token = if d.remaining() > 0 {
                    let tenant = d.string("token tenant")?;
                    let nonce = d.u64("token nonce")?;
                    let mac_bytes = d.bytes(TOKEN_MAC_LEN, "token mac")?;
                    let mut mac = [0u8; TOKEN_MAC_LEN];
                    mac.copy_from_slice(mac_bytes);
                    Some(TenantToken { tenant, nonce, mac })
                } else {
                    None
                };
                Frame::Hello {
                    id,
                    min,
                    max,
                    token,
                }
            }
            OP_HELLO_OK => Frame::HelloOk {
                id,
                version: d.u16("version")?,
                backend: d.string("backend")?,
            },
            OP_RESOLVE => Frame::Resolve {
                id,
                name: d.string("kernel name")?,
            },
            OP_KERNEL_INFO => Frame::KernelInfo {
                id,
                kernel: d.u32("kernel id")?,
                n_inputs: d.u16("n_inputs")?,
                n_outputs: d.u16("n_outputs")?,
            },
            OP_CALL => {
                let kernel = d.u32("kernel id")?;
                let arity = usize::from(d.u16("call arity")?);
                let inputs = d.words(arity, "call inputs")?;
                // A deadline-free Call ends here; any remaining bytes
                // must be a complete deadline suffix.
                let deadline_us = if d.remaining() > 0 {
                    Some(d.u64("call deadline")?)
                } else {
                    None
                };
                Frame::Call {
                    id,
                    kernel,
                    inputs,
                    deadline_us,
                }
            }
            OP_CALL_BATCH => {
                let kernel = d.u32("kernel id")?;
                let batch = d.batch()?;
                let deadline_us = if d.remaining() > 0 {
                    Some(d.u64("batch deadline")?)
                } else {
                    None
                };
                Frame::CallBatch {
                    id,
                    kernel,
                    batch,
                    deadline_us,
                }
            }
            OP_REPLY => Frame::Reply {
                id,
                batch: d.batch()?,
            },
            OP_ERROR => Frame::Error {
                id,
                err: d.error()?,
            },
            OP_GET_METRICS => Frame::GetMetrics { id },
            OP_METRICS => Frame::Metrics {
                id,
                json: d.string("metrics json")?,
            },
            OP_HEALTH => Frame::Health { id },
            OP_HEALTH_OK => Frame::HealthOk {
                id,
                status: d.u8("health status")?,
                inflight: d.u32("health inflight")?,
            },
            OP_DRAIN => Frame::Drain { id },
            OP_CANCEL => Frame::Cancel { id },
            other => return Err(FrameError::new(format!("unknown opcode 0x{other:02x}"))),
        };
        d.finish()?;
        Ok(frame)
    }

    /// Capacity hint so batch encodes reserve once.
    fn encoded_hint(&self) -> usize {
        9 + match self {
            Frame::Call { inputs, .. } => 14 + 4 * inputs.len(),
            Frame::CallBatch { batch, .. } => 18 + 4 * batch.data().len(),
            Frame::Reply { batch, .. } => 6 + 4 * batch.data().len(),
            Frame::Metrics { json, .. } => 4 + json.len(),
            _ => 32,
        }
    }
}

// ---------------------------------------------------------------------
// Error frame body
// ---------------------------------------------------------------------

fn put_error(out: &mut Vec<u8>, err: &WireError) -> Result<(), FrameError> {
    match err {
        WireError::Service(e) => match e {
            ServiceError::UnknownKernel(kernel) => {
                put_u16(out, EC_UNKNOWN_KERNEL);
                put_string(out, kernel)?;
            }
            ServiceError::ShapeMismatch {
                kernel,
                expected,
                got,
            } => {
                put_u16(out, EC_SHAPE_MISMATCH);
                put_string(out, kernel)?;
                put_u32(out, width_u32(*expected, "shape expected")?);
                put_u32(out, width_u32(*got, "shape got")?);
            }
            ServiceError::EmptyBatch { kernel } => {
                put_u16(out, EC_EMPTY_BATCH);
                put_string(out, kernel)?;
            }
            ServiceError::Rejected {
                kernel,
                tenant,
                queued,
                limit,
            } => {
                put_u16(out, EC_REJECTED);
                put_string(out, kernel)?;
                put_string(out, tenant)?;
                // cast-ok: usize -> u64 widens on every supported host
                put_u64(out, *queued as u64);
                // cast-ok: usize -> u64 widens on every supported host
                put_u64(out, *limit as u64);
            }
            ServiceError::ShutDown => put_u16(out, EC_SHUT_DOWN),
            ServiceError::DeadlineExceeded { kernel } => {
                put_u16(out, EC_DEADLINE_EXCEEDED);
                put_string(out, kernel)?;
            }
            ServiceError::Disconnected { kernel } => {
                put_u16(out, EC_DISCONNECTED);
                put_string(out, kernel)?;
            }
            ServiceError::Backend { backend, message } => {
                put_u16(out, EC_BACKEND);
                put_string(out, backend)?;
                put_string(out, message)?;
            }
            ServiceError::Unavailable { kernel } => {
                put_u16(out, EC_UNAVAILABLE);
                put_string(out, kernel)?;
            }
            ServiceError::InvalidKernel { kernel, detail } => {
                put_u16(out, EC_INVALID_KERNEL);
                put_string(out, kernel)?;
                put_string(out, detail)?;
            }
        },
        WireError::VersionMismatch { min, max } => {
            put_u16(out, EC_VERSION_MISMATCH);
            put_u16(out, *min);
            put_u16(out, *max);
        }
        WireError::Malformed { message } => {
            put_u16(out, EC_MALFORMED);
            put_string(out, message)?;
        }
        WireError::Unauthorized { message } => {
            put_u16(out, EC_UNAUTHORIZED);
            put_string(out, message)?;
        }
    }
    Ok(())
}

impl<'a> Dec<'a> {
    fn error(&mut self) -> Result<WireError, FrameError> {
        let code = self.u16("error code")?;
        Ok(match code {
            EC_UNKNOWN_KERNEL => {
                WireError::Service(ServiceError::UnknownKernel(self.string("kernel")?))
            }
            EC_SHAPE_MISMATCH => WireError::Service(ServiceError::ShapeMismatch {
                kernel: self.string("kernel")?,
                expected: self.len_u32("expected")?,
                got: self.len_u32("got")?,
            }),
            EC_EMPTY_BATCH => WireError::Service(ServiceError::EmptyBatch {
                kernel: self.string("kernel")?,
            }),
            EC_REJECTED => WireError::Service(ServiceError::Rejected {
                kernel: self.string("kernel")?,
                tenant: self.string("tenant")?,
                queued: self.len_u64("queued")?,
                limit: self.len_u64("limit")?,
            }),
            EC_SHUT_DOWN => WireError::Service(ServiceError::ShutDown),
            EC_DEADLINE_EXCEEDED => WireError::Service(ServiceError::DeadlineExceeded {
                kernel: self.string("kernel")?,
            }),
            EC_DISCONNECTED => WireError::Service(ServiceError::Disconnected {
                kernel: self.string("kernel")?,
            }),
            EC_BACKEND => WireError::Service(ServiceError::Backend {
                backend: self.string("backend")?,
                message: self.string("message")?,
            }),
            EC_UNAVAILABLE => WireError::Service(ServiceError::Unavailable {
                kernel: self.string("kernel")?,
            }),
            EC_INVALID_KERNEL => WireError::Service(ServiceError::InvalidKernel {
                kernel: self.string("kernel")?,
                detail: self.string("detail")?,
            }),
            EC_VERSION_MISMATCH => WireError::VersionMismatch {
                min: self.u16("min version")?,
                max: self.u16("max version")?,
            },
            EC_MALFORMED => WireError::Malformed {
                message: self.string("message")?,
            },
            EC_UNAUTHORIZED => WireError::Unauthorized {
                message: self.string("message")?,
            },
            other => return Err(FrameError::new(format!("unknown error code {other}"))),
        })
    }
}

// ---------------------------------------------------------------------
// Primitive encoders
// ---------------------------------------------------------------------

fn head(out: &mut Vec<u8>, opcode: u8, id: u64) {
    out.push(opcode);
    put_u64(out, id);
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_string(out: &mut Vec<u8>, s: &str) -> Result<(), FrameError> {
    put_u32(out, width_u32(s.len(), "string length")?);
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

fn put_words(out: &mut Vec<u8>, words: &[i32]) {
    for w in words {
        out.extend_from_slice(&w.to_le_bytes());
    }
}

/// Batch body: `arity:u16 rows:u32 words[arity*rows]` — the words are
/// the batch's own contiguous buffer, copied in one pass.
fn put_batch(out: &mut Vec<u8>, batch: &FlatBatch) -> Result<(), FrameError> {
    put_u16(out, width_u16(batch.arity(), "batch arity")?);
    put_u32(out, width_u32(batch.n_rows(), "batch rows")?);
    put_words(out, batch.data());
    Ok(())
}

fn width_u16(v: usize, what: &str) -> Result<u16, FrameError> {
    u16::try_from(v).map_err(|_| FrameError::new(format!("{what} {v} exceeds u16")))
}

fn width_u32(v: usize, what: &str) -> Result<u32, FrameError> {
    u32::try_from(v).map_err(|_| FrameError::new(format!("{what} {v} exceeds u32")))
}

// ---------------------------------------------------------------------
// Primitive decoder
// ---------------------------------------------------------------------

/// Bounds-checked little-endian reader over one payload.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8], FrameError> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        match end {
            Some(end) => {
                let s = &self.buf[self.pos..end];
                self.pos = end;
                Ok(s)
            }
            None => Err(FrameError::new(format!(
                "truncated frame: {what} needs {n} bytes, {} left",
                self.buf.len() - self.pos
            ))),
        }
    }

    fn u8(&mut self, what: &str) -> Result<u8, FrameError> {
        Ok(self.bytes(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16, FrameError> {
        Ok(u16::from_le_bytes(self.bytes(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &str) -> Result<u32, FrameError> {
        Ok(u32::from_le_bytes(self.bytes(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64, FrameError> {
        Ok(u64::from_le_bytes(self.bytes(8, what)?.try_into().unwrap()))
    }

    /// Decode a `u32` length/count into a `usize`, checked rather than
    /// cast so no port can silently truncate a frame length.
    fn len_u32(&mut self, what: &str) -> Result<usize, FrameError> {
        let v = self.u32(what)?;
        usize::try_from(v).map_err(|_| FrameError::new(format!("{what} {v} exceeds usize")))
    }

    /// [`Dec::len_u32`] for `u64` counts (queue depths on the error
    /// path): a value that cannot index on this host is a malformed
    /// frame, not a wrapped index.
    fn len_u64(&mut self, what: &str) -> Result<usize, FrameError> {
        let v = self.u64(what)?;
        usize::try_from(v).map_err(|_| FrameError::new(format!("{what} {v} exceeds usize")))
    }

    fn string(&mut self, what: &str) -> Result<String, FrameError> {
        let n = self.len_u32(what)?;
        let raw = self.bytes(n, what)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| FrameError::new(format!("{what}: invalid UTF-8")))
    }

    fn words(&mut self, n: usize, what: &str) -> Result<Vec<i32>, FrameError> {
        let byte_len = n
            .checked_mul(4)
            .ok_or_else(|| FrameError::new(format!("{what}: word count {n} overflows")))?;
        let raw = self.bytes(byte_len, what)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Batch body; a zero-arity batch is legal only with zero rows
    /// (`FlatBatch` cannot represent rows of width 0).
    fn batch(&mut self) -> Result<FlatBatch, FrameError> {
        let arity = usize::from(self.u16("batch arity")?);
        let rows = self.len_u32("batch rows")?;
        if rows == 0 {
            return Ok(FlatBatch::new(arity));
        }
        if arity == 0 {
            return Err(FrameError::new(format!(
                "batch with zero arity but {rows} rows"
            )));
        }
        let words = rows
            .checked_mul(arity)
            .ok_or_else(|| FrameError::new("batch size overflows".to_string()))?;
        let data = self.words(words, "batch words")?;
        Ok(FlatBatch::from_flat(arity, data))
    }

    /// Bytes not yet consumed — used to probe for optional suffixes
    /// (the Hello tenant token) before `finish` enforces exhaustion.
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn finish(&self) -> Result<(), FrameError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(FrameError::new(format!(
                "{} trailing bytes after frame body",
                self.buf.len() - self.pos
            )))
        }
    }
}

// ---------------------------------------------------------------------
// Stream I/O
// ---------------------------------------------------------------------

/// Write one frame (length prefix + payload). Does not flush — callers
/// batch flushes per logical message.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> io::Result<()> {
    let payload = frame
        .encode()
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidInput, e.to_string()))?;
    if payload.len() > MAX_PAYLOAD {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("frame payload {}B exceeds max {MAX_PAYLOAD}B", payload.len()),
        ));
    }
    let len = u32::try_from(payload.len())
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidInput, "frame length exceeds u32"))?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&payload)
}

/// Read one frame. `Ok(None)` on clean EOF at a frame boundary;
/// `InvalidData` errors for oversized prefixes and undecodable
/// payloads; `UnexpectedEof` for mid-frame disconnects.
pub fn read_frame(r: &mut impl Read) -> io::Result<Option<Frame>> {
    let mut len = [0u8; 4];
    // Distinguish "no next frame" (clean close) from truncation.
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len[got..])? {
            0 if got == 0 => return Ok(None),
            0 => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed inside a frame length prefix",
                ))
            }
            n => got += n,
        }
    }
    let len = usize::try_from(u32::from_le_bytes(len))
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "frame length exceeds usize"))?;
    if len > MAX_PAYLOAD {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len}B exceeds max {MAX_PAYLOAD}B"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Frame::decode(&payload)
        .map(Some)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))
}

/// Outcome of one [`read_frame_patient`] attempt over a socket with a
/// read timeout armed.
#[derive(Debug)]
pub(crate) enum PatientRead {
    /// A complete frame arrived.
    Frame(Frame),
    /// Clean EOF at a frame boundary.
    Eof,
    /// The read timeout elapsed with **zero** bytes of the next frame
    /// consumed — the peer is merely idle, not stalled. Callers decide
    /// whether to keep waiting (idle keep-alive is legal) or give up
    /// (requests are in flight and the socket has gone silent).
    Idle,
}

/// Is this the error a timed-out socket read surfaces?
/// (`SO_RCVTIMEO` reads return `WouldBlock` on Unix, `TimedOut` on
/// Windows.)
pub(crate) fn is_timeout(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
    )
}

/// [`read_frame`] for sockets with a read timeout: distinguishes an
/// *idle* peer (timeout at a frame boundary, zero bytes consumed —
/// returned as [`PatientRead::Idle`] for the caller to judge) from a
/// peer *stalled mid-frame* (timeout after the frame started — a
/// `TimedOut` error: the stream can never become frame-aligned again
/// by waiting, so the connection must be dropped).
pub(crate) fn read_frame_patient(r: &mut impl Read) -> io::Result<PatientRead> {
    let mut len = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len[got..]) {
            Ok(0) if got == 0 => return Ok(PatientRead::Eof),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed inside a frame length prefix",
                ))
            }
            Ok(n) => got += n,
            Err(e) if is_timeout(&e) && got == 0 => return Ok(PatientRead::Idle),
            Err(e) if is_timeout(&e) => {
                return Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    "peer stalled mid-frame past the read deadline",
                ))
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let len = usize::try_from(u32::from_le_bytes(len))
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "frame length exceeds usize"))?;
    if len > MAX_PAYLOAD {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len}B exceeds max {MAX_PAYLOAD}B"),
        ));
    }
    let mut payload = vec![0u8; len];
    let mut filled = 0;
    while filled < len {
        match r.read(&mut payload[filled..]) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed inside a frame payload",
                ))
            }
            Ok(n) => filled += n,
            Err(e) if is_timeout(&e) => {
                return Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    "peer stalled mid-frame past the read deadline",
                ))
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Frame::decode(&payload)
        .map(PatientRead::Frame)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))
}

// ---------------------------------------------------------------------
// Addresses & streams (shared by server and client)
// ---------------------------------------------------------------------

/// A serve/connect address: TCP (`host:port`) or a Unix socket path
/// (`unix:<path>`). One string syntax everywhere — `tmfu listen
/// --tcp/--socket`, `tmfu call --addr`, `OverlayClient::connect`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ListenAddr {
    Tcp(String),
    Unix(std::path::PathBuf),
}

impl ListenAddr {
    /// Parse the shared syntax: `unix:` prefix selects a Unix socket,
    /// anything else is a TCP `host:port`.
    pub fn parse(s: &str) -> ListenAddr {
        match s.strip_prefix("unix:") {
            Some(path) => ListenAddr::Unix(std::path::PathBuf::from(path)),
            None => ListenAddr::Tcp(s.to_string()),
        }
    }
}

impl fmt::Display for ListenAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ListenAddr::Tcp(a) => f.write_str(a),
            ListenAddr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// One connected stream socket, TCP or Unix, with uniform clone and
/// shutdown so reader/writer threads can share it.
#[derive(Debug)]
pub(crate) enum WireStream {
    Tcp(std::net::TcpStream),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixStream),
}

impl WireStream {
    pub(crate) fn connect(addr: &ListenAddr) -> io::Result<WireStream> {
        WireStream::connect_with_timeout(addr, None)
    }

    /// [`WireStream::connect`] with an optional TCP connect timeout
    /// (each resolved address gets the full budget; the first success
    /// wins). Unix-socket connects are local rendezvous — effectively
    /// instant or refused — so the timeout only gates TCP.
    pub(crate) fn connect_with_timeout(
        addr: &ListenAddr,
        timeout: Option<std::time::Duration>,
    ) -> io::Result<WireStream> {
        match addr {
            ListenAddr::Tcp(a) => {
                let s = match timeout {
                    None => std::net::TcpStream::connect(a)?,
                    Some(t) => {
                        use std::net::ToSocketAddrs;
                        let mut last: Option<io::Error> = None;
                        let mut found = None;
                        for sa in a.to_socket_addrs()? {
                            match std::net::TcpStream::connect_timeout(&sa, t) {
                                Ok(s) => {
                                    found = Some(s);
                                    break;
                                }
                                Err(e) => last = Some(e),
                            }
                        }
                        match found {
                            Some(s) => s,
                            None => {
                                return Err(last.unwrap_or_else(|| {
                                    io::Error::new(
                                        io::ErrorKind::AddrNotAvailable,
                                        format!("{a}: no addresses resolved"),
                                    )
                                }))
                            }
                        }
                    }
                };
                // The protocol is request/response; Nagle would add
                // ~40ms to every small frame.
                s.set_nodelay(true)?;
                Ok(WireStream::Tcp(s))
            }
            #[cfg(unix)]
            ListenAddr::Unix(p) => Ok(WireStream::Unix(std::os::unix::net::UnixStream::connect(
                p,
            )?)),
            #[cfg(not(unix))]
            ListenAddr::Unix(_) => Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "unix sockets are not available on this platform",
            )),
        }
    }

    pub(crate) fn try_clone(&self) -> io::Result<WireStream> {
        Ok(match self {
            WireStream::Tcp(s) => WireStream::Tcp(s.try_clone()?),
            #[cfg(unix)]
            WireStream::Unix(s) => WireStream::Unix(s.try_clone()?),
        })
    }

    /// Shut down both directions; any thread blocked in `read` on a
    /// clone of this socket wakes with EOF.
    pub(crate) fn shutdown_both(&self) {
        match self {
            WireStream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            #[cfg(unix)]
            WireStream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }

    /// Shut down the read direction only: the peer can send no more
    /// requests (readers wake with EOF), but replies already in flight
    /// still go out through the write half — the graceful-drain shape.
    pub(crate) fn shutdown_read(&self) {
        match self {
            WireStream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Read);
            }
            #[cfg(unix)]
            WireStream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Read);
            }
        }
    }

    /// Arm a read timeout (`None` clears it). Timed-out reads surface
    /// as `WouldBlock`/`TimedOut`, which [`read_frame_patient`] folds
    /// into its idle-vs-stalled distinction.
    pub(crate) fn set_read_timeout(&self, d: Option<std::time::Duration>) -> io::Result<()> {
        match self {
            WireStream::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            WireStream::Unix(s) => s.set_read_timeout(d),
        }
    }
}

impl Read for WireStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            WireStream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            WireStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for WireStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            WireStream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            WireStream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            WireStream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            WireStream::Unix(s) => s.flush(),
        }
    }
}

#[cfg(test)]
#[allow(clippy::cast_possible_truncation)] // test-only generators cast freely
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::quickcheck::{check, prop_assert, Gen};

    fn batch(arity: usize, rows: &[Vec<i32>]) -> FlatBatch {
        FlatBatch::from_rows(arity, rows)
    }

    /// Every variant, exercised for encode→decode identity.
    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Hello {
                id: 0,
                min: 1,
                max: 1,
                token: None,
            },
            Frame::Hello {
                id: 0,
                min: 1,
                max: 2,
                token: Some(TenantToken::sign("acme", b"opensesame", 7)),
            },
            Frame::HelloOk {
                id: 0,
                version: 1,
                backend: "turbo".into(),
            },
            Frame::Resolve {
                id: 1,
                name: "gradient".into(),
            },
            Frame::KernelInfo {
                id: 1,
                kernel: 3,
                n_inputs: 5,
                n_outputs: 1,
            },
            Frame::Call {
                id: 2,
                kernel: 3,
                inputs: vec![3, 5, 2, 7, -1],
                deadline_us: None,
            },
            Frame::Call {
                id: 20,
                kernel: 3,
                inputs: vec![3, 5, 2, 7, -1],
                deadline_us: Some(250_000),
            },
            Frame::CallBatch {
                id: 3,
                kernel: 0,
                batch: batch(2, &[vec![1, -2], vec![3, -4], vec![5, -6]]),
                deadline_us: None,
            },
            Frame::CallBatch {
                id: 21,
                kernel: 0,
                batch: batch(2, &[vec![1, -2], vec![3, -4]]),
                deadline_us: Some(1_000_000),
            },
            Frame::Reply {
                id: 3,
                batch: batch(1, &[vec![36], vec![-7], vec![12]]),
            },
            // Zero-row batches keep their arity through the wire.
            Frame::CallBatch {
                id: 7,
                kernel: 2,
                batch: FlatBatch::new(5),
                deadline_us: None,
            },
            Frame::Error {
                id: 4,
                err: WireError::Service(ServiceError::Rejected {
                    kernel: "poly6".into(),
                    tenant: "acme".into(),
                    queued: 7,
                    limit: 8,
                }),
            },
            Frame::Error {
                id: 0,
                err: WireError::VersionMismatch { min: 1, max: 1 },
            },
            Frame::Error {
                id: 5,
                err: WireError::Service(ServiceError::ShapeMismatch {
                    kernel: "fir".into(),
                    expected: 4,
                    got: 2,
                }),
            },
            Frame::Error {
                id: 6,
                err: WireError::Service(ServiceError::Backend {
                    backend: "pjrt".into(),
                    message: "client create failed".into(),
                }),
            },
            Frame::Error {
                id: 8,
                err: WireError::Service(ServiceError::UnknownKernel("nonesuch".into())),
            },
            Frame::Error {
                id: 9,
                err: WireError::Service(ServiceError::EmptyBatch { kernel: "fir".into() }),
            },
            Frame::Error {
                id: 10,
                err: WireError::Service(ServiceError::ShutDown),
            },
            Frame::Error {
                id: 11,
                err: WireError::Service(ServiceError::DeadlineExceeded { kernel: "mm".into() }),
            },
            Frame::Error {
                id: 12,
                err: WireError::Service(ServiceError::Disconnected { kernel: "mm".into() }),
            },
            Frame::Error {
                id: 13,
                err: WireError::Malformed {
                    message: "unknown opcode 0x7f".into(),
                },
            },
            Frame::Error {
                id: 18,
                err: WireError::Unauthorized {
                    message: "bad tenant signature".into(),
                },
            },
            Frame::Error {
                id: 16,
                err: WireError::Service(ServiceError::Unavailable { kernel: "fir".into() }),
            },
            Frame::Error {
                id: 17,
                err: WireError::Service(ServiceError::InvalidKernel {
                    kernel: "poly6".into(),
                    detail: "tape: dst slot 9 out of range".into(),
                }),
            },
            Frame::GetMetrics { id: 9 },
            Frame::Metrics {
                id: 9,
                json: "{\"completed\":1}".into(),
            },
            Frame::Health { id: 14 },
            Frame::HealthOk {
                id: 14,
                status: HEALTH_SERVING,
                inflight: 3,
            },
            Frame::HealthOk {
                id: 14,
                status: HEALTH_DRAINING,
                inflight: 0,
            },
            Frame::Drain { id: 15 },
            Frame::Cancel { id: 22 },
        ]
    }

    #[test]
    fn every_variant_round_trips() {
        for f in sample_frames() {
            let bytes = f.encode().unwrap();
            let back = Frame::decode(&bytes).unwrap();
            assert_eq!(back, f, "{f:?}");
            assert_eq!(back.request_id(), f.request_id());
        }
    }

    /// Golden byte vectors, cross-checked against the independent
    /// Python mirror (`tools/wire_check.py`) — the layout in
    /// `docs/PROTOCOL.md` is normative and both implementations must
    /// produce these exact bytes.
    #[test]
    fn golden_bytes_match_the_spec() {
        let golden: &[(Frame, &str)] = &[
            (
                Frame::Hello {
                    id: 0,
                    min: 1,
                    max: 1,
                    token: None,
                },
                "010000000000000000544d465501000100",
            ),
            // Signed Hello: secret "opensesame", tenant "acme", nonce 7
            // (MAC cross-checked against python3 hmac/hashlib).
            (
                Frame::Hello {
                    id: 0,
                    min: 1,
                    max: 2,
                    token: Some(TenantToken::sign("acme", b"opensesame", 7)),
                },
                "010000000000000000544d4655010002000400000061636d6507000000000000\
                 00e81184456412c22759ad970d88d386486a8e7c8a168201be77ac6423f813ac\
                 ed",
            ),
            (
                Frame::HelloOk {
                    id: 0,
                    version: 1,
                    backend: "turbo".into(),
                },
                "020000000000000000010005000000747572626f",
            ),
            (
                Frame::Resolve {
                    id: 1,
                    name: "gradient".into(),
                },
                "030100000000000000080000006772616469656e74",
            ),
            (
                Frame::KernelInfo {
                    id: 1,
                    kernel: 3,
                    n_inputs: 5,
                    n_outputs: 1,
                },
                "0401000000000000000300000005000100",
            ),
            (
                Frame::Call {
                    id: 2,
                    kernel: 3,
                    inputs: vec![3, 5, 2, 7, -1],
                    deadline_us: None,
                },
                "0502000000000000000300000005000300000005000000020000000700\
                 0000ffffffff",
            ),
            // Deadline-carrying Call: the base encoding plus an 8-byte
            // deadline_us suffix (250_000 µs).
            (
                Frame::Call {
                    id: 20,
                    kernel: 3,
                    inputs: vec![3, 5, 2, 7, -1],
                    deadline_us: Some(250_000),
                },
                "0514000000000000000300000005000300000005000000020000000700\
                 0000ffffffff90d0030000000000",
            ),
            (
                Frame::CallBatch {
                    id: 3,
                    kernel: 0,
                    batch: batch(2, &[vec![1, -2], vec![3, -4], vec![5, -6]]),
                    deadline_us: None,
                },
                "060300000000000000000000000200030000000100\
                 0000feffffff03000000fcffffff05000000faffffff",
            ),
            // Deadline-carrying CallBatch (1_000_000 µs suffix).
            (
                Frame::CallBatch {
                    id: 21,
                    kernel: 0,
                    batch: batch(2, &[vec![1, -2], vec![3, -4]]),
                    deadline_us: Some(1_000_000),
                },
                "0615000000000000000000000002000200000001000000feffffff0300\
                 0000fcffffff40420f0000000000",
            ),
            (
                Frame::Reply {
                    id: 3,
                    batch: batch(1, &[vec![36], vec![-7], vec![12]]),
                },
                "07030000000000000001000300000024000000f9ffffff0c000000",
            ),
            (
                Frame::CallBatch {
                    id: 7,
                    kernel: 2,
                    batch: FlatBatch::new(5),
                    deadline_us: None,
                },
                "060700000000000000020000000500000000 00",
            ),
            (
                Frame::Error {
                    id: 4,
                    err: WireError::Service(ServiceError::Rejected {
                        kernel: "poly6".into(),
                        tenant: "acme".into(),
                        queued: 7,
                        limit: 8,
                    }),
                },
                "080400000000000000040005000000706f6c79360400000061636d6507000000\
                 0000000008 00000000000000",
            ),
            (
                Frame::Error {
                    id: 18,
                    err: WireError::Unauthorized {
                        message: "bad tenant signature".into(),
                    },
                },
                "0812000000000000006600140000006261642074656e616e74207369676e6174\
                 757265",
            ),
            (
                Frame::Error {
                    id: 0,
                    err: WireError::VersionMismatch { min: 1, max: 1 },
                },
                "080000000000000000640001000100",
            ),
            (Frame::GetMetrics { id: 9 }, "090900000000000000"),
            (
                Frame::Metrics {
                    id: 9,
                    json: "{\"completed\":1}".into(),
                },
                "0a09000000000000000f0000007b22636f6d706c65746564223a317d",
            ),
            (Frame::Health { id: 14 }, "0b0e00000000000000"),
            (
                Frame::HealthOk {
                    id: 14,
                    status: 0,
                    inflight: 3,
                },
                "0c0e000000000000000003000000",
            ),
            (Frame::Drain { id: 15 }, "0d0f00000000000000"),
            (Frame::Cancel { id: 22 }, "0e1600000000000000"),
            (
                Frame::Error {
                    id: 16,
                    err: WireError::Service(ServiceError::Unavailable { kernel: "fir".into() }),
                },
                "081000000000000000090003000000666972",
            ),
            (
                Frame::Error {
                    id: 17,
                    err: WireError::Service(ServiceError::InvalidKernel {
                        kernel: "poly6".into(),
                        detail: "tape: dst slot 9 out of range".into(),
                    }),
                },
                "0811000000000000000a0005000000706f6c79361d000000746170653a2064\
                 737420736c6f742039206f7574206f662072616e6765",
            ),
        ];
        for (frame, hex) in golden {
            let hex: String = hex.chars().filter(|c| !c.is_whitespace()).collect();
            let want: Vec<u8> = (0..hex.len())
                .step_by(2)
                .map(|i| u8::from_str_radix(&hex[i..i + 2], 16).unwrap())
                .collect();
            assert_eq!(frame.encode().unwrap(), want, "{frame:?}");
            assert_eq!(&Frame::decode(&want).unwrap(), frame);
        }
    }

    /// Random-frame generator for the codec property test.
    struct GenFrame;

    fn rand_string(rng: &mut Rng, max: usize) -> String {
        let n = rng.index(max + 1);
        (0..n)
            .map(|_| char::from(b'a' + rng.index(26) as u8))
            .collect()
    }

    fn rand_batch(rng: &mut Rng) -> FlatBatch {
        // Includes zero-row batches; arity >= 1 (the representable set).
        let arity = 1 + rng.index(6);
        let rows = rng.index(9);
        let mut b = FlatBatch::with_capacity(arity, rows);
        for _ in 0..rows {
            b.push_iter((0..arity).map(|_| rng.next_i32()));
        }
        b
    }

    impl Gen for GenFrame {
        type Value = Frame;
        fn generate(&self, rng: &mut Rng) -> Frame {
            let id = rng.next_u64();
            match rng.index(16) {
                // Anonymous only: a signed Hello truncated back to the
                // anonymous length decodes fine, which would break the
                // every-strict-prefix-fails truncation property. The
                // tokened encoding gets its own generator below.
                0 => Frame::Hello {
                    id,
                    min: rng.index(4) as u16,
                    max: rng.index(4) as u16,
                    token: None,
                },
                1 => Frame::HelloOk {
                    id,
                    version: rng.index(4) as u16,
                    backend: rand_string(rng, 12),
                },
                2 => Frame::Resolve {
                    id,
                    name: rand_string(rng, 24),
                },
                3 => Frame::KernelInfo {
                    id,
                    kernel: rng.next_u64() as u32,
                    n_inputs: rng.index(40) as u16,
                    n_outputs: rng.index(40) as u16,
                },
                // Deadline-free only: like the tokened Hello, a
                // deadline-carrying Call truncated back to its base
                // length decodes fine, which would break the
                // every-strict-prefix-fails truncation property. The
                // deadline suffix gets its own generator below.
                4 => Frame::Call {
                    id,
                    kernel: rng.next_u64() as u32,
                    inputs: (0..rng.index(12)).map(|_| rng.next_i32()).collect(),
                    deadline_us: None,
                },
                5 => Frame::CallBatch {
                    id,
                    kernel: rng.next_u64() as u32,
                    batch: rand_batch(rng),
                    deadline_us: None,
                },
                6 => Frame::Reply {
                    id,
                    batch: rand_batch(rng),
                },
                7 => Frame::GetMetrics { id },
                8 => Frame::Metrics {
                    id,
                    json: rand_string(rng, 64),
                },
                9 => Frame::Health { id },
                10 => Frame::HealthOk {
                    id,
                    status: rng.index(3) as u8,
                    inflight: rng.next_u64() as u32,
                },
                11 => Frame::Drain { id },
                12 => Frame::Cancel { id },
                _ => {
                    let err = match rng.index(13) {
                        0 => WireError::Service(ServiceError::UnknownKernel(rand_string(rng, 16))),
                        1 => WireError::Service(ServiceError::ShapeMismatch {
                            kernel: rand_string(rng, 16),
                            expected: rng.index(1000),
                            got: rng.index(1000),
                        }),
                        2 => WireError::Service(ServiceError::EmptyBatch {
                            kernel: rand_string(rng, 16),
                        }),
                        3 => WireError::Service(ServiceError::Rejected {
                            kernel: rand_string(rng, 16),
                            tenant: rand_string(rng, 16),
                            queued: rng.index(1 << 20),
                            limit: rng.index(1 << 20),
                        }),
                        4 => WireError::Service(ServiceError::ShutDown),
                        5 => WireError::Service(ServiceError::DeadlineExceeded {
                            kernel: rand_string(rng, 16),
                        }),
                        6 => WireError::Service(ServiceError::Disconnected {
                            kernel: rand_string(rng, 16),
                        }),
                        7 => WireError::Service(ServiceError::Backend {
                            backend: rand_string(rng, 8),
                            message: rand_string(rng, 48),
                        }),
                        8 => WireError::Service(ServiceError::Unavailable {
                            kernel: rand_string(rng, 16),
                        }),
                        9 => WireError::Service(ServiceError::InvalidKernel {
                            kernel: rand_string(rng, 16),
                            detail: rand_string(rng, 48),
                        }),
                        10 => WireError::VersionMismatch {
                            min: rng.index(4) as u16,
                            max: rng.index(4) as u16,
                        },
                        11 => WireError::Unauthorized {
                            message: rand_string(rng, 32),
                        },
                        _ => WireError::Malformed {
                            message: rand_string(rng, 32),
                        },
                    };
                    Frame::Error { id, err }
                }
            }
        }
    }

    #[test]
    fn prop_random_frames_round_trip() {
        check(400, GenFrame, "wire-frame-roundtrip", |f| {
            let bytes = f.encode().map_err(|e| e.to_string())?;
            let back = Frame::decode(&bytes).map_err(|e| e.to_string())?;
            prop_assert(&back == f, "decode(encode(f)) != f")
        });
    }

    /// Decoding any strict prefix of a valid frame is an error — and
    /// never a panic (the malformed-input half of the codec property).
    #[test]
    fn prop_truncated_frames_error_cleanly() {
        check(150, GenFrame, "wire-frame-truncation", |f| {
            let bytes = f.encode().map_err(|e| e.to_string())?;
            for cut in 0..bytes.len() {
                if Frame::decode(&bytes[..cut]).is_ok() {
                    return Err(format!("prefix of {cut}/{} bytes decoded", bytes.len()));
                }
            }
            // Trailing garbage must be rejected too.
            let mut padded = bytes.clone();
            padded.push(0);
            prop_assert(Frame::decode(&padded).is_err(), "trailing byte accepted")
        });
    }

    /// Random *signed* Hellos, kept out of [`GenFrame`] because the
    /// token is an optional suffix: truncating one back to the
    /// anonymous length legally decodes. This test pins that benign
    /// cut explicitly and requires every other strict prefix to fail.
    struct GenTokenHello;

    impl Gen for GenTokenHello {
        type Value = Frame;
        fn generate(&self, rng: &mut Rng) -> Frame {
            let secret: Vec<u8> = (0..1 + rng.index(24)).map(|_| rng.next_u64() as u8).collect();
            Frame::Hello {
                id: rng.next_u64(),
                min: rng.index(4) as u16,
                max: rng.index(4) as u16,
                token: Some(TenantToken::sign(
                    &rand_string(rng, 16),
                    &secret,
                    rng.next_u64(),
                )),
            }
        }
    }

    #[test]
    fn prop_signed_hellos_round_trip_and_truncate_cleanly() {
        // The anonymous Hello body ends after opcode(1) + id(8) +
        // magic(4) + min(2) + max(2) = 17 bytes; a signed Hello cut
        // there decodes as its anonymous counterpart.
        const ANON_LEN: usize = 17;
        check(200, GenTokenHello, "wire-token-hello", |f| {
            let bytes = f.encode().map_err(|e| e.to_string())?;
            let back = Frame::decode(&bytes).map_err(|e| e.to_string())?;
            prop_assert(&back == f, "decode(encode(f)) != f")?;
            for cut in 0..bytes.len() {
                let got = Frame::decode(&bytes[..cut]);
                if cut == ANON_LEN {
                    match got {
                        Ok(Frame::Hello { token: None, .. }) => {}
                        other => {
                            return Err(format!(
                                "anonymous-length cut should decode tokenless, got {other:?}"
                            ))
                        }
                    }
                } else if got.is_ok() {
                    return Err(format!("prefix of {cut}/{} bytes decoded", bytes.len()));
                }
            }
            Ok(())
        });
    }

    /// Random *deadline-carrying* Calls and CallBatches, kept out of
    /// [`GenFrame`] for the same reason as the tokened Hello: the
    /// deadline is an optional suffix, so truncating one back to its
    /// base length legally decodes (as the deadline-free frame). This
    /// test pins that one benign cut and requires every other strict
    /// prefix to fail.
    struct GenDeadlineCall;

    impl Gen for GenDeadlineCall {
        type Value = Frame;
        fn generate(&self, rng: &mut Rng) -> Frame {
            let id = rng.next_u64();
            let deadline_us = Some(rng.next_u64());
            if rng.index(2) == 0 {
                Frame::Call {
                    id,
                    kernel: rng.next_u64() as u32,
                    inputs: (0..rng.index(12)).map(|_| rng.next_i32()).collect(),
                    deadline_us,
                }
            } else {
                Frame::CallBatch {
                    id,
                    kernel: rng.next_u64() as u32,
                    batch: rand_batch(rng),
                    deadline_us,
                }
            }
        }
    }

    #[test]
    fn prop_deadline_calls_round_trip_and_truncate_cleanly() {
        check(200, GenDeadlineCall, "wire-deadline-call", |f| {
            let bytes = f.encode().map_err(|e| e.to_string())?;
            let back = Frame::decode(&bytes).map_err(|e| e.to_string())?;
            prop_assert(&back == f, "decode(encode(f)) != f")?;
            // The base frame ends 8 bytes before the end; a cut there
            // decodes as the deadline-free counterpart.
            let base_len = bytes.len() - 8;
            for cut in 0..bytes.len() {
                let got = Frame::decode(&bytes[..cut]);
                if cut == base_len {
                    match got {
                        Ok(Frame::Call {
                            deadline_us: None, ..
                        })
                        | Ok(Frame::CallBatch {
                            deadline_us: None, ..
                        }) => {}
                        other => {
                            return Err(format!(
                                "base-length cut should decode deadline-free, got {other:?}"
                            ))
                        }
                    }
                } else if got.is_ok() {
                    return Err(format!("prefix of {cut}/{} bytes decoded", bytes.len()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tenant_token_verify_detects_tampering() {
        let t = TenantToken::sign("acme", b"opensesame", 42);
        assert!(t.verify(b"opensesame"));
        assert!(!t.verify(b"wrong-secret"));
        let mut bad_mac = t.clone();
        bad_mac.mac[0] ^= 1;
        assert!(!bad_mac.verify(b"opensesame"));
        let mut bad_nonce = t.clone();
        bad_nonce.nonce += 1;
        assert!(!bad_nonce.verify(b"opensesame"));
        let mut bad_tenant = t;
        bad_tenant.tenant = "acmf".into();
        assert!(!bad_tenant.verify(b"opensesame"));
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Frame::decode(&[]).is_err());
        assert!(Frame::decode(&[0x7f]).is_err());
        // Unknown opcode with a full header.
        let mut buf = vec![0x7fu8];
        buf.extend_from_slice(&0u64.to_le_bytes());
        let err = Frame::decode(&buf).unwrap_err();
        assert!(err.msg.contains("unknown opcode"), "{err}");
        // Bad hello magic.
        let mut hello = Frame::Hello {
            id: 0,
            min: 1,
            max: 1,
            token: None,
        }
        .encode()
        .unwrap();
        hello[9] = b'X';
        assert!(Frame::decode(&hello).unwrap_err().msg.contains("magic"));
        // String length pointing past the payload.
        let mut resolve = vec![OP_RESOLVE];
        resolve.extend_from_slice(&1u64.to_le_bytes());
        resolve.extend_from_slice(&1000u32.to_le_bytes());
        resolve.extend_from_slice(b"abc");
        assert!(Frame::decode(&resolve).unwrap_err().msg.contains("truncated"));
        // Zero-arity batch with rows.
        let mut cb = vec![OP_CALL_BATCH];
        cb.extend_from_slice(&1u64.to_le_bytes());
        cb.extend_from_slice(&0u32.to_le_bytes()); // kernel
        cb.extend_from_slice(&0u16.to_le_bytes()); // arity 0
        cb.extend_from_slice(&3u32.to_le_bytes()); // rows 3
        assert!(Frame::decode(&cb).unwrap_err().msg.contains("zero arity"));
        // Unknown error code.
        let mut e = vec![OP_ERROR];
        e.extend_from_slice(&1u64.to_le_bytes());
        e.extend_from_slice(&999u16.to_le_bytes());
        assert!(Frame::decode(&e).unwrap_err().msg.contains("error code"));
    }

    #[test]
    fn stream_io_round_trips_and_guards_lengths() {
        let mut buf = Vec::new();
        for f in sample_frames() {
            write_frame(&mut buf, &f).unwrap();
        }
        let mut cur = std::io::Cursor::new(buf);
        for f in sample_frames() {
            assert_eq!(read_frame(&mut cur).unwrap().unwrap(), f);
        }
        // Clean EOF at a boundary is None, not an error.
        assert!(read_frame(&mut cur).unwrap().is_none());
        // A hostile length prefix is refused before allocation.
        let huge = (MAX_PAYLOAD as u32 + 1).to_le_bytes().to_vec();
        let mut cur = std::io::Cursor::new(huge);
        let err = read_frame(&mut cur).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // A truncated length prefix is an UnexpectedEof.
        let mut cur = std::io::Cursor::new(vec![1u8, 0]);
        let err = read_frame(&mut cur).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        // A truncated payload too.
        let mut partial = Vec::new();
        write_frame(&mut partial, &Frame::GetMetrics { id: 1 }).unwrap();
        partial.pop();
        let mut cur = std::io::Cursor::new(partial);
        assert!(read_frame(&mut cur).is_err());
    }

    /// The widest legal frame: a batch whose payload lands within one
    /// word of `MAX_PAYLOAD`. One word more must be refused by
    /// `write_frame`.
    #[test]
    fn max_length_batch_round_trips() {
        // payload = 9 (head) + 4 (kernel) + 2 (arity) + 4 (rows) + 4*words
        let words = (MAX_PAYLOAD - 19) / 4;
        let batch = FlatBatch::from_flat(1, vec![0x5A5A5A5Au32 as i32; words]);
        let f = Frame::CallBatch {
            id: 1,
            kernel: 0,
            batch,
            deadline_us: None,
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        assert_eq!(buf.len(), 4 + MAX_PAYLOAD - 1); // 19 + 4*words = MAX-1
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), f);
        // Push the payload past the cap: write_frame refuses.
        let words = (MAX_PAYLOAD - 19) / 4 + 1;
        let batch = FlatBatch::from_flat(1, vec![0; words]);
        let f = Frame::CallBatch {
            id: 1,
            kernel: 0,
            batch,
            deadline_us: None,
        };
        let err = write_frame(&mut Vec::new(), &f).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
    }

    /// A `Read` that serves scripted chunks, yielding a timeout error
    /// between them (and forever after) — the shape of a socket with
    /// `SO_RCVTIMEO` armed under a trickling or stalled peer.
    struct StutterRead {
        chunks: VecDeque<Vec<u8>>,
    }

    use std::collections::VecDeque;

    impl Read for StutterRead {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            match self.chunks.pop_front() {
                Some(c) if c.is_empty() => {
                    Err(io::Error::new(io::ErrorKind::WouldBlock, "timeout"))
                }
                Some(c) => {
                    let n = c.len().min(buf.len());
                    buf[..n].copy_from_slice(&c[..n]);
                    if n < c.len() {
                        self.chunks.push_front(c[n..].to_vec());
                    }
                    Ok(n)
                }
                None => Err(io::Error::new(io::ErrorKind::WouldBlock, "timeout")),
            }
        }
    }

    #[test]
    fn patient_read_distinguishes_idle_from_mid_frame_stall() {
        let frame = Frame::GetMetrics { id: 7 };
        let mut encoded = Vec::new();
        write_frame(&mut encoded, &frame).unwrap();

        // Idle: timeout with zero bytes of the next frame consumed.
        let mut r = StutterRead {
            chunks: VecDeque::from([vec![]]),
        };
        assert!(matches!(
            read_frame_patient(&mut r).unwrap(),
            PatientRead::Idle
        ));

        // Byte-at-a-time delivery with timeouts *between* frames still
        // decodes: only a timeout after the frame started is a stall.
        let mut chunks: VecDeque<Vec<u8>> =
            encoded.iter().map(|b| vec![*b]).collect();
        chunks.push_back(vec![]); // trailing idle tick
        let mut r = StutterRead { chunks };
        match read_frame_patient(&mut r).unwrap() {
            PatientRead::Frame(f) => assert_eq!(f, frame),
            other => panic!("expected frame, got {other:?}"),
        }
        assert!(matches!(
            read_frame_patient(&mut r).unwrap(),
            PatientRead::Idle
        ));

        // Stall: two bytes of length prefix, then silence.
        let mut r = StutterRead {
            chunks: VecDeque::from([encoded[..2].to_vec()]),
        };
        let err = read_frame_patient(&mut r).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::TimedOut);

        // Stall inside the payload is equally fatal.
        let mut r = StutterRead {
            chunks: VecDeque::from([encoded[..6].to_vec()]),
        };
        let err = read_frame_patient(&mut r).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::TimedOut);

        // Clean EOF at a boundary is Eof, not an error.
        struct Empty;
        impl Read for Empty {
            fn read(&mut self, _: &mut [u8]) -> io::Result<usize> {
                Ok(0)
            }
        }
        assert!(matches!(
            read_frame_patient(&mut Empty).unwrap(),
            PatientRead::Eof
        ));
    }

    #[test]
    fn oversized_fields_fail_encode_not_panic() {
        let f = Frame::Call {
            id: 1,
            kernel: 0,
            inputs: vec![0; u16::MAX as usize + 1],
            deadline_us: None,
        };
        assert!(f.encode().unwrap_err().msg.contains("arity"));
    }

    #[test]
    fn wire_errors_collapse_to_service_errors() {
        let e = WireError::Service(ServiceError::ShutDown).into_service_error();
        assert_eq!(e, ServiceError::ShutDown);
        let e = WireError::VersionMismatch { min: 1, max: 1 }.into_service_error();
        match e {
            ServiceError::Backend { backend, message } => {
                assert_eq!(backend, "wire");
                assert!(message.contains("version"), "{message}");
            }
            other => panic!("expected Backend, got {other}"),
        }
        let e = WireError::Malformed {
            message: "nope".into(),
        }
        .into_service_error();
        assert!(matches!(e, ServiceError::Backend { .. }));
        let e = WireError::Unauthorized {
            message: "unknown tenant 'acme'".into(),
        }
        .into_service_error();
        match e {
            ServiceError::Backend { backend, message } => {
                assert_eq!(backend, "auth");
                assert!(message.contains("unknown tenant"), "{message}");
            }
            other => panic!("expected Backend, got {other}"),
        }
    }

    #[test]
    fn listen_addr_parses_both_schemes() {
        assert_eq!(
            ListenAddr::parse("127.0.0.1:7700"),
            ListenAddr::Tcp("127.0.0.1:7700".into())
        );
        assert_eq!(
            ListenAddr::parse("unix:/tmp/tmfu.sock"),
            ListenAddr::Unix("/tmp/tmfu.sock".into())
        );
        // Display round-trips the shared syntax.
        for s in ["127.0.0.1:7700", "unix:/tmp/tmfu.sock"] {
            assert_eq!(ListenAddr::parse(s).to_string(), s);
        }
    }
}
