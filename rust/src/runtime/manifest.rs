//! `artifacts/manifest.json` parsing — the contract emitted by
//! `python/compile/aot.py` describing each AOT-compiled kernel.
//!
//! Each kernel is lowered at several **batch buckets** (8/64/256 by
//! default); the runtime picks the smallest bucket that fits a request
//! batch and zero-pads to it (bucketed batching).

use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One kernel's entry in the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelEntry {
    pub name: String,
    /// (batch size, artifact path), ascending by batch.
    pub artifacts: Vec<(usize, PathBuf)>,
    pub n_inputs: usize,
    pub n_outputs: usize,
    pub n_ops: usize,
    pub n_fus: usize,
    pub ii: u32,
    pub latency: u64,
    pub context_bytes: usize,
}

impl KernelEntry {
    /// Smallest bucket holding `n` packets.
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.artifacts
            .iter()
            .map(|&(b, _)| b)
            .find(|&b| b >= n)
    }

    pub fn max_batch(&self) -> usize {
        self.artifacts.last().map(|&(b, _)| b).unwrap_or(0)
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Largest batch bucket (back-compat alias).
    pub batch: usize,
    pub batches: Vec<usize>,
    pub kernels: BTreeMap<String, KernelEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let batch = v
            .get("batch")
            .as_usize()
            .context("manifest: missing 'batch'")?;
        let batches: Vec<usize> = match v.get("batches").as_arr() {
            Some(arr) => arr.iter().filter_map(Json::as_usize).collect(),
            None => vec![batch],
        };
        let mut kernels = BTreeMap::new();
        let kmap = v
            .get("kernels")
            .as_obj()
            .context("manifest: missing 'kernels'")?;
        for (name, e) in kmap {
            let mut artifacts = Vec::new();
            if let Some(amap) = e.get("artifacts").as_obj() {
                for (b, a) in amap {
                    let bsz: usize = b.parse().with_context(|| format!("{name}: bad batch key"))?;
                    let file = a
                        .get("file")
                        .as_str()
                        .with_context(|| format!("{name}: artifact missing 'file'"))?;
                    artifacts.push((bsz, dir.join(file)));
                }
            } else if let Some(file) = e.get("artifact").as_str() {
                // Legacy single-batch manifest.
                artifacts.push((batch, dir.join(file)));
            }
            anyhow::ensure!(!artifacts.is_empty(), "{name}: no artifacts listed");
            artifacts.sort_by_key(|&(b, _)| b);
            let entry = KernelEntry {
                name: name.clone(),
                artifacts,
                n_inputs: field(e, name, "n_inputs")?,
                n_outputs: field(e, name, "n_outputs")?,
                n_ops: field(e, name, "n_ops")?,
                n_fus: field(e, name, "n_fus")?,
                ii: field(e, name, "ii")? as u32,
                latency: field(e, name, "latency")? as u64,
                context_bytes: field(e, name, "context_bytes")?,
            };
            kernels.insert(name.clone(), entry);
        }
        Ok(Manifest {
            batch,
            batches,
            kernels,
            dir: dir.to_path_buf(),
        })
    }

    pub fn kernel(&self, name: &str) -> Result<&KernelEntry> {
        self.kernels
            .get(name)
            .with_context(|| format!("kernel '{name}' not in manifest"))
    }
}

fn field(e: &Json, name: &str, key: &str) -> Result<usize> {
    e.get(key)
        .as_usize()
        .with_context(|| format!("{name}: missing '{key}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "batch": 256,
      "batches": [8, 256],
      "kernels": {
        "gradient": {
          "artifacts": {
            "8":   {"file": "gradient.b8.hlo.txt",   "sha256_16": "x"},
            "256": {"file": "gradient.b256.hlo.txt", "sha256_16": "y"}
          },
          "n_inputs": 5, "n_outputs": 1, "n_ops": 11, "n_fus": 4,
          "ii": 11, "latency": 24, "context_bytes": 55
        }
      }
    }"#;

    #[test]
    fn parses_bucketed_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.batch, 256);
        assert_eq!(m.batches, vec![8, 256]);
        let k = m.kernel("gradient").unwrap();
        assert_eq!(k.artifacts.len(), 2);
        assert_eq!(k.bucket_for(1), Some(8));
        assert_eq!(k.bucket_for(8), Some(8));
        assert_eq!(k.bucket_for(9), Some(256));
        assert_eq!(k.bucket_for(257), None);
        assert_eq!(k.max_batch(), 256);
        assert!(m.kernel("nope").is_err());
    }

    #[test]
    fn parses_legacy_single_batch() {
        let legacy = r#"{
          "batch": 64,
          "kernels": {
            "g": {"artifact": "g.hlo.txt", "n_inputs": 1, "n_outputs": 1,
                   "n_ops": 1, "n_fus": 1, "ii": 3, "latency": 4,
                   "context_bytes": 5}
          }
        }"#;
        let m = Manifest::parse(legacy, Path::new(".")).unwrap();
        assert_eq!(m.kernel("g").unwrap().artifacts.len(), 1);
        assert_eq!(m.kernel("g").unwrap().bucket_for(3), Some(64));
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"kernels": {}}"#, Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{"batch": 1, "kernels": {"x": {}}}"#, Path::new(".")).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert_eq!(m.kernels.len(), 9);
            assert_eq!(m.kernel("gradient").unwrap().ii, 11);
            assert!(m.kernel("gradient").unwrap().artifacts.len() >= 2);
        }
    }
}
