//! Offline shim for the `xla` PJRT bindings.
//!
//! The build image ships no XLA/PJRT shared library, so this module
//! provides the exact API surface [`super::pjrt`] consumes with the
//! same shapes and `Result` signatures. Every entry point that would
//! touch the real runtime fails cleanly at [`PjRtClient::cpu`] with an
//! actionable message; nothing downstream of client creation can be
//! reached. Swapping the real `xla` crate back in is a one-line change
//! in `runtime/pjrt.rs` (`use super::xla_shim as xla;` → `use xla;`) —
//! the serving stack itself no longer depends on PJRT because the
//! interpreter and cycle-accurate simulator backends in [`crate::exec`]
//! cover the full workload without artifacts.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?` conversion
/// into `anyhow::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT runtime is not available in this build (the offline image \
         ships no XLA library); use `--backend sim` or `--backend ref`, \
         or link the real `xla` crate in runtime/pjrt.rs"
            .to_string(),
    ))
}

/// Parsed HLO module (stub: retains nothing).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Mirrors `xla::PjRtLoadedExecutable::execute`: one output buffer
    /// list per device.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer holding an execution result.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Host literal (stub: carries no data).
pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[i32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_with_actionable_message() {
        let err = PjRtClient::cpu().err().expect("stub must not succeed");
        assert!(err.to_string().contains("--backend sim"), "{err}");
    }

    #[test]
    fn hlo_parse_fails_cleanly() {
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
    }
}
