//! PJRT execution engine: loads the AOT-compiled HLO text artifacts and
//! executes them on the request path (the "FPGA fabric" of our
//! simulated deployment — see DESIGN.md §3).
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO text →
//! `HloModuleProto::from_text_file` → `XlaComputation` → compile once →
//! `execute` per batch. Python never runs here.

use super::manifest::{KernelEntry, Manifest};
// Offline build: the PJRT bindings are satisfied by the in-repo shim
// (same API, fails cleanly at client creation). Swap this line for the
// real `xla` crate to run on actual PJRT.
use super::xla_shim as xla;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

/// A compiled kernel: one executable per batch bucket, plus signature.
pub struct LoadedKernel {
    pub entry: KernelEntry,
    /// (batch bucket, compiled executable), ascending by bucket.
    exes: Vec<(usize, xla::PjRtLoadedExecutable)>,
    /// Executions performed (metrics).
    pub executions: std::sync::atomic::AtomicU64,
}

/// The engine: one PJRT client + all compiled kernels.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    pub batch: usize,
    kernels: BTreeMap<String, LoadedKernel>,
    /// PJRT CPU execution is not re-entrant per executable here; the
    /// coordinator serializes through this (one "fabric").
    exec_lock: Mutex<()>,
}

impl Engine {
    /// Load every kernel in the manifest and compile it on the CPU
    /// PJRT client.
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut kernels = BTreeMap::new();
        for (name, entry) in &manifest.kernels {
            let mut exes = Vec::new();
            for (bucket, path) in &entry.artifacts {
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("artifact path not utf-8")?,
                )
                .with_context(|| format!("parsing HLO for '{name}' (batch {bucket})"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compiling '{name}' (batch {bucket})"))?;
                exes.push((*bucket, exe));
            }
            kernels.insert(
                name.clone(),
                LoadedKernel {
                    entry: entry.clone(),
                    exes,
                    executions: Default::default(),
                },
            );
        }
        Ok(Engine {
            client,
            batch: manifest.batch,
            kernels,
            exec_lock: Mutex::new(()),
        })
    }

    pub fn kernel_names(&self) -> Vec<&str> {
        self.kernels.keys().map(String::as_str).collect()
    }

    pub fn entry(&self, kernel: &str) -> Result<&KernelEntry> {
        Ok(&self
            .kernels
            .get(kernel)
            .with_context(|| format!("kernel '{kernel}' not loaded"))?
            .entry)
    }

    /// Execute one batch. `packets` is up to `self.batch` rows of
    /// `n_inputs` words; partial batches are zero-padded (the artifact
    /// has a fixed batch dimension). Returns one output row per input
    /// packet.
    pub fn execute(&self, kernel: &str, packets: &[Vec<i32>]) -> Result<Vec<Vec<i32>>> {
        let lk = self
            .kernels
            .get(kernel)
            .with_context(|| format!("kernel '{kernel}' not loaded"))?;
        let (n_in, n_out) = (lk.entry.n_inputs, lk.entry.n_outputs);
        anyhow::ensure!(
            packets.len() <= self.batch,
            "batch overflow: {} > {}",
            packets.len(),
            self.batch
        );
        anyhow::ensure!(!packets.is_empty(), "empty batch");
        // Bucketed batching: smallest compiled bucket that fits, with
        // zero padding ([batch, n_inputs] row-major).
        let bucket = lk
            .entry
            .bucket_for(packets.len())
            .with_context(|| format!("no bucket for batch of {}", packets.len()))?;
        let exe = &lk
            .exes
            .iter()
            .find(|(b, _)| *b == bucket)
            .expect("bucket list consistent")
            .1;
        let mut flat = vec![0i32; bucket * n_in];
        for (i, p) in packets.iter().enumerate() {
            anyhow::ensure!(
                p.len() == n_in,
                "kernel '{kernel}' expects {n_in} inputs, got {}",
                p.len()
            );
            flat[i * n_in..(i + 1) * n_in].copy_from_slice(p);
        }
        let lit = xla::Literal::vec1(&flat)
            .reshape(&[bucket as i64, n_in as i64])
            .context("reshaping input literal")?;
        let result = {
            let _guard = self.exec_lock.lock().unwrap();
            exe.execute::<xla::Literal>(&[lit])?[0][0]
                .to_literal_sync()
                .context("fetching result")?
        };
        lk.executions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // aot.py lowers with return_tuple=True -> 1-tuple.
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        let values = out.to_vec::<i32>().context("reading result values")?;
        anyhow::ensure!(
            values.len() == bucket * n_out,
            "result shape mismatch: {} != {}",
            values.len(),
            bucket * n_out
        );
        Ok(packets
            .iter()
            .enumerate()
            .map(|(i, _)| values[i * n_out..(i + 1) * n_out].to_vec())
            .collect())
    }

    /// Total executions across kernels.
    pub fn total_executions(&self) -> u64 {
        self.kernels
            .values()
            .map(|k| k.executions.load(std::sync::atomic::Ordering::Relaxed))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dfg::eval;
    use crate::util::prng::Rng;

    /// PJRT is not Send/Sync (Rc internals), so all engine tests share
    /// one sequential test body with a locally-owned Engine. Skipped
    /// when `make artifacts` has not been run.
    #[test]
    fn engine_end_to_end() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let e = Engine::load(&dir).expect("engine load");

        // Loads all nine kernels.
        assert_eq!(e.kernel_names().len(), 9);
        assert_eq!(e.batch, 256);

        // L1/L2/L3 numeric agreement: the PJRT-executed artifact must
        // match the Rust functional oracle bit-for-bit.
        let mut rng = Rng::new(99);
        for name in bench_suite::all_names() {
            let g = bench_suite::load(name).unwrap();
            let n_in = g.inputs().len();
            let packets: Vec<Vec<i32>> = (0..17)
                .map(|_| (0..n_in).map(|_| rng.next_i32()).collect())
                .collect();
            let out = e.execute(name, &packets).unwrap();
            for (pkt, got) in packets.iter().zip(&out) {
                assert_eq!(got, &eval(&g, pkt), "{name} diverged on {pkt:?}");
            }
        }

        // Full batch and single packet.
        let g = bench_suite::load("gradient").unwrap();
        let one = vec![vec![3, 5, 2, 7, 1]];
        assert_eq!(e.execute("gradient", &one).unwrap()[0], eval(&g, &one[0]));
        let full: Vec<Vec<i32>> = (0..256).map(|k| vec![k, k, k, k, k]).collect();
        let out = e.execute("gradient", &full).unwrap();
        assert_eq!(out.len(), 256);
        assert!(out.iter().all(|o| o[0] == 0)); // all-equal inputs -> 0

        // Bad batches are rejected.
        assert!(e.execute("gradient", &[]).is_err());
        assert!(e.execute("gradient", &[vec![1, 2]]).is_err());
        assert!(e.execute("nonesuch", &[vec![1]]).is_err());
        let over: Vec<Vec<i32>> = (0..257).map(|_| vec![0; 5]).collect();
        assert!(e.execute("gradient", &over).is_err());

        // Metrics counted.
        assert!(e.total_executions() >= 10);
    }
}
