//! Runtime: PJRT loading/execution of the AOT artifacts plus the
//! manifest contract with `python/compile/aot.py`.
//!
//! The PJRT engine is one of three execution substrates behind the
//! [`crate::exec::Backend`] trait; the serving coordinator no longer
//! depends on it directly.

pub mod manifest;
pub mod pjrt;
pub mod xla_shim;

pub use manifest::{KernelEntry, Manifest};
pub use pjrt::Engine;
