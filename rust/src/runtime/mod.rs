//! Runtime: PJRT loading/execution of the AOT artifacts plus the
//! manifest contract with `python/compile/aot.py`.

pub mod manifest;
pub mod pjrt;

pub use manifest::{KernelEntry, Manifest};
pub use pjrt::Engine;
