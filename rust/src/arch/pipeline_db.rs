//! Linear pipeline built from double-buffered FUs (the §VI extension).
//! Same streaming interface as [`super::Pipeline`]; packet admission is
//! paced at the reduced `II_db = max_s(max(loads_s, execs_s))`.

use super::fifo::Fifo;
use super::fu_db::{ii_double_buffered, FuDb};
use crate::sched::Program;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct PipelineDb {
    pub kernel: String,
    fus: Vec<FuDb>,
    pub input_fifo: Fifo,
    pub output_fifo: Fifo,
    n_inputs: usize,
    n_out_words: usize,
    output_order: Vec<(String, usize)>,
    ii: u64,
    next_packet_cycle: u64,
    words_in: u64,
    pub cycle: u64,
}

impl PipelineDb {
    pub fn new(p: &Program, fifo_capacity: usize) -> Result<PipelineDb> {
        let mut fus = Vec::with_capacity(p.stages.len());
        for st in p.stages.iter() {
            let consts: Vec<i32> = st.consts.iter().map(|&(_, v)| v).collect();
            fus.push(FuDb::new(st.instrs.clone(), &consts, st.n_loads())?);
        }
        let last = p.stages.last().unwrap();
        Ok(PipelineDb {
            kernel: p.kernel.clone(),
            fus,
            input_fifo: Fifo::new(fifo_capacity),
            output_fifo: Fifo::new(fifo_capacity),
            n_inputs: p.stages[0].n_loads(),
            n_out_words: last.n_execs(),
            output_order: p.output_order.clone(),
            ii: ii_double_buffered(p) as u64,
            next_packet_cycle: 1,
            words_in: 0,
            cycle: 0,
        })
    }

    pub fn ii(&self) -> u64 {
        self.ii
    }

    pub fn enqueue_packet(&mut self, packet: &[i32]) -> bool {
        assert_eq!(packet.len(), self.n_inputs, "packet arity");
        if self.input_fifo.capacity() - self.input_fifo.len() < packet.len() {
            return false;
        }
        for &v in packet {
            let ok = self.input_fifo.push(v);
            debug_assert!(ok);
        }
        true
    }

    pub fn step(&mut self) -> Result<()> {
        self.cycle += 1;
        let at_boundary = self.words_in % self.n_inputs as u64 == 0;
        let gate_open = !at_boundary || self.cycle >= self.next_packet_cycle;
        let mut carry: Option<i32> = if self.fus[0].can_accept() && gate_open {
            let w = self.input_fifo.pop();
            if w.is_some() {
                if at_boundary {
                    self.next_packet_cycle = self.cycle + self.ii;
                }
                self.words_in += 1;
            }
            w
        } else {
            None
        };
        for fu in &mut self.fus {
            carry = fu.step(carry)?;
        }
        if let Some(v) = carry {
            if !self.output_fifo.push(v) {
                anyhow::bail!("output FIFO overflow");
            }
        }
        Ok(())
    }

    pub fn packets_ready(&self) -> usize {
        self.output_fifo.len() / self.n_out_words
    }

    pub fn dequeue_packet(&mut self) -> Option<Vec<i32>> {
        if self.packets_ready() == 0 {
            return None;
        }
        let words: Vec<i32> = (0..self.n_out_words)
            .map(|_| self.output_fifo.pop().unwrap())
            .collect();
        Some(self.output_order.iter().map(|&(_, pos)| words[pos]).collect())
    }

    pub fn run(&mut self, packets: &[Vec<i32>], max_cycles: u64) -> Result<Vec<Vec<i32>>> {
        let mut next = 0usize;
        let mut out = Vec::with_capacity(packets.len());
        let start = self.cycle;
        while out.len() < packets.len() {
            if self.cycle - start > max_cycles {
                anyhow::bail!("cycle budget exceeded ({}/{})", out.len(), packets.len());
            }
            if next < packets.len() && self.enqueue_packet(&packets[next]) {
                next += 1;
            }
            self.step()?;
            while let Some(p) = self.dequeue_packet() {
                out.push(p);
            }
        }
        Ok(out)
    }

    /// Measured steady-state II (same protocol as `Pipeline::measure_ii`).
    pub fn measure_ii(&mut self, sample_packets: &[Vec<i32>]) -> Result<f64> {
        assert!(sample_packets.len() >= 4);
        let mut next = 0usize;
        let mut completions = Vec::new();
        let mut seen = 0usize;
        let budget = 1000 + sample_packets.len() as u64 * 200;
        let start = self.cycle;
        while completions.len() < sample_packets.len() {
            if self.cycle - start > budget {
                anyhow::bail!("II measurement did not converge");
            }
            if next < sample_packets.len() && self.enqueue_packet(&sample_packets[next]) {
                next += 1;
            }
            self.step()?;
            while self.packets_ready() > seen {
                seen += 1;
                completions.push(self.cycle);
            }
        }
        let gaps: Vec<f64> = completions.windows(2).map(|w| (w[1] - w[0]) as f64).collect();
        Ok(gaps.iter().sum::<f64>() / gaps.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dfg::eval;
    use crate::sched::{Program, Timing};
    use crate::util::prng::Rng;

    /// Correctness: the double-buffered pipeline matches the oracle on
    /// every benchmark.
    #[test]
    fn matches_oracle_on_all_benchmarks() {
        let mut rng = Rng::new(77);
        for name in bench_suite::all_names() {
            let g = bench_suite::load(name).unwrap();
            let p = Program::schedule(&g).unwrap();
            let mut pl = PipelineDb::new(&p, 1024).unwrap();
            let n_in = g.inputs().len();
            let packets: Vec<Vec<i32>> = (0..8)
                .map(|_| (0..n_in).map(|_| rng.range_i64(-999, 999) as i32).collect())
                .collect();
            let out = pl.run(&packets, 10_000).unwrap();
            for (pkt, got) in packets.iter().zip(&out) {
                assert_eq!(got, &eval(&g, pkt), "{name}");
            }
        }
    }

    /// The extension's claim: measured II equals the analytical
    /// `max(loads, execs)` model and beats the single-bank II on every
    /// benchmark.
    #[test]
    fn measured_ii_matches_db_model_and_beats_baseline() {
        for name in bench_suite::all_names() {
            let g = bench_suite::load(name).unwrap();
            let p = Program::schedule(&g).unwrap();
            let baseline_ii = Timing::of(&p).ii as f64;
            let mut pl = PipelineDb::new(&p, 4096).unwrap();
            let n_in = g.inputs().len();
            let packets: Vec<Vec<i32>> = (0..12).map(|k| vec![k as i32; n_in]).collect();
            let ii = pl.measure_ii(&packets).unwrap();
            assert!(
                (ii - pl.ii() as f64).abs() < 1e-9,
                "{name}: measured {ii} vs model {}",
                pl.ii()
            );
            assert!(ii < baseline_ii, "{name}: {ii} !< {baseline_ii}");
        }
    }
}
