//! Cycle-accurate DSP48E1 functional model.
//!
//! The FU's ALU is a DSP48E1 primitive with registered A/B/C inputs, an
//! M (multiplier) stage and a P (output) stage. The visible effect in
//! the paper's Table I is a 2-cycle issue→downstream-load offset (an
//! instruction issued by FU0 at cycle 6 is loaded by FU1 at cycle 8),
//! which we model as a 2-deep output delay line with the arithmetic
//! evaluated at issue.
//!
//! Semantics follow the configuration word ([`DspConfig`]): the C port
//! carries operand 1 (`rs1`), A:B carries operand 2 (`rs2`); ALUMODE
//! add/sub compute `Z ± X` with Z=C, X=A:B; the multiplier path squares
//! or multiplies A×B... in our FU the two RF read ports drive the
//! multiplier, so MUL computes `rs1 × rs2`. All arithmetic is wrapping
//! two's-complement int32.

use crate::isa::DspConfig;

/// Visible pipeline latency: issue at cycle t, downstream RF write at
/// t + LATENCY (Table I: 6 → 8).
pub const LATENCY: usize = 2;

/// One issued operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DspIssue {
    pub config: DspConfig,
    /// Operand read on RF port 1 (drives the C register).
    pub c: i32,
    /// Operand read on RF port 2 (drives A:B).
    pub ab: i32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BadIssue;

impl std::fmt::Display for BadIssue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("DSP48E1 issued an unclassifiable configuration")
    }
}

impl std::error::Error for BadIssue {}

/// The pipelined DSP block.
#[derive(Debug, Clone)]
pub struct Dsp48e1 {
    /// Delay line; `line[0]` emerges this cycle.
    line: [Option<i32>; LATENCY],
    /// Total operations issued (for utilization accounting).
    pub issued: u64,
}

impl Default for Dsp48e1 {
    fn default() -> Self {
        Self::new()
    }
}

impl Dsp48e1 {
    pub fn new() -> Self {
        Dsp48e1 {
            line: [None; LATENCY],
            issued: 0,
        }
    }

    /// Combinational result for an issue (the ALU proper).
    pub fn compute(issue: &DspIssue) -> Result<i32, BadIssue> {
        match issue.config.classify() {
            Some(Some(op)) => Ok(op.apply(issue.c, issue.ab)),
            Some(None) => Ok(issue.c), // bypass: route C to P
            None => Err(BadIssue),
        }
    }

    /// Advance one clock. `issue` is the operation entering the pipe
    /// this cycle (or `None` when the FU is loading/flushing); the
    /// return value is the P-register output leaving the pipe.
    pub fn step(&mut self, issue: Option<DspIssue>) -> Result<Option<i32>, BadIssue> {
        let out = self.line[0];
        for i in 0..LATENCY - 1 {
            self.line[i] = self.line[i + 1];
        }
        self.line[LATENCY - 1] = match issue {
            Some(ref iss) => {
                self.issued += 1;
                Some(Self::compute(iss)?)
            }
            None => None,
        };
        Ok(out)
    }

    /// Fast path used by the FU after pre-decoding: push an already
    /// computed value through the delay line (identical timing to
    /// [`Self::step`], minus the per-cycle classification).
    #[inline]
    pub fn step_value(&mut self, value: Option<i32>) -> Option<i32> {
        let out = self.line[0];
        for i in 0..LATENCY - 1 {
            self.line[i] = self.line[i + 1];
        }
        if value.is_some() {
            self.issued += 1;
        }
        self.line[LATENCY - 1] = value;
        out
    }

    /// True when no results remain in flight.
    pub fn drained(&self) -> bool {
        self.line.iter().all(Option::is_none)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::OpKind;

    fn issue(op: OpKind, c: i32, ab: i32) -> DspIssue {
        DspIssue {
            config: DspConfig::for_op(op),
            c,
            ab,
        }
    }

    #[test]
    fn latency_is_two_cycles() {
        let mut d = Dsp48e1::new();
        assert_eq!(d.step(Some(issue(OpKind::Add, 2, 3))).unwrap(), None);
        assert_eq!(d.step(None).unwrap(), None);
        assert_eq!(d.step(None).unwrap(), Some(5));
        assert!(d.drained());
    }

    #[test]
    fn back_to_back_issues_stream_out() {
        let mut d = Dsp48e1::new();
        let mut out = Vec::new();
        for i in 0..4 {
            out.push(d.step(Some(issue(OpKind::Mul, i, i))).unwrap());
        }
        out.push(d.step(None).unwrap());
        out.push(d.step(None).unwrap());
        assert_eq!(out, vec![None, None, Some(0), Some(1), Some(4), Some(9)]);
    }

    #[test]
    fn sub_orientation_is_rs1_minus_rs2() {
        // SUB (R0 R2) in Table I computes RF[0] - RF[2]: C - A:B.
        assert_eq!(Dsp48e1::compute(&issue(OpKind::Sub, 10, 3)).unwrap(), 7);
    }

    #[test]
    fn bypass_routes_c() {
        let iss = DspIssue {
            config: DspConfig::bypass(),
            c: 42,
            ab: -1,
        };
        assert_eq!(Dsp48e1::compute(&iss).unwrap(), 42);
    }

    #[test]
    fn wrapping_semantics() {
        assert_eq!(
            Dsp48e1::compute(&issue(OpKind::Add, i32::MAX, 1)).unwrap(),
            i32::MIN
        );
        assert_eq!(
            Dsp48e1::compute(&issue(OpKind::Mul, 1 << 20, 1 << 20)).unwrap(),
            0
        );
    }

    #[test]
    fn logic_ops() {
        assert_eq!(Dsp48e1::compute(&issue(OpKind::And, 0b1100, 0b1010)).unwrap(), 0b1000);
        assert_eq!(Dsp48e1::compute(&issue(OpKind::Or, 0b1100, 0b1010)).unwrap(), 0b1110);
        assert_eq!(Dsp48e1::compute(&issue(OpKind::Xor, 0b1100, 0b1010)).unwrap(), 0b0110);
    }

    #[test]
    fn bad_config_is_error() {
        let bad = DspIssue {
            config: DspConfig {
                opmode: 0x7F,
                alumode: 0xF,
                inmode: 0,
                carryinsel: 0,
                use_mult: false,
            },
            c: 0,
            ab: 0,
        };
        assert!(Dsp48e1::compute(&bad).is_err());
    }

    #[test]
    fn issue_counter_tracks_utilization() {
        let mut d = Dsp48e1::new();
        for _ in 0..5 {
            d.step(Some(issue(OpKind::Add, 1, 1))).unwrap();
        }
        d.step(None).unwrap();
        assert_eq!(d.issued, 5);
    }
}
