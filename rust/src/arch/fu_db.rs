//! Extension (paper §VI future work): a **double-buffered register
//! file** FU that overlaps data entry with execution to reduce the II.
//!
//! The paper closes with "we are currently examining architectural
//! modifications to reduce the II". The single-bank FU serializes
//! `loads + execs + flush` per iteration because new stream data would
//! overwrite registers still being read. With a ping-pong RF (two
//! 32-entry banks, i.e. 16 RAM32M primitives instead of 8) the FU
//! loads packet *k+1* into the idle bank while executing packet *k*
//! from the active bank, and the DSP drain overlaps the next load
//! burst. The steady-state initiation interval becomes
//!
//! ```text
//!     II_db = max_s( max(loads_s, execs_s) )      (no +2 flush)
//! ```
//!
//! Costs: +32 LUTs of LUTRAM per FU, one bank-select FF and a second
//! write port mux (see `resources::estimate::fu_double_buffered`).
//! `bench_ablation` quantifies the II / throughput / area trade-off.

use super::dsp48e1::{Dsp48e1, DspIssue};
use crate::isa::FuInstr;
use anyhow::{bail, Result};

/// Double-buffered FU (cycle-accurate).
#[derive(Debug, Clone)]
pub struct FuDb {
    im: Vec<FuInstr>,
    /// Two RF banks; `write_bank` receives stream data, the other is
    /// read by execution.
    banks: [[i32; 32]; 2],
    write_bank: usize,
    n_loads: usize,
    /// Words loaded into the write bank so far.
    dc: usize,
    /// Exec in progress: Some(pc) when issuing from the read bank.
    pc: Option<usize>,
    /// A full bank is waiting to be executed (loaded while exec busy).
    pending_swap: bool,
    dsp: Dsp48e1,
    pub iterations: u64,
    pub cycles: u64,
}

impl FuDb {
    pub fn new(im: Vec<FuInstr>, consts: &[i32], n_loads: usize) -> Result<FuDb> {
        if im.is_empty() || im.len() > 32 {
            bail!("IM size {} invalid", im.len());
        }
        if consts.len() + n_loads > 32 {
            bail!("RF overflow");
        }
        let mut bank = [0i32; 32];
        for (i, &c) in consts.iter().enumerate() {
            bank[31 - i] = c;
        }
        Ok(FuDb {
            im,
            banks: [bank, bank], // consts preloaded into both banks
            write_bank: 0,
            n_loads,
            dc: 0,
            pc: None,
            pending_swap: false,
            dsp: Dsp48e1::new(),
            iterations: 0,
            cycles: 0,
        })
    }

    /// Can the FU absorb a stream word this cycle? (`pending_swap`
    /// implies the write bank is full; it drains on the next swap.)
    pub fn can_accept(&self) -> bool {
        self.dc < self.n_loads
    }

    pub fn step(&mut self, input: Option<i32>) -> Result<Option<i32>> {
        self.cycles += 1;
        // Start executing a banked packet if idle.
        if self.pc.is_none() && self.pending_swap {
            // Swap banks: the filled write bank becomes the read bank.
            self.write_bank ^= 1;
            self.pending_swap = false;
            self.dc = 0;
            self.pc = Some(0);
        }
        // Data entry into the write bank.
        if let Some(v) = input {
            if self.dc >= self.n_loads {
                bail!("protocol violation: write bank full (pending swap)");
            }
            self.banks[self.write_bank][self.dc] = v;
            self.dc += 1;
            if self.dc == self.n_loads {
                self.pending_swap = true;
            }
        }
        // Immediately claim the bank if we became ready this cycle and
        // the executor is idle (trigger is combinational on dc).
        if self.pc.is_none() && self.pending_swap {
            self.write_bank ^= 1;
            self.pending_swap = false;
            self.dc = 0;
            self.pc = Some(0);
        }
        // Issue from the read bank.
        let issue = if let Some(pc) = self.pc {
            let read_bank = self.write_bank ^ 1;
            let ins = &self.im[pc];
            let (rs1, rs2) = ins.reads();
            let c = self.banks[read_bank][rs1 as usize];
            let ab = self.banks[read_bank][rs2.unwrap_or(rs1) as usize];
            let next = pc + 1;
            if next == self.im.len() {
                self.pc = None;
                self.iterations += 1;
            } else {
                self.pc = Some(next);
            }
            Some(DspIssue {
                config: ins.dsp_config(),
                c,
                ab,
            })
        } else {
            None
        };
        self.dsp.step(issue).map_err(|e| anyhow::anyhow!("{e}"))
    }
}

/// Analytical II for the double-buffered pipeline.
pub fn ii_double_buffered(p: &crate::sched::Program) -> u32 {
    p.stages
        .iter()
        .map(|s| s.n_loads().max(s.n_execs()) as u32)
        .max()
        .unwrap_or(1)
        .max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dfg::OpKind;
    use crate::sched::Program;

    fn simple_fu() -> FuDb {
        FuDb::new(
            vec![
                FuInstr::Arith {
                    op: OpKind::Add,
                    rs1: 0,
                    rs2: 1,
                },
                FuInstr::Bypass { rs: 0 },
            ],
            &[],
            2,
        )
        .unwrap()
    }

    #[test]
    fn overlaps_loading_with_execution() {
        let mut fu = simple_fu();
        let mut out = Vec::new();
        // Stream two packets back-to-back (period 2 = max(loads, execs)).
        let feed = [Some(1), Some(2), Some(10), Some(20), None, None, None, None];
        for w in feed {
            out.push(fu.step(w).unwrap());
        }
        let vals: Vec<i32> = out.into_iter().flatten().collect();
        // Packet 1: ADD=3, BYP=1; packet 2: ADD=30, BYP=10.
        assert_eq!(vals, vec![3, 1, 30, 10]);
        assert_eq!(fu.iterations, 2);
    }

    #[test]
    fn rejects_overrun_of_full_bank() {
        let mut fu = FuDb::new(vec![FuInstr::Bypass { rs: 0 }; 4], &[], 1).unwrap();
        // Packet A loads (starts exec), packet B loads into idle bank
        // and must wait (4 execs > 1 load) — a third word overruns.
        fu.step(Some(1)).unwrap();
        fu.step(Some(2)).unwrap(); // fills bank B, pending swap
        assert!(fu.step(Some(3)).is_err());
    }

    #[test]
    fn analytical_ii_drops_vs_single_bank() {
        for (name, paper_ii) in [("gradient", 11u32), ("chebyshev", 6), ("qspline", 18)] {
            let g = bench_suite::load(name).unwrap();
            let p = Program::schedule(&g).unwrap();
            let ii_db = ii_double_buffered(&p);
            assert!(
                ii_db < paper_ii,
                "{name}: db II {ii_db} !< single-bank {paper_ii}"
            );
        }
        // gradient: max over stages of max(loads, execs) = max(5,4)=5.
        let g = bench_suite::load("gradient").unwrap();
        let p = Program::schedule(&g).unwrap();
        assert_eq!(ii_double_buffered(&p), 5);
    }

    #[test]
    fn consts_present_in_both_banks() {
        let mut fu = FuDb::new(
            vec![FuInstr::Arith {
                op: OpKind::Mul,
                rs1: 0,
                rs2: 31,
            }],
            &[7],
            1,
        )
        .unwrap();
        let mut vals = Vec::new();
        for w in [Some(3), Some(5), None, None, None] {
            if let Some(v) = fu.step(w).unwrap() {
                vals.push(v);
            }
        }
        assert_eq!(vals, vec![21, 35]); // both packets used the const
    }
}
