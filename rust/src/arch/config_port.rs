//! Daisy-chained configuration port (paper §III.A).
//!
//! Context words are clocked one per cycle from the external context
//! memory into the head FU's instruction port; each FU latches words
//! whose 8-bit tag matches its index and forwards the rest. The model
//! verifies the timing claim (one word per cycle ⇒ `words × 1/f`
//! switch time) and reconstructs the per-FU contents for the pipeline.

use crate::isa::{ContextImage, ContextWord, FuContext, FuInstr};
use anyhow::{bail, Result};

/// Result of clocking a context stream into a pipeline of `n_fus` FUs.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadedContext {
    pub fus: Vec<FuContext>,
    /// Cycles taken (== number of context words).
    pub cycles: u64,
}

/// Simulate the word-per-cycle daisy-chain load.
pub fn load_context(words: &[ContextWord], n_fus: usize) -> Result<LoadedContext> {
    let mut fus = vec![FuContext::default(); n_fus];
    let mut cycles = 0u64;
    for w in words {
        cycles += 1; // one word enters the chain per cycle
        let fu = w.fu_index() as usize;
        if fu >= n_fus {
            bail!("context word tagged for FU {fu} but pipeline has {n_fus}");
        }
        match w.kind() {
            0 => {
                let ins = FuInstr::decode(w.payload)?;
                if fus[fu].instrs.len() >= 32 {
                    bail!("FU {fu}: IM overflow during context load");
                }
                fus[fu].instrs.push(ins);
            }
            1 => {
                if fus[fu].consts.len() >= 32 {
                    bail!("FU {fu}: RF const overflow during context load");
                }
                fus[fu].consts.push(w.payload as i32);
            }
            k => bail!("context word with unknown kind {k}"),
        }
    }
    Ok(LoadedContext { fus, cycles })
}

/// Clock a full image through the chain and check it reproduces the
/// source image (the round-trip the hardware performs).
pub fn load_image(img: &ContextImage) -> Result<LoadedContext> {
    let words = img.words().map_err(|e| anyhow::anyhow!("{e}"))?;
    let loaded = load_context(&words, img.n_fus())?;
    for (i, (got, want)) in loaded.fus.iter().zip(&img.fus).enumerate() {
        if got != want {
            bail!("FU {i}: loaded context differs from image");
        }
    }
    Ok(loaded)
}

/// Context-switch time in microseconds at `freq_mhz`.
pub fn switch_time_us(loaded: &LoadedContext, freq_mhz: f64) -> f64 {
    loaded.cycles as f64 / freq_mhz
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::sched::Program;

    #[test]
    fn round_trips_every_benchmark_context() {
        for name in bench_suite::all_names() {
            let g = bench_suite::load(name).unwrap();
            let p = Program::schedule(&g).unwrap();
            let img = p.context_image().unwrap();
            let loaded = load_image(&img).unwrap();
            assert_eq!(loaded.cycles as usize, img.load_cycles().unwrap(), "{name}");
        }
    }

    #[test]
    fn chebyshev_switch_time() {
        // 13 instruction words + 3 const words = 16 cycles at 300 MHz.
        let g = bench_suite::load("chebyshev").unwrap();
        let p = Program::schedule(&g).unwrap();
        let img = p.context_image().unwrap();
        let loaded = load_image(&img).unwrap();
        let t = switch_time_us(&loaded, 300.0);
        assert!(t < 0.1, "t = {t}");
    }

    #[test]
    fn rejects_misrouted_words() {
        let g = bench_suite::load("gradient").unwrap();
        let p = Program::schedule(&g).unwrap();
        let img = p.context_image().unwrap();
        let words = img.words().unwrap();
        // Pipeline claims fewer FUs than the stream addresses.
        assert!(load_context(&words, 2).is_err());
    }
}
