//! Distributed-RAM FIFO model (the streaming data interface of Fig. 2).
//!
//! The hardware uses LUTRAM-based FIFOs at the pipeline input and
//! output. The model tracks occupancy against a configurable capacity
//! (the paper's DRAM FIFOs are shallow) and high-water statistics used
//! by the resource estimator and the coordinator's backpressure tests.

use std::collections::VecDeque;

#[derive(Debug, Clone)]
pub struct Fifo {
    q: VecDeque<i32>,
    capacity: usize,
    /// Statistics.
    pub pushed: u64,
    pub popped: u64,
    pub high_water: usize,
    pub overflow_attempts: u64,
}

impl Fifo {
    pub fn new(capacity: usize) -> Fifo {
        assert!(capacity > 0);
        Fifo {
            q: VecDeque::with_capacity(capacity),
            capacity,
            pushed: 0,
            popped: 0,
            high_water: 0,
            overflow_attempts: 0,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.q.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.q.len() >= self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Push one word; returns false (and counts the attempt) when full.
    #[inline]
    pub fn push(&mut self, v: i32) -> bool {
        if self.is_full() {
            self.overflow_attempts += 1;
            return false;
        }
        self.q.push_back(v);
        self.pushed += 1;
        self.high_water = self.high_water.max(self.q.len());
        true
    }

    #[inline]
    pub fn pop(&mut self) -> Option<i32> {
        let v = self.q.pop_front();
        if v.is_some() {
            self.popped += 1;
        }
        v
    }

    pub fn peek(&self) -> Option<i32> {
        self.q.front().copied()
    }

    /// Drain everything (used by tests and the output collector).
    pub fn drain_all(&mut self) -> Vec<i32> {
        let out: Vec<i32> = self.q.drain(..).collect();
        self.popped += out.len() as u64;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut f = Fifo::new(4);
        for v in [1, 2, 3] {
            assert!(f.push(v));
        }
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.peek(), Some(3));
        assert_eq!(f.pop(), Some(3));
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn capacity_enforced() {
        let mut f = Fifo::new(2);
        assert!(f.push(1));
        assert!(f.push(2));
        assert!(!f.push(3));
        assert_eq!(f.overflow_attempts, 1);
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn high_water_tracks_max() {
        let mut f = Fifo::new(8);
        for v in 0..5 {
            f.push(v);
        }
        f.pop();
        f.pop();
        f.push(9);
        assert_eq!(f.high_water, 5);
    }

    #[test]
    fn stats_count() {
        let mut f = Fifo::new(8);
        f.push(1);
        f.push(2);
        f.pop();
        assert_eq!(f.pushed, 2);
        assert_eq!(f.popped, 1);
        assert_eq!(f.drain_all(), vec![2]);
        assert_eq!(f.popped, 2);
    }
}
