//! The multi-pipeline overlay system (paper Fig. 4): replicated
//! processing pipelines on the Zynq fabric, a per-pipeline data BRAM,
//! a shared configuration BRAM, and DMA between external memory and
//! the BRAMs, managed by the host (ARM) side.
//!
//! Replication recovers throughput lost to the II: `R` pipelines give
//! an effective II of `II / R` (paper §V: "we can replicate the
//! processing pipeline ... to effectively achieve a lower II").

use super::pipeline::Pipeline;
use crate::sched::{Program, Timing};
use anyhow::Result;

/// DMA/bus timing model for the memory subsystem (AXI HP port).
#[derive(Debug, Clone, Copy)]
pub struct DmaModel {
    /// Bus width in bytes per beat (64-bit AXI HP).
    pub bytes_per_beat: u32,
    /// Bus clock in MHz.
    pub bus_mhz: f64,
    /// Fixed setup latency per transfer (descriptor + handshake), µs.
    pub setup_us: f64,
}

impl Default for DmaModel {
    fn default() -> Self {
        DmaModel {
            bytes_per_beat: 8,
            bus_mhz: 150.0,
            setup_us: 0.5,
        }
    }
}

impl DmaModel {
    /// Transfer time for `bytes`, in microseconds.
    pub fn transfer_us(&self, bytes: usize) -> f64 {
        let beats = bytes.div_ceil(self.bytes_per_beat as usize) as f64;
        self.setup_us + beats / self.bus_mhz
    }
}

/// A replicated-pipeline overlay executing one kernel context.
#[derive(Debug)]
pub struct Overlay {
    pub kernel: String,
    pipelines: Vec<Pipeline>,
    /// Round-robin dispatch cursor.
    next: usize,
    pub dma: DmaModel,
}

impl Overlay {
    pub fn new(p: &Program, replicas: usize, fifo_capacity: usize) -> Result<Overlay> {
        assert!(replicas >= 1);
        let pipelines = (0..replicas)
            .map(|_| Pipeline::new(p, fifo_capacity))
            .collect::<Result<Vec<_>>>()?;
        Ok(Overlay {
            kernel: p.kernel.clone(),
            pipelines,
            next: 0,
            dma: DmaModel::default(),
        })
    }

    pub fn replicas(&self) -> usize {
        self.pipelines.len()
    }

    pub fn total_fus(&self) -> usize {
        self.pipelines.iter().map(|p| p.n_fus()).sum()
    }

    /// Effective initiation interval with replication.
    pub fn effective_ii(p: &Program, replicas: usize) -> f64 {
        Timing::of(p).ii as f64 / replicas as f64
    }

    /// Run a batch of packets round-robin across replicas; returns
    /// outputs in input order.
    pub fn run(&mut self, packets: &[Vec<i32>], max_cycles: u64) -> Result<Vec<Vec<i32>>> {
        // Assign packets to replicas round-robin, preserving order.
        let r = self.replicas();
        let mut per: Vec<Vec<Vec<i32>>> = vec![Vec::new(); r];
        for (i, pkt) in packets.iter().enumerate() {
            per[(self.next + i) % r].push(pkt.clone());
        }
        let assignments: Vec<usize> = (0..packets.len()).map(|i| (self.next + i) % r).collect();
        self.next = (self.next + packets.len()) % r;
        // Run each replica (sequentially here; the coordinator runs
        // replicas on worker threads).
        let mut per_out: Vec<std::collections::VecDeque<Vec<i32>>> = Vec::with_capacity(r);
        for (rep, pkts) in self.pipelines.iter_mut().zip(per) {
            let outs = rep.run(&pkts, max_cycles)?;
            per_out.push(outs.into());
        }
        // Reassemble in input order.
        let mut out = Vec::with_capacity(packets.len());
        for rep in assignments {
            out.push(per_out[rep].pop_front().expect("replica under-produced"));
        }
        Ok(out)
    }

    /// Total simulated cycles for a batch, if run in lock-step
    /// (max across replicas — they run concurrently in hardware).
    pub fn batch_cycles(&self) -> u64 {
        self.pipelines.iter().map(|p| p.cycle).max().unwrap_or(0)
    }

    /// Model: time to stage `n_packets` of `n_inputs` words each into
    /// the per-pipeline BRAMs over DMA, µs.
    pub fn staging_time_us(&self, n_packets: usize, n_inputs: usize) -> f64 {
        self.dma.transfer_us(n_packets * n_inputs * 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dfg::eval;
    use crate::sched::Program;

    #[test]
    fn replication_preserves_results_and_order() {
        let g = bench_suite::load("mibench").unwrap();
        let p = Program::schedule(&g).unwrap();
        let mut ov = Overlay::new(&p, 3, 256).unwrap();
        let packets: Vec<Vec<i32>> = (0..10).map(|k| vec![k, k + 1, k + 2]).collect();
        let out = ov.run(&packets, 10_000).unwrap();
        for (pkt, got) in packets.iter().zip(&out) {
            assert_eq!(got, &eval(&g, pkt));
        }
    }

    #[test]
    fn effective_ii_scales_with_replicas() {
        let g = bench_suite::load("chebyshev").unwrap();
        let p = Program::schedule(&g).unwrap();
        assert_eq!(Overlay::effective_ii(&p, 1), 6.0);
        assert_eq!(Overlay::effective_ii(&p, 2), 3.0);
        assert_eq!(Overlay::effective_ii(&p, 6), 1.0);
    }

    #[test]
    fn total_fus_counts_replicas() {
        let g = bench_suite::load("gradient").unwrap();
        let p = Program::schedule(&g).unwrap();
        let ov = Overlay::new(&p, 2, 64).unwrap();
        assert_eq!(ov.total_fus(), 8);
    }

    #[test]
    fn dma_model_monotonic() {
        let dma = DmaModel::default();
        let t1 = dma.transfer_us(64);
        let t2 = dma.transfer_us(4096);
        assert!(t2 > t1);
        assert!(t1 >= dma.setup_us);
    }
}
