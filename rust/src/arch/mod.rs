//! Cycle-accurate overlay microarchitecture: the DSP48E1 ALU, the
//! time-multiplexed FU (Fig. 3), DRAM FIFOs, the linear processing
//! pipeline (Fig. 2), the daisy-chained configuration port and the
//! replicated multi-pipeline overlay (Fig. 4).

pub mod config_port;
pub mod dsp48e1;
pub mod fifo;
pub mod fu;
pub mod fu_db;
pub mod overlay;
pub mod pipeline;
pub mod pipeline_db;

pub use dsp48e1::{Dsp48e1, DspIssue};
pub use fifo::Fifo;
pub use fu::{Fu, FuState};
pub use fu_db::FuDb;
pub use overlay::{DmaModel, Overlay};
pub use pipeline::Pipeline;
pub use pipeline_db::PipelineDb;
