//! The programmable processing pipeline (paper Fig. 2): input FIFO →
//! cascade of time-multiplexed FUs → output FIFO, cycle-accurate.
//!
//! Data words issued by FU *s* at cycle *t* are written into FU *s+1*'s
//! RF at *t + 2* (the DSP's internal pipeline); the model achieves this
//! by stepping FUs in order and handing each FU's delayed DSP output to
//! its successor within the same simulated cycle.

use super::fifo::Fifo;
use super::fu::Fu;
use crate::sched::{Program, Timing};
use anyhow::Result;

/// A configured pipeline executing one kernel context.
#[derive(Debug, Clone)]
pub struct Pipeline {
    pub kernel: String,
    fus: Vec<Fu>,
    pub input_fifo: Fifo,
    pub output_fifo: Fifo,
    /// Words consumed per input packet (primary inputs).
    n_inputs: usize,
    /// Words produced per packet by the final FU.
    n_out_words: usize,
    /// Output name -> position within the final FU's emissions.
    output_order: Vec<(String, usize)>,
    /// Initiation interval: packet admission is paced at this period.
    /// When stage 1 is the bottleneck (gradient) the FU's own
    /// back-pressure produces the same pacing; for kernels whose
    /// bottleneck sits mid-pipeline the admission gate keeps upstream
    /// stages from overrunning the bottleneck FU (the paper's control
    /// generator achieves this with the valid handshake).
    ii: u64,
    /// First cycle at which the next packet may begin streaming.
    next_packet_cycle: u64,
    /// Words of the current packet already streamed in (wraps at
    /// `n_inputs`; avoids a modulo in the per-cycle hot path).
    packet_word: usize,
    pub cycle: u64,
    /// Cycles in which the input FIFO wanted to send but was blocked.
    pub backpressure_cycles: u64,
}

impl Pipeline {
    /// Instantiate from a scheduled program (context load is modelled
    /// separately by [`super::config_port`]).
    pub fn new(p: &Program, fifo_capacity: usize) -> Result<Pipeline> {
        let mut fus = Vec::with_capacity(p.stages.len());
        for st in p.stages.iter() {
            let consts: Vec<i32> = st.consts.iter().map(|&(_, v)| v).collect();
            fus.push(Fu::new(st.instrs.clone(), &consts, st.n_loads())?);
        }
        let n_inputs = p.stages[0].n_loads();
        let last = p.stages.last().unwrap();
        Ok(Pipeline {
            kernel: p.kernel.clone(),
            fus,
            input_fifo: Fifo::new(fifo_capacity),
            output_fifo: Fifo::new(fifo_capacity),
            n_inputs,
            n_out_words: last.n_execs(),
            output_order: p.output_order.clone(),
            ii: Timing::of(p).ii as u64,
            next_packet_cycle: 1,
            packet_word: 0,
            cycle: 0,
            backpressure_cycles: 0,
        })
    }

    pub fn n_fus(&self) -> usize {
        self.fus.len()
    }

    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }

    /// Queue one input packet (values in input declaration order).
    /// Returns false if the FIFO lacks space for the whole packet.
    pub fn enqueue_packet(&mut self, packet: &[i32]) -> bool {
        assert_eq!(packet.len(), self.n_inputs, "packet arity");
        if self.input_fifo.capacity() - self.input_fifo.len() < packet.len() {
            return false;
        }
        for &v in packet {
            let ok = self.input_fifo.push(v);
            debug_assert!(ok);
        }
        true
    }

    /// Advance one clock cycle.
    #[inline]
    pub fn step(&mut self) -> Result<()> {
        self.cycle += 1;
        // Input FIFO -> FU0 (respecting back-pressure + II pacing).
        let at_boundary = self.packet_word == 0;
        let gate_open = !at_boundary || self.cycle >= self.next_packet_cycle;
        let mut carry: Option<i32> = if !self.fus[0].backpressure() && gate_open {
            let w = self.input_fifo.pop();
            if w.is_some() {
                if at_boundary {
                    self.next_packet_cycle = self.cycle + self.ii;
                }
                self.packet_word += 1;
                if self.packet_word == self.n_inputs {
                    self.packet_word = 0;
                }
            }
            w
        } else {
            if !self.input_fifo.is_empty() {
                self.backpressure_cycles += 1;
            }
            None
        };
        // FU cascade: each FU's (delayed) output feeds the next.
        for fu in &mut self.fus {
            carry = fu.step(carry)?;
        }
        // Final FU -> output FIFO.
        if let Some(v) = carry {
            if !self.output_fifo.push(v) {
                anyhow::bail!("output FIFO overflow at cycle {}", self.cycle);
            }
        }
        Ok(())
    }

    /// Complete output packets currently in the output FIFO.
    pub fn packets_ready(&self) -> usize {
        self.output_fifo.len() / self.n_out_words
    }

    /// At least one complete packet is ready. This is the per-cycle
    /// poll in [`Self::run`]: a comparison instead of
    /// `packets_ready()`'s integer division (which profiled as pure
    /// overhead when attempted every simulated cycle).
    #[inline]
    pub fn has_ready_packet(&self) -> bool {
        self.output_fifo.len() >= self.n_out_words
    }

    /// Pop one complete output packet and project the named outputs in
    /// declaration order.
    pub fn dequeue_packet(&mut self) -> Option<Vec<i32>> {
        if !self.has_ready_packet() {
            return None;
        }
        let words: Vec<i32> = (0..self.n_out_words)
            .map(|_| self.output_fifo.pop().unwrap())
            .collect();
        Some(
            self.output_order
                .iter()
                .map(|&(_, pos)| words[pos])
                .collect(),
        )
    }

    /// Run until `n_packets` results are collected (or a cycle budget
    /// expires). Inputs are taken from `packets` as FIFO space allows.
    pub fn run(&mut self, packets: &[Vec<i32>], max_cycles: u64) -> Result<Vec<Vec<i32>>> {
        let mut next = 0usize;
        let mut out = Vec::with_capacity(packets.len());
        let start = self.cycle;
        while out.len() < packets.len() {
            if self.cycle - start > max_cycles {
                anyhow::bail!(
                    "cycle budget exceeded: {} packets out of {} after {max_cycles} cycles",
                    out.len(),
                    packets.len()
                );
            }
            if next < packets.len() && self.enqueue_packet(&packets[next]) {
                next += 1;
            }
            self.step()?;
            // Cheap readiness poll before the popping path (this runs
            // once per simulated cycle, almost always empty-handed).
            while self.has_ready_packet() {
                out.push(self.dequeue_packet().expect("packet ready"));
            }
        }
        Ok(out)
    }

    /// Measured steady-state initiation interval: feed `n` packets and
    /// report the cycle distance between consecutive first-output words.
    pub fn measure_ii(&mut self, sample_packets: &[Vec<i32>]) -> Result<f64> {
        assert!(sample_packets.len() >= 4, "need >= 4 packets for a stable II");
        let mut next = 0usize;
        let mut completion_cycles = Vec::new();
        let mut seen = 0usize;
        let budget = 1000 + sample_packets.len() as u64 * 200;
        let start = self.cycle;
        while completion_cycles.len() < sample_packets.len() {
            if self.cycle - start > budget {
                anyhow::bail!("II measurement did not converge");
            }
            if next < sample_packets.len() && self.enqueue_packet(&sample_packets[next]) {
                next += 1;
            }
            self.step()?;
            while self.packets_ready() > seen {
                seen += 1;
                completion_cycles.push(self.cycle);
            }
        }
        // Skip the first sample (pipeline fill), average the gaps.
        let gaps: Vec<f64> = completion_cycles
            .windows(2)
            .map(|w| (w[1] - w[0]) as f64)
            .collect();
        Ok(gaps.iter().sum::<f64>() / gaps.len() as f64)
    }

    /// Per-FU DSP utilization snapshot.
    pub fn dsp_utilizations(&self) -> Vec<f64> {
        self.fus.iter().map(|f| f.dsp_utilization()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dfg::eval;
    use crate::sched::{Program, Timing};
    use crate::util::prng::Rng;

    fn pipeline_for(name: &str) -> (crate::dfg::Dfg, Program, Pipeline) {
        let g = bench_suite::load(name).unwrap();
        let p = Program::schedule(&g).unwrap();
        let pl = Pipeline::new(&p, 256).unwrap();
        (g, p, pl)
    }

    #[test]
    fn gradient_single_packet_matches_eval() {
        let (g, _, mut pl) = pipeline_for("gradient");
        let packet = vec![3, 5, 2, 7, 1];
        let out = pl.run(&[packet.clone()], 200).unwrap();
        assert_eq!(out, vec![eval(&g, &packet)]);
    }

    #[test]
    fn gradient_first_output_cycle_matches_timing_model() {
        let (_, p, mut pl) = pipeline_for("gradient");
        let t = Timing::of(&p);
        pl.enqueue_packet(&[1, 2, 3, 4, 5]);
        let mut first = None;
        for _ in 0..100 {
            pl.step().unwrap();
            if first.is_none() && !pl.output_fifo.is_empty() {
                first = Some(pl.cycle);
                break;
            }
        }
        assert_eq!(first, Some(t.first_output));
    }

    /// The cycle-accurate simulator must agree with the functional
    /// oracle on every benchmark for randomized inputs.
    #[test]
    fn all_benchmarks_match_functional_oracle() {
        let mut rng = Rng::new(2016);
        for name in bench_suite::all_names() {
            let (g, _, mut pl) = pipeline_for(name);
            let n_in = g.inputs().len();
            let packets: Vec<Vec<i32>> = (0..8)
                .map(|_| (0..n_in).map(|_| rng.range_i64(-1000, 1000) as i32).collect())
                .collect();
            let out = pl.run(&packets, 5000).unwrap();
            for (pkt, got) in packets.iter().zip(&out) {
                assert_eq!(got, &eval(&g, pkt), "{name} diverged on {pkt:?}");
            }
        }
    }

    /// Measured steady-state II must equal the analytical model (and
    /// hence the paper's Table II) for every benchmark.
    #[test]
    fn measured_ii_matches_model() {
        for name in bench_suite::all_names() {
            let (g, p, mut pl) = pipeline_for(name);
            let t = Timing::of(&p);
            let n_in = g.inputs().len();
            let packets: Vec<Vec<i32>> = (0..10).map(|k| vec![k as i32; n_in]).collect();
            let ii = pl.measure_ii(&packets).unwrap();
            assert!(
                (ii - t.ii as f64).abs() < 1e-9,
                "{name}: measured II {ii} vs model {}",
                t.ii
            );
        }
    }

    #[test]
    fn backpressure_engages_when_fifo_prefilled() {
        let (_, _, mut pl) = pipeline_for("gradient");
        for k in 0..4 {
            assert!(pl.enqueue_packet(&[k, k, k, k, k]));
        }
        for _ in 0..60 {
            pl.step().unwrap();
        }
        assert!(pl.backpressure_cycles > 0);
    }

    #[test]
    fn extreme_values_survive_the_pipeline() {
        let (g, _, mut pl) = pipeline_for("poly6");
        let pkt = vec![i32::MAX, i32::MIN, -1];
        let out = pl.run(&[pkt.clone()], 500).unwrap();
        assert_eq!(out[0], eval(&g, &pkt));
    }

    #[test]
    fn packet_arity_is_checked() {
        let (_, _, mut pl) = pipeline_for("gradient");
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pl.enqueue_packet(&[1, 2]);
        }));
        assert!(r.is_err());
    }
}
