//! Cycle-accurate time-multiplexed functional unit (paper Fig. 3).
//!
//! Components modelled: the 32-entry instruction memory (IM) with its
//! instruction counter (IC), the 32-entry register file (RF) with the
//! sequential data counter (DC), the program counter (PC), the control
//! generator FSM and the DSP48E1 ALU.
//!
//! Control flow per iteration (paper §III.A / Table I):
//!
//! 1. **Loading** — streamed words are written to `RF[DC++]`. When DC
//!    reaches the expected load count the FU triggers.
//! 2. **Executing** — PC issues one instruction per cycle into the DSP;
//!    results stream out `LATENCY` cycles later toward the next FU.
//! 3. **Flushing** — 2 cycles drain the DSP pipe, then DC/PC reset and
//!    the FU accepts the next data set.
//!
//! Stage-1 FUs assert back-pressure to the input FIFO from the trigger
//! cycle until the flush completes (Table I cycles 6–11).
//!
//! Deviation noted in DESIGN.md: the paper triggers on the `valid`
//! falling edge; we give the FU its expected load count (known at
//! schedule time) which reproduces Table I exactly and stays robust to
//! FIFO underruns.

use super::dsp48e1::Dsp48e1;
use crate::dfg::OpKind;
use crate::isa::FuInstr;
use anyhow::{bail, Result};

/// Pre-decoded instruction: the DSP configuration classified once at
/// context-load time instead of every issue cycle (perf: the per-cycle
/// encode→classify round trip dominated the simulator's inner loop —
/// see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy)]
struct DecodedInstr {
    /// `Some(op)` = arithmetic, `None` = bypass.
    op: Option<OpKind>,
    rs1: u8,
    rs2: u8,
}

impl DecodedInstr {
    fn of(ins: &FuInstr) -> DecodedInstr {
        match *ins {
            FuInstr::Arith { op, rs1, rs2 } => DecodedInstr {
                op: Some(op),
                rs1,
                rs2,
            },
            FuInstr::Bypass { rs } => DecodedInstr {
                op: None,
                rs1: rs,
                rs2: rs,
            },
        }
    }

    #[inline]
    fn apply(&self, c: i32, ab: i32) -> i32 {
        match self.op {
            Some(op) => op.apply(c, ab),
            None => c,
        }
    }
}

/// Control generator state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FuState {
    Loading,
    Executing,
    Flushing,
}

/// The functional unit.
#[derive(Debug, Clone)]
pub struct Fu {
    /// Instruction memory (≤ 32 entries, RAM32M in hardware).
    im: Vec<FuInstr>,
    /// Pre-decoded mirror of `im` (see [`DecodedInstr`]).
    decoded: Vec<DecodedInstr>,
    /// Register file (8 × RAM32M in hardware).
    rf: [i32; 32],
    /// Constants preloaded at context-load time (slot 31 downward).
    n_consts: usize,
    /// Expected streamed loads per iteration.
    n_loads: usize,
    dc: usize,
    pc: usize,
    state: FuState,
    flush_left: u8,
    dsp: Dsp48e1,
    /// Statistics.
    pub cycles: u64,
    pub idle_cycles: u64,
    pub iterations: u64,
}

impl Fu {
    /// Build an FU from its stage program (context already "loaded").
    pub fn new(im: Vec<FuInstr>, consts: &[i32], n_loads: usize) -> Result<Fu> {
        if im.len() > 32 {
            bail!("IM overflow: {} instructions", im.len());
        }
        if im.is_empty() {
            bail!("FU with empty instruction memory");
        }
        if consts.len() + n_loads > 32 {
            bail!("RF overflow: {} consts + {n_loads} loads", consts.len());
        }
        let mut rf = [0i32; 32];
        for (i, &c) in consts.iter().enumerate() {
            rf[31 - i] = c;
        }
        let decoded = im.iter().map(DecodedInstr::of).collect();
        Ok(Fu {
            im,
            decoded,
            rf,
            n_consts: consts.len(),
            n_loads,
            dc: 0,
            pc: 0,
            state: FuState::Loading,
            flush_left: 0,
            dsp: Dsp48e1::new(),
            cycles: 0,
            idle_cycles: 0,
            iterations: 0,
        })
    }

    pub fn state(&self) -> FuState {
        self.state
    }

    /// Back-pressure: the FU cannot accept stream data this cycle.
    pub fn backpressure(&self) -> bool {
        self.state != FuState::Loading || self.dc >= self.n_loads
    }

    /// Advance one clock cycle. `input` is the word arriving from the
    /// previous FU / input FIFO (must only be `Some` when
    /// `!backpressure()` was observed this cycle). Returns the word
    /// emitted toward the next FU / output FIFO, if any.
    #[inline]
    pub fn step(&mut self, input: Option<i32>) -> Result<Option<i32>> {
        self.cycles += 1;
        // 1. Trigger: all loads arrived by the END of the previous
        //    cycle -> execution starts THIS cycle (Table I: last load at
        //    cycle 5, first instruction at cycle 6).
        if self.state == FuState::Loading && self.dc >= self.n_loads {
            self.state = FuState::Executing;
            self.pc = 0;
        }
        // 2. Data entry.
        if let Some(v) = input {
            if self.state != FuState::Loading || self.dc >= self.n_loads {
                bail!(
                    "protocol violation: data arrived while FU busy (state {:?}, dc {})",
                    self.state,
                    self.dc
                );
            }
            self.rf[self.dc] = v;
            self.dc += 1;
        }
        // 3. Issue (pre-decoded: the classify step ran at context load).
        let issue = if self.state == FuState::Executing {
            let ins = self.decoded[self.pc];
            // RF addresses are 5 bits by ISA construction (RAM32M);
            // the mask states that to the compiler, eliding the
            // per-read bounds checks in the inner loop. The assert
            // keeps an encoder bug a loud failure in debug builds
            // rather than a silent wrapped read.
            debug_assert!(ins.rs1 < 32 && ins.rs2 < 32, "RF address out of range");
            let c = self.rf[(ins.rs1 & 31) as usize];
            let ab = self.rf[(ins.rs2 & 31) as usize];
            self.pc += 1;
            // decoded.len() == im.len(); comparing against the vector
            // we just indexed keeps the hot loop on one allocation.
            if self.pc == self.decoded.len() {
                self.state = FuState::Flushing;
                self.flush_left = super::dsp48e1::LATENCY as u8;
            }
            Some(ins.apply(c, ab))
        } else {
            if self.state == FuState::Loading && input.is_none() {
                self.idle_cycles += 1;
            }
            None
        };
        // 4. DSP pipeline (value delay line).
        let out = self.dsp.step_value(issue);
        // 5. Flush bookkeeping (after the DSP has shifted).
        if self.state == FuState::Flushing {
            if self.flush_left == 0 {
                self.dc = 0;
                self.state = FuState::Loading;
                self.iterations += 1;
            } else {
                self.flush_left -= 1;
            }
        }
        Ok(out)
    }

    /// RF snapshot (tests / trace).
    pub fn rf(&self) -> &[i32; 32] {
        &self.rf
    }

    pub fn n_loads(&self) -> usize {
        self.n_loads
    }

    pub fn n_instrs(&self) -> usize {
        self.im.len()
    }

    pub fn n_consts(&self) -> usize {
        self.n_consts
    }

    /// DSP utilization: issued ops / elapsed cycles.
    pub fn dsp_utilization(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.dsp.issued as f64 / self.cycles as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::OpKind;

    /// FU computing (a-b) then squaring: 2 instructions, 2 loads.
    fn sub_sqr_fu() -> Fu {
        // Not a realistic stage (mixes levels) but exercises the FSM.
        Fu::new(
            vec![
                FuInstr::Arith {
                    op: OpKind::Sub,
                    rs1: 0,
                    rs2: 1,
                },
                FuInstr::Bypass { rs: 0 },
            ],
            &[],
            2,
        )
        .unwrap()
    }

    #[test]
    fn iteration_timing_matches_table1_shape() {
        let mut fu = sub_sqr_fu();
        let mut outs = Vec::new();
        // Cycle 1-2: loads; cycle 3: first exec; outputs at 5,6.
        outs.push(fu.step(Some(10)).unwrap()); // c1 load
        assert_eq!(fu.state(), FuState::Loading);
        outs.push(fu.step(Some(4)).unwrap()); // c2 load (dc==2 -> trigger next step)
        outs.push(fu.step(None).unwrap()); // c3 exec SUB
        assert_eq!(fu.state(), FuState::Executing);
        outs.push(fu.step(None).unwrap()); // c4 exec BYP -> flushing
        outs.push(fu.step(None).unwrap()); // c5: SUB result out
        outs.push(fu.step(None).unwrap()); // c6: BYP result out
        assert_eq!(outs, vec![None, None, None, None, Some(6), Some(10)]);
        // After flush the FU accepts data again.
        assert_eq!(fu.state(), FuState::Loading);
        assert!(!fu.backpressure());
        assert_eq!(fu.iterations, 1);
    }

    #[test]
    fn backpressure_during_exec_and_flush() {
        let mut fu = sub_sqr_fu();
        fu.step(Some(1)).unwrap();
        fu.step(Some(2)).unwrap();
        // trigger happened inside the *next* step; emulate FIFO checking
        // before each push:
        for _ in 0..4 {
            assert!(!matches!(fu.state(), FuState::Loading) || fu.backpressure() || true);
            fu.step(None).unwrap();
        }
        assert_eq!(fu.state(), FuState::Loading);
    }

    #[test]
    fn rejects_data_while_busy() {
        let mut fu = sub_sqr_fu();
        fu.step(Some(1)).unwrap();
        fu.step(Some(2)).unwrap();
        fu.step(None).unwrap(); // executing now
        assert!(fu.backpressure());
        assert!(fu.step(Some(99)).is_err());
    }

    #[test]
    fn consts_live_at_top_of_rf() {
        let fu = Fu::new(
            vec![FuInstr::Arith {
                op: OpKind::Mul,
                rs1: 0,
                rs2: 31,
            }],
            &[16, -5],
            1,
        )
        .unwrap();
        assert_eq!(fu.rf()[31], 16);
        assert_eq!(fu.rf()[30], -5);
    }

    #[test]
    fn const_multiply_iteration() {
        // h1 = x * 16 with const at slot 31 (chebyshev stage 1 shape).
        let mut fu = Fu::new(
            vec![
                FuInstr::Arith {
                    op: OpKind::Mul,
                    rs1: 0,
                    rs2: 31,
                },
                FuInstr::Bypass { rs: 0 },
            ],
            &[16],
            1,
        )
        .unwrap();
        let mut outs = Vec::new();
        outs.push(fu.step(Some(3)).unwrap());
        for _ in 0..4 {
            outs.push(fu.step(None).unwrap());
        }
        let vals: Vec<i32> = outs.into_iter().flatten().collect();
        assert_eq!(vals, vec![48, 3]); // 3*16 then bypassed x
    }

    #[test]
    fn multiple_iterations_reuse_program() {
        let mut fu = sub_sqr_fu();
        let mut results = Vec::new();
        for (a, b) in [(9, 4), (100, 1), (-5, 5)] {
            fu.step(Some(a)).unwrap();
            fu.step(Some(b)).unwrap();
            for _ in 0..4 {
                if let Some(v) = fu.step(None).unwrap() {
                    results.push(v);
                }
            }
        }
        assert_eq!(results, vec![5, 9, 99, 100, -10, -5]);
        assert_eq!(fu.iterations, 3);
    }

    #[test]
    fn capacity_limits_enforced() {
        assert!(Fu::new(vec![FuInstr::Bypass { rs: 0 }; 33], &[], 1).is_err());
        assert!(Fu::new(vec![FuInstr::Bypass { rs: 0 }], &[0; 20], 20).is_err());
        assert!(Fu::new(vec![], &[], 1).is_err());
    }
}
