//! Recursive-descent parser for the kernel language.
//!
//! Grammar:
//! ```text
//! file    := kernel
//! kernel  := 'kernel' IDENT '(' params? ')' '{' stmt* return '}'
//! params  := IDENT (',' IDENT)*
//! stmt    := IDENT '=' expr ';'
//! return  := 'return' expr (',' expr)* ';'
//! expr    := or
//! or      := xor ('|' xor)*
//! xor     := and ('^' and)*
//! and     := addsub ('&' addsub)*
//! addsub  := mul (('+'|'-') mul)*
//! mul     := unary ('*' unary)*
//! unary   := '-' unary | atom
//! atom    := IDENT | INT | '(' expr ')'
//! ```

use super::ast::{Assign, Expr, KernelDef};
use super::lexer::{lex, Spanned, Tok};
use crate::dfg::OpKind;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub line: u32,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse one kernel definition from source text.
pub fn parse_kernel(src: &str) -> Result<KernelDef, ParseError> {
    let toks = lex(src).map_err(|e| ParseError {
        line: e.line,
        msg: e.msg,
    })?;
    let mut p = Parser { toks, pos: 0 };
    let k = p.kernel()?;
    p.expect(Tok::Eof)?;
    Ok(k)
}

struct Parser {
    toks: Vec<Spanned>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos].tok
    }

    fn line(&self) -> u32 {
        self.toks[self.pos].line
    }

    fn bump(&mut self) -> Tok {
        let t = self.toks[self.pos].tok.clone();
        if self.pos < self.toks.len() - 1 {
            self.pos += 1;
        }
        t
    }

    fn err(&self, msg: String) -> ParseError {
        ParseError {
            line: self.line(),
            msg,
        }
    }

    fn expect(&mut self, want: Tok) -> Result<(), ParseError> {
        if *self.peek() == want {
            self.bump();
            Ok(())
        } else {
            Err(self.err(format!("expected {want}, found {}", self.peek())))
        }
    }

    fn ident(&mut self) -> Result<String, ParseError> {
        let line = self.line();
        match self.bump() {
            Tok::Ident(s) => Ok(s),
            other => Err(ParseError {
                line,
                msg: format!("expected identifier, found {other}"),
            }),
        }
    }

    fn kernel(&mut self) -> Result<KernelDef, ParseError> {
        self.expect(Tok::Kernel)?;
        let name = self.ident()?;
        self.expect(Tok::LParen)?;
        let mut params = Vec::new();
        if *self.peek() != Tok::RParen {
            loop {
                params.push(self.ident()?);
                if *self.peek() == Tok::Comma {
                    self.bump();
                } else {
                    break;
                }
            }
        }
        self.expect(Tok::RParen)?;
        self.expect(Tok::LBrace)?;
        let mut body = Vec::new();
        let returns = loop {
            match self.peek() {
                Tok::Return => {
                    self.bump();
                    let mut rets = vec![self.expr()?];
                    while *self.peek() == Tok::Comma {
                        self.bump();
                        rets.push(self.expr()?);
                    }
                    self.expect(Tok::Semi)?;
                    break rets;
                }
                Tok::Ident(_) => {
                    let line = self.line();
                    let name = self.ident()?;
                    self.expect(Tok::Assign)?;
                    let expr = self.expr()?;
                    self.expect(Tok::Semi)?;
                    body.push(Assign { name, expr, line });
                }
                other => return Err(self.err(format!("expected statement or return, found {other}"))),
            }
        };
        self.expect(Tok::RBrace)?;
        Ok(KernelDef {
            name,
            params,
            body,
            returns,
        })
    }

    fn expr(&mut self) -> Result<Expr, ParseError> {
        self.binary(0)
    }

    /// Precedence-climbing over the binary levels.
    fn binary(&mut self, level: usize) -> Result<Expr, ParseError> {
        const LEVELS: &[&[(Tok, OpKind)]] = &[
            &[(Tok::Pipe, OpKind::Or)],
            &[(Tok::Caret, OpKind::Xor)],
            &[(Tok::Amp, OpKind::And)],
            &[(Tok::Plus, OpKind::Add), (Tok::Minus, OpKind::Sub)],
            &[(Tok::Star, OpKind::Mul)],
        ];
        if level == LEVELS.len() {
            return self.unary();
        }
        let mut lhs = self.binary(level + 1)?;
        loop {
            let op = LEVELS[level]
                .iter()
                .find(|(t, _)| t == self.peek())
                .map(|(_, op)| *op);
            match op {
                Some(op) => {
                    self.bump();
                    let rhs = self.binary(level + 1)?;
                    lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
                }
                None => return Ok(lhs),
            }
        }
    }

    fn unary(&mut self) -> Result<Expr, ParseError> {
        if *self.peek() == Tok::Minus {
            self.bump();
            return Ok(Expr::Neg(Box::new(self.unary()?)));
        }
        self.atom()
    }

    fn atom(&mut self) -> Result<Expr, ParseError> {
        let line = self.line();
        match self.bump() {
            Tok::Ident(s) => Ok(Expr::Var(s)),
            Tok::Int(v) => Ok(Expr::Lit(v)),
            Tok::LParen => {
                let e = self.expr()?;
                self.expect(Tok::RParen)?;
                Ok(e)
            }
            other => Err(ParseError {
                line,
                msg: format!("expected expression, found {other}"),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_kernel() {
        let k = parse_kernel("kernel f(a, b) { return a + b; }").unwrap();
        assert_eq!(k.name, "f");
        assert_eq!(k.params, vec!["a", "b"]);
        assert!(k.body.is_empty());
        assert_eq!(k.returns.len(), 1);
    }

    #[test]
    fn precedence_mul_over_add() {
        let k = parse_kernel("kernel f(a,b,c) { return a + b * c; }").unwrap();
        match &k.returns[0] {
            Expr::Bin(OpKind::Add, _, rhs) => {
                assert!(matches!(**rhs, Expr::Bin(OpKind::Mul, _, _)));
            }
            other => panic!("wrong shape: {other:?}"),
        }
    }

    #[test]
    fn precedence_bitops_lowest() {
        let k = parse_kernel("kernel f(a,b,c) { return a | b + c; }").unwrap();
        match &k.returns[0] {
            Expr::Bin(OpKind::Or, _, rhs) => {
                assert!(matches!(**rhs, Expr::Bin(OpKind::Add, _, _)));
            }
            other => panic!("wrong shape: {other:?}"),
        }
    }

    #[test]
    fn parens_override() {
        let k = parse_kernel("kernel f(a,b,c) { return (a + b) * c; }").unwrap();
        match &k.returns[0] {
            Expr::Bin(OpKind::Mul, lhs, _) => {
                assert!(matches!(**lhs, Expr::Bin(OpKind::Add, _, _)));
            }
            other => panic!("wrong shape: {other:?}"),
        }
    }

    #[test]
    fn statements_and_multi_return() {
        let src = "kernel g(x) {\n  t = x * x;\n  u = t + 1;\n  return t, u;\n}";
        let k = parse_kernel(src).unwrap();
        assert_eq!(k.body.len(), 2);
        assert_eq!(k.body[0].name, "t");
        assert_eq!(k.body[1].line, 3);
        assert_eq!(k.returns.len(), 2);
    }

    #[test]
    fn unary_minus() {
        let k = parse_kernel("kernel f(x) { return -x * 3; }").unwrap();
        // -x binds tighter than *: (-x) * 3
        assert!(matches!(&k.returns[0], Expr::Bin(OpKind::Mul, lhs, _)
            if matches!(**lhs, Expr::Neg(_))));
    }

    #[test]
    fn error_reports_line() {
        let err = parse_kernel("kernel f(a) {\n  t = ;\n  return t;\n}").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn rejects_trailing_tokens() {
        assert!(parse_kernel("kernel f(a) { return a; } extra").is_err());
    }

    #[test]
    fn rejects_missing_return() {
        assert!(parse_kernel("kernel f(a) { t = a + 1; }").is_err());
    }
}
