//! Lexer for the kernel description language (`benchmarks/src/*.k`).
//!
//! The paper's flow starts from a C description of the compute kernel
//! (§IV "HLL to DFG Conversion"); our frontend accepts the expression
//! subset those kernels actually use: straight-line assignments over
//! `+ - * & | ^`, parentheses, integer literals, and a `return`.

use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    // keywords
    Kernel,
    Return,
    // punctuation
    LParen,
    RParen,
    LBrace,
    RBrace,
    Comma,
    Semi,
    Assign,
    // operators
    Plus,
    Minus,
    Star,
    Amp,
    Pipe,
    Caret,
    // atoms
    Ident(String),
    Int(i64),
    Eof,
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Tok::Kernel => write!(f, "'kernel'"),
            Tok::Return => write!(f, "'return'"),
            Tok::LParen => write!(f, "'('"),
            Tok::RParen => write!(f, "')'"),
            Tok::LBrace => write!(f, "'{{'"),
            Tok::RBrace => write!(f, "'}}'"),
            Tok::Comma => write!(f, "','"),
            Tok::Semi => write!(f, "';'"),
            Tok::Assign => write!(f, "'='"),
            Tok::Plus => write!(f, "'+'"),
            Tok::Minus => write!(f, "'-'"),
            Tok::Star => write!(f, "'*'"),
            Tok::Amp => write!(f, "'&'"),
            Tok::Pipe => write!(f, "'|'"),
            Tok::Caret => write!(f, "'^'"),
            Tok::Ident(s) => write!(f, "identifier '{s}'"),
            Tok::Int(v) => write!(f, "integer {v}"),
            Tok::Eof => write!(f, "end of input"),
        }
    }
}

/// A token with its source line (1-based) for error messages.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Spanned {
    pub tok: Tok,
    pub line: u32,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LexError {
    pub line: u32,
    pub msg: String,
}

impl fmt::Display for LexError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "lex error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for LexError {}

/// Tokenize a kernel source file. `#` and `//` start line comments.
pub fn lex(src: &str) -> Result<Vec<Spanned>, LexError> {
    let mut out = Vec::new();
    let bytes = src.as_bytes();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < bytes.len() {
        let c = bytes[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b'#' => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            b'/' if bytes.get(i + 1) == Some(&b'/') => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            b'(' => push1(&mut out, Tok::LParen, line, &mut i),
            b')' => push1(&mut out, Tok::RParen, line, &mut i),
            b'{' => push1(&mut out, Tok::LBrace, line, &mut i),
            b'}' => push1(&mut out, Tok::RBrace, line, &mut i),
            b',' => push1(&mut out, Tok::Comma, line, &mut i),
            b';' => push1(&mut out, Tok::Semi, line, &mut i),
            b'=' => push1(&mut out, Tok::Assign, line, &mut i),
            b'+' => push1(&mut out, Tok::Plus, line, &mut i),
            b'-' => push1(&mut out, Tok::Minus, line, &mut i),
            b'*' => push1(&mut out, Tok::Star, line, &mut i),
            b'&' => push1(&mut out, Tok::Amp, line, &mut i),
            b'|' => push1(&mut out, Tok::Pipe, line, &mut i),
            b'^' => push1(&mut out, Tok::Caret, line, &mut i),
            b'0'..=b'9' => {
                let start = i;
                while i < bytes.len() && (bytes[i].is_ascii_digit() || bytes[i] == b'x' || bytes[i].is_ascii_hexdigit())
                {
                    i += 1;
                }
                let text = &src[start..i];
                let v = if let Some(hex) = text.strip_prefix("0x").or_else(|| text.strip_prefix("0X")) {
                    i64::from_str_radix(hex, 16)
                } else {
                    text.parse::<i64>()
                }
                .map_err(|_| LexError {
                    line,
                    msg: format!("invalid integer literal '{text}'"),
                })?;
                out.push(Spanned { tok: Tok::Int(v), line });
            }
            c if c.is_ascii_alphabetic() || c == b'_' => {
                let start = i;
                while i < bytes.len() && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_') {
                    i += 1;
                }
                let word = &src[start..i];
                let tok = match word {
                    "kernel" => Tok::Kernel,
                    "return" => Tok::Return,
                    _ => Tok::Ident(word.to_string()),
                };
                out.push(Spanned { tok, line });
            }
            other => {
                return Err(LexError {
                    line,
                    msg: format!("unexpected character '{}'", other as char),
                })
            }
        }
    }
    out.push(Spanned { tok: Tok::Eof, line });
    Ok(out)
}

fn push1(out: &mut Vec<Spanned>, tok: Tok, line: u32, i: &mut usize) {
    out.push(Spanned { tok, line });
    *i += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|s| s.tok).collect()
    }

    #[test]
    fn lexes_kernel_header() {
        assert_eq!(
            toks("kernel f(a, b) {"),
            vec![
                Tok::Kernel,
                Tok::Ident("f".into()),
                Tok::LParen,
                Tok::Ident("a".into()),
                Tok::Comma,
                Tok::Ident("b".into()),
                Tok::RParen,
                Tok::LBrace,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn lexes_numbers() {
        assert_eq!(toks("42 0x10"), vec![Tok::Int(42), Tok::Int(16), Tok::Eof]);
    }

    #[test]
    fn skips_comments() {
        let src = "a # comment here\nb // another\nc";
        assert_eq!(
            toks(src),
            vec![
                Tok::Ident("a".into()),
                Tok::Ident("b".into()),
                Tok::Ident("c".into()),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn tracks_lines() {
        let spanned = lex("a\nb\n\nc").unwrap();
        let lines: Vec<u32> = spanned.iter().map(|s| s.line).collect();
        assert_eq!(lines, vec![1, 2, 4, 4]);
    }

    #[test]
    fn rejects_bad_char() {
        let err = lex("a $ b").unwrap_err();
        assert!(err.msg.contains('$'));
    }

    #[test]
    fn operators_all_lex() {
        assert_eq!(
            toks("+-*&|^=;"),
            vec![
                Tok::Plus,
                Tok::Minus,
                Tok::Star,
                Tok::Amp,
                Tok::Pipe,
                Tok::Caret,
                Tok::Assign,
                Tok::Semi,
                Tok::Eof
            ]
        );
    }
}
