//! HLL → DFG frontend (the first step of the paper's §IV mapping flow).
//!
//! Accepts the C-expression subset the benchmark kernels use (see
//! `benchmarks/src/*.k`), parses to an AST, lowers to the [`crate::dfg`]
//! IR and normalizes (constant folding, CSE, DCE).

pub mod ast;
pub mod lexer;
pub mod lower;
pub mod parser;

pub use lower::{compile, compile_raw, LowerError};
pub use parser::{parse_kernel, ParseError};
