//! AST → DFG lowering.
//!
//! Variables resolve lexically (parameters, then prior assignments);
//! literals become `Const` nodes; `-e` lowers to `0 - e`; the returned
//! expressions become `Output` nodes (`out` for a single return, `outN`
//! otherwise). The result is then run through the `normalize` pipeline
//! (const-fold → CSE → DCE) exactly like the paper's HLL→DFG tool, which
//! emits a cleaned DFG.

use super::ast::{Expr, KernelDef};
use super::parser::{parse_kernel, ParseError};
use crate::dfg::{normalize, Dfg, NodeId, OpKind};
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LowerError {
    Parse(ParseError),
    UnknownVar { name: String, line: u32 },
    Reassigned { name: String, line: u32 },
    LitRange(i64),
}

impl std::fmt::Display for LowerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LowerError::Parse(e) => write!(f, "{e}"),
            LowerError::UnknownVar { name, line } => {
                write!(f, "line {line}: unknown variable '{name}'")
            }
            LowerError::Reassigned { name, line } => write!(
                f,
                "line {line}: variable '{name}' reassigned (kernels are single-assignment)"
            ),
            LowerError::LitRange(v) => write!(f, "literal {v} out of i32 range"),
        }
    }
}

impl std::error::Error for LowerError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LowerError::Parse(e) => Some(e),
            _ => None,
        }
    }
}

impl From<ParseError> for LowerError {
    fn from(e: ParseError) -> LowerError {
        LowerError::Parse(e)
    }
}

/// Compile kernel source text to a normalized DFG.
pub fn compile(src: &str) -> Result<Dfg, LowerError> {
    let def = parse_kernel(src)?;
    lower(&def)
}

/// Compile without the normalize pass (for tests that inspect raw shape).
pub fn compile_raw(src: &str) -> Result<Dfg, LowerError> {
    let def = parse_kernel(src)?;
    lower_raw(&def)
}

/// Lower a parsed kernel and normalize.
pub fn lower(def: &KernelDef) -> Result<Dfg, LowerError> {
    Ok(normalize(&lower_raw(def)?))
}

fn lower_raw(def: &KernelDef) -> Result<Dfg, LowerError> {
    let mut g = Dfg::new(&def.name);
    let mut env: BTreeMap<String, NodeId> = BTreeMap::new();
    for p in &def.params {
        let id = g.add_input(p);
        env.insert(p.clone(), id);
    }
    for stmt in &def.body {
        if env.contains_key(&stmt.name) {
            return Err(LowerError::Reassigned {
                name: stmt.name.clone(),
                line: stmt.line,
            });
        }
        let id = lower_expr(&mut g, &env, &stmt.expr, stmt.line)?;
        env.insert(stmt.name.clone(), id);
    }
    let multi = def.returns.len() > 1;
    for (i, r) in def.returns.iter().enumerate() {
        let id = lower_expr(&mut g, &env, r, 0)?;
        let name = if multi { format!("out{i}") } else { "out".to_string() };
        g.add_output(&name, id);
    }
    debug_assert!(g.validate().is_ok());
    Ok(g)
}

fn lower_expr(
    g: &mut Dfg,
    env: &BTreeMap<String, NodeId>,
    e: &Expr,
    line: u32,
) -> Result<NodeId, LowerError> {
    match e {
        Expr::Var(name) => env.get(name).copied().ok_or_else(|| LowerError::UnknownVar {
            name: name.clone(),
            line,
        }),
        Expr::Lit(v) => {
            if *v < i32::MIN as i64 || *v > i32::MAX as i64 {
                return Err(LowerError::LitRange(*v));
            }
            Ok(g.add_const(*v as i32))
        }
        Expr::Bin(op, a, b) => {
            let a = lower_expr(g, env, a, line)?;
            let b = lower_expr(g, env, b, line)?;
            Ok(g.add_op(*op, a, b))
        }
        Expr::Neg(inner) => {
            let zero = g.add_const(0);
            let v = lower_expr(g, env, inner, line)?;
            Ok(g.add_op(OpKind::Sub, zero, v))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::{eval, Characteristics};

    #[test]
    fn lowers_and_evaluates() {
        let g = compile("kernel f(a, b) {\n  s = a + b;\n  return s * s;\n}").unwrap();
        assert_eq!(eval(&g, &[2, 3]), vec![25]);
    }

    #[test]
    fn sqr_is_single_node_after_cse() {
        // x*x must lower to one MUL with both args equal (the paper's SQR).
        let g = compile("kernel s(x) { return x * x; }").unwrap();
        assert_eq!(g.n_ops(), 1);
    }

    #[test]
    fn cse_collapses_repeated_subexpr() {
        let g = compile("kernel f(a,b) { return (a+b)*(a+b); }").unwrap();
        assert_eq!(g.n_ops(), 2); // one add, one mul
    }

    #[test]
    fn const_exprs_fold() {
        let g = compile("kernel f(x) { return x * (2 + 3); }").unwrap();
        assert_eq!(g.n_ops(), 1);
        assert_eq!(eval(&g, &[4]), vec![20]);
    }

    #[test]
    fn neg_lowers_to_sub_from_zero() {
        let g = compile("kernel f(x) { return -x; }").unwrap();
        assert_eq!(eval(&g, &[42]), vec![-42]);
        assert_eq!(eval(&g, &[i32::MIN]), vec![i32::MIN]); // wrapping
    }

    #[test]
    fn unknown_var_reports_line() {
        let err = compile("kernel f(a) {\n  t = a + 1;\n  u = bogus * 2;\n  return u;\n}")
            .unwrap_err();
        assert_eq!(
            err,
            LowerError::UnknownVar {
                name: "bogus".into(),
                line: 3
            }
        );
    }

    #[test]
    fn reassignment_rejected() {
        let err = compile("kernel f(a) {\n  t = a;\n  t = a + 1;\n  return t;\n}").unwrap_err();
        assert!(matches!(err, LowerError::Reassigned { .. }));
    }

    #[test]
    fn chebyshev_shape_matches_paper() {
        // The reconstructed chebyshev kernel: 16x^5 - 20x^3 + 5x as a
        // 7-op chain (Table II row 1: 1/1 io, 12 edges, 7 ops, depth 7).
        let src = "kernel chebyshev(x) {
            h1 = x * 16;
            h2 = h1 * x;
            h3 = h2 - 20;
            h4 = h3 * x;
            h5 = h4 * x;
            h6 = h5 + 5;
            return h6 * x;
        }";
        let g = compile(src).unwrap();
        let c = Characteristics::of(&g);
        assert_eq!(c.n_inputs, 1);
        assert_eq!(c.n_outputs, 1);
        assert_eq!(c.n_ops, 7);
        assert_eq!(c.depth, 7);
        assert_eq!(c.n_edges, 12);
        assert!((c.avg_parallelism - 1.0).abs() < 1e-9);
        // Semantic check: 16x^5 - 20x^3 + 5x at small x.
        for x in [-3i32, -1, 0, 1, 2, 5] {
            let expect = 16 * x.pow(5) - 20 * x.pow(3) + 5 * x;
            assert_eq!(eval(&g, &[x]), vec![expect]);
        }
    }

    #[test]
    fn multi_return_names() {
        let g = compile("kernel f(a,b) { return a+b, a-b; }").unwrap();
        assert_eq!(g.output_names(), vec!["out0", "out1"]);
    }
}
