//! AST for the kernel language.

use crate::dfg::OpKind;

/// Expression tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    /// Variable reference (parameter or earlier assignment).
    Var(String),
    /// Integer literal.
    Lit(i64),
    /// Binary operation.
    Bin(OpKind, Box<Expr>, Box<Expr>),
    /// Unary negation (lowered as `0 - e`).
    Neg(Box<Expr>),
}

/// One `name = expr;` statement.
#[derive(Debug, Clone, PartialEq)]
pub struct Assign {
    pub name: String,
    pub expr: Expr,
    pub line: u32,
}

/// A complete kernel definition.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelDef {
    pub name: String,
    pub params: Vec<String>,
    pub body: Vec<Assign>,
    /// Returned expressions, in order; single return is named `out`,
    /// multiple are `out0`, `out1`, ...
    pub returns: Vec<Expr>,
}

impl Expr {
    /// Count of binary-op applications (pre-lowering size metric).
    pub fn op_count(&self) -> usize {
        match self {
            Expr::Var(_) | Expr::Lit(_) => 0,
            Expr::Bin(_, a, b) => 1 + a.op_count() + b.op_count(),
            Expr::Neg(e) => 1 + e.op_count(),
        }
    }
}
