//! PJRT backend: serves batches through the AOT-compiled (JAX +
//! Pallas) artifacts via [`crate::runtime::Engine`].
//!
//! Requires `make artifacts` output on disk and a linked PJRT runtime
//! (see `runtime/xla_shim.rs` for the offline-build story). Context
//! switches are charged with the same daisy-chain word count as the
//! hardware model, keeping the simulated 300 MHz fabric timeline
//! comparable across backends.

use super::{
    validate_batch, Backend, Capabilities, CompiledKernel, ExecError, ExecReport, FlatBatch,
};
use crate::runtime::Engine;
use anyhow::{Context, Result};
use std::path::Path;

/// The PJRT execution backend.
pub struct PjrtBackend {
    engine: Engine,
    context: Option<String>,
}

impl PjrtBackend {
    /// Load and compile every kernel artifact in `dir`.
    pub fn load(dir: &Path) -> Result<PjrtBackend> {
        let engine = Engine::load(dir)
            .with_context(|| format!("loading PJRT artifacts from '{}'", dir.display()))?;
        Ok(PjrtBackend {
            engine,
            context: None,
        })
    }

    /// Largest batch the compiled artifacts accept.
    pub fn max_batch(&self) -> usize {
        self.engine.batch
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            cycle_accurate: false,
            needs_artifacts: true,
            models_context_switch: true,
            max_batch: Some(self.engine.batch),
        }
    }

    fn execute(
        &mut self,
        kernel: &CompiledKernel,
        batch: &FlatBatch,
    ) -> Result<ExecReport, ExecError> {
        validate_batch(kernel, batch)?;
        if batch.n_rows() > self.engine.batch {
            return Err(ExecError::BatchTooLarge {
                kernel: kernel.name.clone(),
                got: batch.n_rows(),
                max: self.engine.batch,
            });
        }
        // The PJRT engine consumes row vectors; convert at the
        // boundary (artifact-gated path, not the flat fast path).
        let rows = batch.to_rows();
        let outputs = self
            .engine
            .execute(&kernel.name, &rows)
            .map_err(|e| ExecError::Backend {
                backend: "pjrt",
                message: format!("{e}"),
            })?;
        let switch_cycles = if self.context.as_deref() != Some(kernel.name.as_str()) {
            self.context = Some(kernel.name.clone());
            kernel.context_words as u64
        } else {
            0
        };
        Ok(ExecReport {
            outputs: FlatBatch::from_rows(kernel.n_outputs, &outputs),
            switch_cycles,
            fabric_cycles: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::eval;
    use crate::exec::KernelRegistry;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn load_fails_cleanly_without_artifacts() {
        assert!(PjrtBackend::load(Path::new("/definitely/not/here")).is_err());
    }

    /// Artifact-gated: PJRT output must match the oracle through the
    /// backend contract (skips when `make artifacts` has not run).
    #[test]
    fn matches_oracle_when_artifacts_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let mut b = PjrtBackend::load(&dir).unwrap();
        let k = reg.get("gradient").unwrap();
        let batch = FlatBatch::from_rows(5, &[vec![3, 5, 2, 7, 1]]);
        let r = b.execute(k, &batch).unwrap();
        assert_eq!(r.outputs.to_rows(), vec![eval(&k.dfg, batch.row(0))]);
        assert_eq!(r.switch_cycles, k.context_words as u64);
        let over_rows: Vec<Vec<i32>> = (0..b.max_batch() + 1).map(|_| vec![0; 5]).collect();
        let over = FlatBatch::from_rows(5, &over_rows);
        assert!(matches!(
            b.execute(k, &over),
            Err(ExecError::BatchTooLarge { .. })
        ));
    }
}
