//! Interpreter backend: the functional DFG oracle on the serving path.
//!
//! Executes batches through [`crate::dfg::eval_into`] — a node-by-node
//! graph walk per packet (a `match` and bounds-checked indexing per
//! node) with the per-node value scratch hoisted into the backend and
//! reused forever. No hardware model, no artifacts, bit-exact wrapping
//! int32 semantics. This is the reference substrate the other backends
//! are verified against: it deliberately stays a *graph traversal* (it
//! shares `eval_into` with the one-packet oracle, and nothing with
//! the turbo backend's pre-compiled tape), so ref-vs-turbo
//! equivalence compares two genuinely different executable forms.
//!
//! The native [`Backend::execute_into`] writes rows straight into the
//! caller's reusable [`ExecReport`], so even the oracle path is
//! allocation-free in steady state — which keeps the worker-loop
//! zero-allocation audit meaningful on the `ref` substrate too.

use super::{
    validate_batch, Backend, Capabilities, CompiledKernel, ExecError, ExecReport, FlatBatch,
};
use crate::dfg::eval_into;

/// The DFG-interpreter backend.
#[derive(Debug, Default)]
pub struct RefBackend {
    /// Per-node value scratch for `eval_into`, reused across packets.
    value: Vec<i32>,
    /// One packet's outputs, copied into the report row by row.
    row_out: Vec<i32>,
    /// Packets executed (introspection / tests).
    pub executed: u64,
}

impl RefBackend {
    pub fn new() -> RefBackend {
        RefBackend::default()
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            cycle_accurate: false,
            needs_artifacts: false,
            models_context_switch: false,
            max_batch: None,
        }
    }

    fn execute(
        &mut self,
        kernel: &CompiledKernel,
        batch: &FlatBatch,
    ) -> Result<ExecReport, ExecError> {
        let mut report = ExecReport::default();
        self.execute_into(kernel, batch, &mut report)?;
        Ok(report)
    }

    /// Native zero-allocation path: one `eval_into` per packet against
    /// backend-owned scratch, appending rows to the caller's warm
    /// output buffer. `FlatBatch::iter` yields one (possibly empty)
    /// slice per row, so zero-input kernels take the same loop.
    fn execute_into(
        &mut self,
        kernel: &CompiledKernel,
        batch: &FlatBatch,
        report: &mut ExecReport,
    ) -> Result<(), ExecError> {
        validate_batch(kernel, batch)?;
        report.outputs.reset(kernel.n_outputs);
        report.outputs.reserve_rows(batch.n_rows());
        for row in batch.iter() {
            self.row_out.clear();
            eval_into(&kernel.dfg, row, &mut self.value, &mut self.row_out);
            report.outputs.push(&self.row_out);
        }
        report.switch_cycles = 0;
        report.fabric_cycles = None;
        self.executed += batch.n_rows() as u64;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::eval;
    use crate::exec::KernelRegistry;

    #[test]
    fn executes_and_counts() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let k = reg.get("gradient").unwrap();
        let mut b = RefBackend::new();
        let batch = FlatBatch::from_rows(5, &[vec![3, 5, 2, 7, 1], vec![0, 0, 0, 0, 0]]);
        let r = b.execute(k, &batch).unwrap();
        assert_eq!(r.outputs.to_rows(), vec![vec![36], vec![0]]);
        assert_eq!(b.executed, 2);
        assert_eq!(r.fabric_cycles, None);
    }

    #[test]
    fn structured_errors_not_panics() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let k = reg.get("chebyshev").unwrap();
        let mut b = RefBackend::new();
        assert!(matches!(
            b.execute(k, &FlatBatch::from_rows(2, &[vec![1, 2]])),
            Err(ExecError::WrongArity { .. })
        ));
        assert!(matches!(
            b.execute(k, &FlatBatch::new(1)),
            Err(ExecError::EmptyBatch { .. })
        ));
        assert_eq!(b.executed, 0);
    }

    #[test]
    fn execute_into_reuses_scratch_across_batches() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let mut b = RefBackend::new();
        let mut report = ExecReport::default();
        for name in ["gradient", "poly6", "gradient"] {
            let k = reg.get(name).unwrap();
            let rows = vec![vec![1; k.n_inputs], vec![-3; k.n_inputs], vec![40; k.n_inputs]];
            let batch = FlatBatch::from_rows(k.n_inputs, &rows);
            b.execute_into(k, &batch, &mut report).unwrap();
            assert_eq!(report.outputs.n_rows(), rows.len(), "{name}");
            for (pkt, o) in rows.iter().zip(report.outputs.iter()) {
                assert_eq!(o, &eval(&k.dfg, pkt)[..], "{name}");
            }
        }
        assert_eq!(b.executed, 9);
    }
}
