//! Interpreter backend: the functional DFG oracle on the serving path.
//!
//! Executes batches through [`crate::dfg::eval_batch`] — a node-by-node
//! graph walk per packet (a `match` and bounds-checked indexing per
//! node) with the per-node value scratch hoisted out of the packet
//! loop. No hardware model, no artifacts, bit-exact wrapping int32
//! semantics. This is the reference substrate the other backends are
//! verified against: it deliberately stays a *graph traversal* (it
//! shares `eval_into` with the one-packet oracle, and nothing with
//! the turbo backend's pre-compiled tape), so ref-vs-turbo
//! equivalence compares two genuinely different executable forms.

use super::{
    validate_batch, Backend, Capabilities, CompiledKernel, ExecError, ExecReport, FlatBatch,
};
use crate::dfg::{eval, eval_batch};

/// The DFG-interpreter backend (stateless).
#[derive(Debug, Default)]
pub struct RefBackend {
    /// Packets executed (introspection / tests).
    pub executed: u64,
}

impl RefBackend {
    pub fn new() -> RefBackend {
        RefBackend::default()
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            cycle_accurate: false,
            needs_artifacts: false,
            models_context_switch: false,
            max_batch: None,
        }
    }

    fn execute(
        &mut self,
        kernel: &CompiledKernel,
        batch: &FlatBatch,
    ) -> Result<ExecReport, ExecError> {
        validate_batch(kernel, batch)?;
        let outputs = if kernel.n_inputs > 0 {
            FlatBatch::from_flat(kernel.n_outputs, eval_batch(&kernel.dfg, batch.data()))
        } else {
            // Zero-input kernels (constant graphs built through
            // `KernelRegistry::compile`) have no flat row shape;
            // evaluate them packet by packet.
            let mut out = FlatBatch::with_capacity(kernel.n_outputs, batch.n_rows());
            for row in batch.iter() {
                out.push_iter(eval(&kernel.dfg, row));
            }
            out
        };
        self.executed += batch.n_rows() as u64;
        Ok(ExecReport {
            outputs,
            switch_cycles: 0,
            fabric_cycles: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::KernelRegistry;

    #[test]
    fn executes_and_counts() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let k = reg.get("gradient").unwrap();
        let mut b = RefBackend::new();
        let batch = FlatBatch::from_rows(5, &[vec![3, 5, 2, 7, 1], vec![0, 0, 0, 0, 0]]);
        let r = b.execute(k, &batch).unwrap();
        assert_eq!(r.outputs.to_rows(), vec![vec![36], vec![0]]);
        assert_eq!(b.executed, 2);
        assert_eq!(r.fabric_cycles, None);
    }

    #[test]
    fn structured_errors_not_panics() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let k = reg.get("chebyshev").unwrap();
        let mut b = RefBackend::new();
        assert!(matches!(
            b.execute(k, &FlatBatch::from_rows(2, &[vec![1, 2]])),
            Err(ExecError::WrongArity { .. })
        ));
        assert!(matches!(
            b.execute(k, &FlatBatch::new(1)),
            Err(ExecError::EmptyBatch { .. })
        ));
        assert_eq!(b.executed, 0);
    }
}
