//! Interpreter backend: the functional DFG oracle on the serving path.
//!
//! Executes every packet through [`crate::dfg::eval`] — no hardware
//! model, no artifacts, bit-exact wrapping int32 semantics. This is
//! the reference substrate the other backends are verified against,
//! and the fastest way to serve when no fabric modeling is wanted.

use super::{validate_batch, Backend, Capabilities, CompiledKernel, ExecError, ExecReport};
use crate::dfg::eval;

/// The DFG-interpreter backend (stateless).
#[derive(Debug, Default)]
pub struct RefBackend {
    /// Packets executed (introspection / tests).
    pub executed: u64,
}

impl RefBackend {
    pub fn new() -> RefBackend {
        RefBackend::default()
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            cycle_accurate: false,
            needs_artifacts: false,
            models_context_switch: false,
            max_batch: None,
        }
    }

    fn execute(
        &mut self,
        kernel: &CompiledKernel,
        batch: &[Vec<i32>],
    ) -> Result<ExecReport, ExecError> {
        validate_batch(kernel, batch)?;
        let outputs = batch.iter().map(|p| eval(&kernel.dfg, p)).collect();
        self.executed += batch.len() as u64;
        Ok(ExecReport {
            outputs,
            switch_cycles: 0,
            fabric_cycles: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::KernelRegistry;

    #[test]
    fn executes_and_counts() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let k = reg.get("gradient").unwrap();
        let mut b = RefBackend::new();
        let r = b
            .execute(k, &[vec![3, 5, 2, 7, 1], vec![0, 0, 0, 0, 0]])
            .unwrap();
        assert_eq!(r.outputs, vec![vec![36], vec![0]]);
        assert_eq!(b.executed, 2);
        assert_eq!(r.fabric_cycles, None);
    }

    #[test]
    fn structured_errors_not_panics() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let k = reg.get("chebyshev").unwrap();
        let mut b = RefBackend::new();
        assert!(matches!(
            b.execute(k, &[vec![1, 2]]),
            Err(ExecError::WrongArity { .. })
        ));
        assert!(matches!(
            b.execute(k, &[]),
            Err(ExecError::EmptyBatch { .. })
        ));
        assert_eq!(b.executed, 0);
    }
}
