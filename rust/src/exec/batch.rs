//! Flat batch I/O: one contiguous `i32` buffer for a whole batch.
//!
//! The serving path historically moved batches as `&[Vec<i32>]` — one
//! heap allocation per packet on every hop (ingress copy, backend
//! dispatch, oracle check). [`FlatBatch`] replaces that shape end to
//! end: packets are rows of a single row-major buffer (`arity` words
//! per row), so a steady-state worker reuses one buffer per batch
//! (`reset` + `push`) and backends index rows without pointer chasing.
//! This is the software analogue of the overlay's streaming data BRAM:
//! packets are contiguous words, not boxed objects.

use std::fmt;

/// A row-major batch of packets sharing one contiguous buffer.
///
/// Invariant: `data.len() == arity * rows`. `arity` is words per
/// packet (kernel inputs on the request side, kernel outputs on the
/// reply side). `rows` is tracked explicitly so the container stays
/// well-defined even for zero-arity edge cases.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FlatBatch {
    data: Vec<i32>,
    arity: usize,
    rows: usize,
}

impl FlatBatch {
    /// Empty batch of `arity`-word packets.
    pub fn new(arity: usize) -> FlatBatch {
        FlatBatch {
            data: Vec::new(),
            arity,
            rows: 0,
        }
    }

    /// Empty batch with room for `rows` packets.
    pub fn with_capacity(arity: usize, rows: usize) -> FlatBatch {
        FlatBatch {
            data: Vec::with_capacity(arity * rows),
            arity,
            rows: 0,
        }
    }

    /// Build from row vectors (tests / adapters for row-shaped APIs).
    /// `arity` is explicit so empty batches keep their shape.
    pub fn from_rows(arity: usize, rows: &[Vec<i32>]) -> FlatBatch {
        let mut b = FlatBatch::with_capacity(arity, rows.len());
        for r in rows {
            b.push(r);
        }
        b
    }

    /// Adopt an already row-major buffer without copying (producers
    /// that emit flat output, e.g. `dfg::eval_batch`). Panics unless
    /// the length is a whole number of `arity`-word rows.
    pub fn from_flat(arity: usize, data: Vec<i32>) -> FlatBatch {
        assert!(arity > 0, "FlatBatch::from_flat needs a positive arity");
        assert_eq!(data.len() % arity, 0, "FlatBatch::from_flat ragged buffer");
        let rows = data.len() / arity;
        FlatBatch { data, arity, rows }
    }

    /// Clear and re-shape in place, keeping the allocation (the
    /// worker-loop reuse hook: one buffer serves every kernel).
    pub fn reset(&mut self, arity: usize) {
        self.data.clear();
        self.arity = arity;
        self.rows = 0;
    }

    /// Reserve room for `rows` more packets.
    pub fn reserve_rows(&mut self, rows: usize) {
        self.data.reserve(self.arity * rows);
    }

    /// Shape the batch to exactly `rows` zero-filled packets, so rows
    /// can be written in place (and out of order) with
    /// [`Self::row_mut`]. Keeps the allocation when shrinking — the
    /// completion slab's reply buffers stay warm across generations.
    pub fn resize_rows(&mut self, rows: usize) {
        self.data.resize(self.arity * rows, 0);
        self.rows = rows;
    }

    /// One packet as a mutable slice (in-place reply writes).
    pub fn row_mut(&mut self, i: usize) -> &mut [i32] {
        let start = i * self.arity;
        &mut self.data[start..start + self.arity]
    }

    /// Append every packet of `other` in one contiguous copy. Panics
    /// on arity mismatch — same caller-bug contract as [`Self::push`].
    pub fn extend_from_batch(&mut self, other: &FlatBatch) {
        assert_eq!(other.arity, self.arity, "FlatBatch batch arity");
        self.data.extend_from_slice(&other.data);
        self.rows += other.rows;
    }

    /// Append one packet. Panics on arity mismatch — shape errors are
    /// caught at ingress ([`super::validate_batch`] / `submit`), so a
    /// mismatch here is a caller bug, not a request error.
    pub fn push(&mut self, row: &[i32]) {
        assert_eq!(row.len(), self.arity, "FlatBatch row arity");
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Append one packet from an iterator yielding exactly `arity`
    /// values (lets producers write straight into the buffer).
    pub fn push_iter<I: IntoIterator<Item = i32>>(&mut self, values: I) {
        let before = self.data.len();
        self.data.extend(values);
        assert_eq!(self.data.len() - before, self.arity, "FlatBatch row arity");
        self.rows += 1;
    }

    /// Words per packet.
    pub fn arity(&self) -> usize {
        self.arity
    }

    /// Packets in the batch.
    pub fn n_rows(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// One packet as a slice.
    pub fn row(&self, i: usize) -> &[i32] {
        let start = i * self.arity;
        &self.data[start..start + self.arity]
    }

    /// Iterate packets in submission order. Yields exactly
    /// [`Self::n_rows`] slices, including the zero-arity edge (one
    /// empty slice per row).
    pub fn iter(&self) -> impl Iterator<Item = &[i32]> + '_ {
        (0..self.rows).map(move |i| {
            let start = i * self.arity;
            &self.data[start..start + self.arity]
        })
    }

    /// The whole row-major buffer.
    pub fn data(&self) -> &[i32] {
        &self.data
    }

    /// Allocated capacity in `i32` words (watermark introspection).
    pub fn capacity_words(&self) -> usize {
        self.data.capacity()
    }

    /// If this batch's allocation exceeds `words`, discard its
    /// contents and shrink the buffer to at most `words` capacity —
    /// the completion slab's high-watermark trim, so one giant burst
    /// does not pin its peak allocation on a recycled slot forever.
    /// Batches at or under the watermark are left untouched (contents
    /// included), keeping steady-state traffic allocation-free.
    pub fn trim_to_words(&mut self, words: usize) {
        if self.data.capacity() > words {
            // shrink_to never goes below len, so drop contents first.
            self.data.clear();
            self.rows = 0;
            self.data.shrink_to(words);
        }
    }

    /// Explode into row vectors (adapter for row-shaped APIs like the
    /// overlay simulator and the PJRT engine).
    pub fn to_rows(&self) -> Vec<Vec<i32>> {
        self.iter().map(<[i32]>::to_vec).collect()
    }
}

impl fmt::Display for FlatBatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "FlatBatch[{} x {}]", self.rows, self.arity)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_index() {
        let mut b = FlatBatch::new(3);
        b.push(&[1, 2, 3]);
        b.push(&[4, 5, 6]);
        assert_eq!(b.n_rows(), 2);
        assert_eq!(b.arity(), 3);
        assert_eq!(b.row(0), &[1, 2, 3]);
        assert_eq!(b.row(1), &[4, 5, 6]);
        assert_eq!(b.data(), &[1, 2, 3, 4, 5, 6]);
        let rows: Vec<&[i32]> = b.iter().collect();
        assert_eq!(rows, vec![&[1, 2, 3][..], &[4, 5, 6][..]]);
    }

    #[test]
    fn from_rows_round_trips() {
        let rows = vec![vec![7, 8], vec![9, 10], vec![-1, i32::MIN]];
        let b = FlatBatch::from_rows(2, &rows);
        assert_eq!(b.to_rows(), rows);
    }

    #[test]
    fn from_flat_adopts_buffer() {
        let b = FlatBatch::from_flat(3, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(b.n_rows(), 2);
        assert_eq!(b.row(1), &[4, 5, 6]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn from_flat_rejects_ragged() {
        FlatBatch::from_flat(2, vec![1, 2, 3]);
    }

    #[test]
    fn reset_keeps_allocation_and_reshapes() {
        let mut b = FlatBatch::with_capacity(4, 16);
        for _ in 0..16 {
            b.push(&[0, 1, 2, 3]);
        }
        let cap = b.data.capacity();
        b.reset(2);
        assert_eq!(b.n_rows(), 0);
        assert_eq!(b.arity(), 2);
        assert!(b.data.capacity() >= cap.min(64));
        b.push(&[5, 6]);
        assert_eq!(b.row(0), &[5, 6]);
    }

    #[test]
    fn push_iter_counts_values() {
        let mut b = FlatBatch::new(2);
        b.push_iter([1, 2]);
        assert_eq!(b.row(0), &[1, 2]);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut b = FlatBatch::new(3);
        b.push(&[1, 2]);
    }

    #[test]
    fn resize_rows_and_row_mut_write_in_place() {
        let mut b = FlatBatch::new(2);
        b.resize_rows(3);
        assert_eq!(b.n_rows(), 3);
        assert_eq!(b.data(), &[0; 6]);
        b.row_mut(2).copy_from_slice(&[5, 6]);
        b.row_mut(0).copy_from_slice(&[1, 2]);
        assert_eq!(b.to_rows(), vec![vec![1, 2], vec![0, 0], vec![5, 6]]);
        // Shrinking keeps the shape well-defined.
        b.resize_rows(1);
        assert_eq!(b.to_rows(), vec![vec![1, 2]]);
    }

    #[test]
    fn extend_from_batch_is_one_copy() {
        let mut a = FlatBatch::from_rows(2, &[vec![1, 2]]);
        let b = FlatBatch::from_rows(2, &[vec![3, 4], vec![5, 6]]);
        a.extend_from_batch(&b);
        assert_eq!(a.n_rows(), 3);
        assert_eq!(a.data(), &[1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn trim_to_words_shrinks_only_oversized_buffers() {
        let mut b = FlatBatch::with_capacity(2, 4096);
        b.push(&[1, 2]);
        assert!(b.capacity_words() >= 8192);
        b.trim_to_words(64);
        assert!(b.capacity_words() <= 64, "oversized buffer must shrink");
        assert_eq!(b.n_rows(), 0, "trim discards contents when it fires");
        assert_eq!(b.arity(), 2, "shape survives the trim");
        // Under the watermark: contents and capacity are untouched.
        b.push(&[5, 6]);
        let cap = b.capacity_words();
        b.trim_to_words(64);
        assert_eq!(b.capacity_words(), cap);
        assert_eq!(b.to_rows(), vec![vec![5, 6]]);
    }

    #[test]
    fn empty_batch_has_shape() {
        let b = FlatBatch::new(5);
        assert!(b.is_empty());
        assert_eq!(b.arity(), 5);
        assert_eq!(b.iter().count(), 0);
    }
}
