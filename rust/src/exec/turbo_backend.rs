//! Tape-compiled "turbo" backend: the throughput substrate.
//!
//! Executes batches through the kernel's pre-compiled [`super::Tape`]
//! (built once at registry-compile time) with a per-backend reusable
//! [`super::TapeArena`] — the steady-state request path performs no
//! per-packet allocation, no graph traversal, and (same-kernel
//! traffic) no arena setup: the arena caches the resident tape's
//! constants by epoch. This is the serving-side expression of the
//! paper's thesis: compile the kernel onto the substrate **once**,
//! then stream packets through a flat instruction sequence at full
//! rate. Like `ref` it is functional-only (no fabric timing, no
//! context-switch cost); unlike `ref` it never touches the DFG at
//! execution time.
//!
//! The native [`Backend::execute_into`] is the zero-allocation entry:
//! workers reuse one [`ExecReport`] forever and the tape writes output
//! rows straight into its warm buffer.

use super::{
    validate_batch, Backend, Capabilities, CompiledKernel, ExecError, ExecReport, FlatBatch,
    TapeArena,
};

/// The tape-interpreter backend.
#[derive(Debug, Default)]
pub struct TurboBackend {
    /// Slot-major lane arena, reused across batches and kernels.
    arena: TapeArena,
    /// Packets executed (introspection / tests).
    pub executed: u64,
}

impl TurboBackend {
    pub fn new() -> TurboBackend {
        TurboBackend::default()
    }

    /// Current scratch arena size in bytes (tests: proves reuse).
    pub fn scratch_bytes(&self) -> usize {
        self.arena.scratch_bytes()
    }
}

impl Backend for TurboBackend {
    fn name(&self) -> &'static str {
        "turbo"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            cycle_accurate: false,
            needs_artifacts: false,
            models_context_switch: false,
            max_batch: None,
        }
    }

    fn execute(
        &mut self,
        kernel: &CompiledKernel,
        batch: &FlatBatch,
    ) -> Result<ExecReport, ExecError> {
        let mut report = ExecReport::default();
        self.execute_into(kernel, batch, &mut report)?;
        Ok(report)
    }

    /// Native zero-allocation path: reset the caller's output buffer
    /// in place (keeping its allocation) and stream the tape into it.
    fn execute_into(
        &mut self,
        kernel: &CompiledKernel,
        batch: &FlatBatch,
        report: &mut ExecReport,
    ) -> Result<(), ExecError> {
        validate_batch(kernel, batch)?;
        report.outputs.reset(kernel.n_outputs);
        kernel.tape.execute_into(batch, &mut self.arena, &mut report.outputs);
        report.switch_cycles = 0;
        report.fabric_cycles = None;
        self.executed += batch.n_rows() as u64;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dfg::eval;
    use crate::exec::KernelRegistry;
    use crate::util::prng::Rng;

    #[test]
    fn matches_oracle_across_the_suite() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let mut b = TurboBackend::new();
        let mut rng = Rng::new(2026);
        for name in bench_suite::all_names() {
            let k = reg.get(name).unwrap();
            let rows: Vec<Vec<i32>> = (0..37)
                .map(|_| (0..k.n_inputs).map(|_| rng.next_i32()).collect())
                .collect();
            let batch = FlatBatch::from_rows(k.n_inputs, &rows);
            let r = b.execute(k, &batch).unwrap();
            assert_eq!(r.switch_cycles, 0);
            assert_eq!(r.fabric_cycles, None);
            for (pkt, o) in rows.iter().zip(r.outputs.iter()) {
                assert_eq!(o, &eval(&k.dfg, pkt)[..], "{name}");
            }
        }
        assert_eq!(b.executed, 37 * bench_suite::all_names().len() as u64);
    }

    #[test]
    fn structured_errors_not_panics() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let k = reg.get("gradient").unwrap();
        let mut b = TurboBackend::new();
        assert!(matches!(
            b.execute(k, &FlatBatch::new(5)),
            Err(ExecError::EmptyBatch { .. })
        ));
        assert!(matches!(
            b.execute(k, &FlatBatch::from_rows(2, &[vec![1, 2]])),
            Err(ExecError::WrongArity { .. })
        ));
        assert_eq!(b.executed, 0);
    }

    #[test]
    fn scratch_grows_once_then_sticks() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let k = reg.get("poly6").unwrap();
        let mut b = TurboBackend::new();
        let batch = FlatBatch::from_rows(3, &[vec![1, 2, 3]]);
        b.execute(k, &batch).unwrap();
        let bytes = b.scratch_bytes();
        assert_eq!(bytes, k.tape.scratch_bytes());
        for _ in 0..5 {
            b.execute(k, &batch).unwrap();
        }
        assert_eq!(b.scratch_bytes(), bytes);
    }

    #[test]
    fn execute_into_reuses_one_report_across_kernels() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let mut b = TurboBackend::new();
        let mut report = ExecReport::default();
        for name in ["poly6", "gradient", "poly6"] {
            let k = reg.get(name).unwrap();
            let rows = vec![vec![2; k.n_inputs], vec![-9; k.n_inputs]];
            let batch = FlatBatch::from_rows(k.n_inputs, &rows);
            b.execute_into(k, &batch, &mut report).unwrap();
            assert_eq!(report.outputs.arity(), k.n_outputs, "{name}");
            for (pkt, o) in rows.iter().zip(report.outputs.iter()) {
                assert_eq!(o, &eval(&k.dfg, pkt)[..], "{name}");
            }
        }
        assert_eq!(b.executed, 6);
    }
}
