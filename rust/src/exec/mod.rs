//! Unified execution backend layer.
//!
//! The serving coordinator historically executed only through the AOT
//! PJRT engine, leaving the cycle-accurate overlay model — the actual
//! reproduction artifact — disconnected from the serving path. This
//! module defines one [`Backend`] contract with four interchangeable
//! execution substrates:
//!
//! * [`RefBackend`] — the functional DFG interpreter ([`crate::dfg::eval`]);
//!   the oracle, no hardware model;
//! * [`TurboBackend`] — the tape-compiled throughput substrate: each
//!   kernel is lowered once into a flat [`Tape`] of pre-resolved slot
//!   indices (the software analogue of the overlay's instruction
//!   stream) and batches run lane-chunked through a reusable scratch
//!   arena — the fast path for production serving;
//! * [`SimBackend`] — the cycle-accurate overlay ([`crate::arch::Overlay`] /
//!   [`crate::arch::Pipeline`]), including the daisy-chained context load
//!   ([`crate::arch::config_port`]) on every kernel switch;
//! * [`PjrtBackend`] — the PJRT engine over the AOT artifacts
//!   ([`crate::runtime::Engine`]).
//!
//! Batch I/O is **flat** end to end: requests and replies travel as
//! [`FlatBatch`] (one contiguous row-major `i32` buffer) rather than
//! `Vec<Vec<i32>>`, so the request side of the dispatch loop performs
//! no per-packet allocation (per-caller reply rows are the one
//! remaining per-packet `Vec`). Kernels are compiled **once** into an
//! [`Arc<CompiledKernel>`] registry ([`KernelRegistry`]) shared by
//! every worker, and interned as dense [`KernelId`]s so queues and
//! dispatch never touch kernel-name strings. Batch validation returns
//! structured [`ExecError`]s (never panics), and the fabric-timing
//! model ([`fabric_exec_cycles`]) is guarded against empty batches.

mod batch;
mod pjrt_backend;
mod ref_backend;
mod sim_backend;
mod tape;
mod turbo_backend;

pub use batch::FlatBatch;
pub use pjrt_backend::PjrtBackend;
pub use ref_backend::RefBackend;
pub use sim_backend::SimBackend;
pub use tape::{Tape, TapeArena, TapeOp, LANES};
pub use turbo_backend::TurboBackend;

use crate::bench_suite;
use crate::dfg::Dfg;
use crate::isa::ContextImage;
use crate::sched::{Program, Timing};
use anyhow::Result;
use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;
use std::str::FromStr;
use std::sync::Arc;

// ---------------------------------------------------------------------
// Compiled kernels
// ---------------------------------------------------------------------

/// Everything the serving path needs about one kernel, compiled once:
/// the normalized DFG (functional oracle), the scheduled program, the
/// timing model, the 40-bit context image and the flat op tape.
#[derive(Debug, Clone)]
pub struct CompiledKernel {
    pub name: String,
    pub dfg: Dfg,
    pub program: Program,
    /// Initiation interval in fabric cycles.
    pub ii: u32,
    /// End-to-end packet latency in fabric cycles.
    pub latency: u64,
    pub n_inputs: usize,
    pub n_outputs: usize,
    /// The kernel's 40-bit context stream.
    pub context: ContextImage,
    /// Context words == daisy-chain load cycles (one word per cycle).
    pub context_words: usize,
    /// Flat executable form for the turbo backend (DESIGN.md §3).
    pub tape: Tape,
}

impl CompiledKernel {
    /// Compile one kernel from its DFG.
    pub fn compile(g: Dfg) -> Result<CompiledKernel> {
        let program = Program::schedule(&g)?;
        let t = Timing::of(&program);
        let context = program.context_image()?;
        let context_words = context.load_cycles().map_err(|e| anyhow::anyhow!("{e}"))?;
        let tape = Tape::compile(&g, &program)?;
        Ok(CompiledKernel {
            name: g.name.clone(),
            n_inputs: g.inputs().len(),
            n_outputs: g.outputs().len(),
            ii: t.ii,
            latency: t.latency(),
            dfg: g,
            program,
            context,
            context_words,
            tape,
        })
    }

    /// Modeled context-switch time in microseconds at `freq_mhz`.
    pub fn switch_time_us(&self, freq_mhz: f64) -> f64 {
        self.context_words as f64 / freq_mhz
    }
}

/// Dense registry index for a compiled kernel. Interning names once at
/// submit time means queues, batches and worker context tracking move
/// a `u32` instead of allocating `String`s on every push.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct KernelId(pub u32);

impl KernelId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Display for KernelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "kernel#{}", self.0)
    }
}

/// Shared, immutable registry of compiled kernels (compile once, share
/// across workers via `Arc`). Kernels are stored dense, indexed by
/// [`KernelId`] in insertion order, with a name index for ingress.
#[derive(Debug, Default)]
pub struct KernelRegistry {
    kernels: Vec<Arc<CompiledKernel>>,
    by_name: BTreeMap<String, KernelId>,
}

impl KernelRegistry {
    /// Compile the full benchmark suite.
    pub fn compile_bench_suite() -> Result<KernelRegistry> {
        KernelRegistry::compile(bench_suite::load_all()?)
    }

    /// Registry over an explicit kernel set (tests, custom workloads).
    pub fn compile(graphs: Vec<Dfg>) -> Result<KernelRegistry> {
        let mut reg = KernelRegistry::default();
        for g in graphs {
            reg.insert(CompiledKernel::compile(g)?);
        }
        Ok(reg)
    }

    fn insert(&mut self, k: CompiledKernel) {
        match self.by_name.get(&k.name) {
            // Recompiling an existing name keeps its id stable.
            Some(&id) => self.kernels[id.index()] = Arc::new(k),
            None => {
                let id = KernelId(self.kernels.len() as u32);
                self.by_name.insert(k.name.clone(), id);
                self.kernels.push(Arc::new(k));
            }
        }
    }

    /// Intern a kernel name (ingress: resolve once, then move ids).
    pub fn id_of(&self, name: &str) -> Option<KernelId> {
        self.by_name.get(name).copied()
    }

    /// Kernel by dense id (dispatch hot path).
    pub fn kernel(&self, id: KernelId) -> Option<&Arc<CompiledKernel>> {
        self.kernels.get(id.index())
    }

    pub fn get(&self, name: &str) -> Option<&Arc<CompiledKernel>> {
        self.id_of(name).and_then(|id| self.kernel(id))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.by_name.contains_key(name)
    }

    /// Kernel names in id (insertion) order.
    pub fn names(&self) -> Vec<&str> {
        self.kernels.iter().map(|k| k.name.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.kernels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kernels.is_empty()
    }

    /// Kernels in id order.
    pub fn iter(&self) -> impl Iterator<Item = &Arc<CompiledKernel>> {
        self.kernels.iter()
    }
}

// ---------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------

/// Structured serving-path error: every invalid request shape is a
/// typed variant (not a panic, not a stringly-typed failure).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExecError {
    /// A batch with zero packets reached the execution layer; the
    /// fabric timing model (`latency + (n-1)*II`) is undefined for it.
    EmptyBatch { kernel: String },
    WrongArity {
        kernel: String,
        expected: usize,
        got: usize,
    },
    UnknownKernel(String),
    BatchTooLarge {
        kernel: String,
        got: usize,
        max: usize,
    },
    /// Substrate-specific failure (PJRT load/execute, cycle budget...).
    Backend {
        backend: &'static str,
        message: String,
    },
    /// The request's deadline budget was exhausted while it waited in
    /// the queue — the rows were evicted without ever executing
    /// (lazy expiry; see `coordinator::queue`).
    DeadlineExceeded { kernel: String },
}

impl fmt::Display for ExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecError::EmptyBatch { kernel } => {
                write!(f, "kernel '{kernel}': empty batch (no packets to execute)")
            }
            ExecError::WrongArity {
                kernel,
                expected,
                got,
            } => write!(f, "kernel '{kernel}' expects {expected} inputs, got {got}"),
            ExecError::UnknownKernel(name) => write!(f, "unknown kernel '{name}'"),
            ExecError::BatchTooLarge { kernel, got, max } => {
                write!(f, "kernel '{kernel}': batch of {got} exceeds backend max {max}")
            }
            ExecError::Backend { backend, message } => write!(f, "{backend} backend: {message}"),
            ExecError::DeadlineExceeded { kernel } => {
                write!(f, "kernel '{kernel}': deadline exceeded while queued")
            }
        }
    }
}

impl std::error::Error for ExecError {}

// ---------------------------------------------------------------------
// The backend contract
// ---------------------------------------------------------------------

/// What a backend can and cannot do — consulted by the coordinator for
/// batch sizing and by `serve` for fail-fast configuration checks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Capabilities {
    /// Results come from the cycle-accurate overlay model (fabric
    /// cycle counts in [`ExecReport`] are measured, not modeled).
    pub cycle_accurate: bool,
    /// Requires `make artifacts` output on disk.
    pub needs_artifacts: bool,
    /// Charges the daisy-chain context-load cost on kernel switches.
    pub models_context_switch: bool,
    /// Hard per-call batch limit, if any.
    pub max_batch: Option<usize>,
}

/// Result of one batch execution. `Default` is the empty report —
/// the starting state for the caller-owned report that
/// [`Backend::execute_into`] refills on every call.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ExecReport {
    /// One output row per input packet, in submission order.
    pub outputs: FlatBatch,
    /// Context-switch cycles charged for this call (0 when the kernel
    /// was already resident).
    pub switch_cycles: u64,
    /// Fabric cycles actually simulated (cycle-accurate backends only).
    pub fabric_cycles: Option<u64>,
}

/// One execution substrate. Workers own a `Box<dyn Backend>` each;
/// backends are deliberately **not** required to be `Send` (the PJRT
/// client is thread-local), so workers construct their own via
/// [`make_backend`] inside the worker thread.
pub trait Backend {
    /// Stable short name (`"ref"`, `"sim"`, `"pjrt"`, `"turbo"`).
    fn name(&self) -> &'static str;

    fn capabilities(&self) -> Capabilities;

    /// Execute one kernel-affine batch. Implementations must validate
    /// the batch shape (see [`validate_batch`]) and never panic on bad
    /// requests.
    fn execute(
        &mut self,
        kernel: &CompiledKernel,
        batch: &FlatBatch,
    ) -> Result<ExecReport, ExecError>;

    /// Execute into a caller-owned [`ExecReport`], refilling it in
    /// place (`report.outputs` is reset to this kernel's output arity,
    /// then one row is appended per input packet).
    ///
    /// This is the worker hot path: a worker thread keeps one report
    /// forever and round-trips it through here, so a backend with a
    /// native implementation (ref, turbo) performs **zero allocations
    /// per batch** in steady state — the report's buffers are warm
    /// after the first large batch. The default implementation simply
    /// delegates to [`Backend::execute`] and moves the result over
    /// (correct for every backend; sim and pjrt allocate inside their
    /// substrates anyway, so a native path would buy them nothing).
    ///
    /// On `Err` the report's contents are unspecified; callers must
    /// not read it without a preceding `Ok`.
    fn execute_into(
        &mut self,
        kernel: &CompiledKernel,
        batch: &FlatBatch,
        report: &mut ExecReport,
    ) -> Result<(), ExecError> {
        *report = self.execute(kernel, batch)?;
        Ok(())
    }
}

/// Shared request validation: non-empty batch, exact input arity. The
/// flat shape makes arity a property of the whole batch, so this is
/// one comparison rather than a per-packet scan.
pub fn validate_batch(kernel: &CompiledKernel, batch: &FlatBatch) -> Result<(), ExecError> {
    if batch.is_empty() {
        return Err(ExecError::EmptyBatch {
            kernel: kernel.name.clone(),
        });
    }
    if batch.arity() != kernel.n_inputs {
        return Err(ExecError::WrongArity {
            kernel: kernel.name.clone(),
            expected: kernel.n_inputs,
            got: batch.arity(),
        });
    }
    Ok(())
}

/// Modeled fabric execution time for a batch of `n` packets:
/// pipeline fill (`latency`) plus `n - 1` further initiations at `II`.
/// Guarded: `n == 0` is a structured error, not a `u64` underflow.
pub fn fabric_exec_cycles(kernel: &CompiledKernel, n: usize) -> Result<u64, ExecError> {
    if n == 0 {
        return Err(ExecError::EmptyBatch {
            kernel: kernel.name.clone(),
        });
    }
    Ok(kernel.latency + (n as u64 - 1) * kernel.ii as u64)
}

// ---------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------

/// The four execution substrates, CLI-selectable via `--backend`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Ref,
    Sim,
    Pjrt,
    Turbo,
}

impl BackendKind {
    pub const ALL: [BackendKind; 4] = [
        BackendKind::Ref,
        BackendKind::Sim,
        BackendKind::Pjrt,
        BackendKind::Turbo,
    ];

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Ref => "ref",
            BackendKind::Sim => "sim",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Turbo => "turbo",
        }
    }

    /// Whether this substrate needs `make artifacts` output on disk
    /// (known before construction; mirrors
    /// [`Capabilities::needs_artifacts`]).
    pub fn needs_artifacts(self) -> bool {
        matches!(self, BackendKind::Pjrt)
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The one name→kind conversion (use `s.parse::<BackendKind>()`; the
/// former `from_name` duplicate is gone).
impl FromStr for BackendKind {
    type Err = String;

    fn from_str(s: &str) -> Result<BackendKind, String> {
        BackendKind::ALL
            .iter()
            .copied()
            .find(|k| k.name() == s)
            .ok_or_else(|| format!("unknown backend '{s}' (expected one of: ref, sim, pjrt, turbo)"))
    }
}

/// Build a backend instance. Called from inside each worker thread —
/// the returned box is intentionally not `Send`. Backends receive
/// compiled kernels per call, so only construction inputs appear here:
/// `artifacts_dir` feeds the PJRT engine, `sim_replicas` /
/// `sim_fifo_capacity` size the simulated overlay; the service builder
/// owns these knobs (there is no separate backend-config struct).
pub fn make_backend(
    kind: BackendKind,
    artifacts_dir: &Path,
    sim_replicas: usize,
    sim_fifo_capacity: usize,
) -> Result<Box<dyn Backend>> {
    Ok(match kind {
        BackendKind::Ref => Box::new(RefBackend::new()),
        BackendKind::Sim => Box::new(SimBackend::new(sim_replicas, sim_fifo_capacity)?),
        BackendKind::Pjrt => Box::new(PjrtBackend::load(artifacts_dir)?),
        BackendKind::Turbo => Box::new(TurboBackend::new()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::eval;
    use crate::util::prng::Rng;

    fn registry() -> KernelRegistry {
        KernelRegistry::compile_bench_suite().unwrap()
    }

    fn batch_of(rows: &[Vec<i32>]) -> FlatBatch {
        FlatBatch::from_rows(rows[0].len(), rows)
    }

    #[test]
    fn registry_compiles_all_kernels_once() {
        let reg = registry();
        assert_eq!(reg.len(), bench_suite::all_names().len());
        let grad = reg.get("gradient").unwrap();
        assert_eq!(grad.n_inputs, 5);
        assert_eq!(grad.ii, 11);
        assert_eq!(grad.latency, 24);
        assert!(grad.context_words > 0);
        assert_eq!(grad.tape.len(), grad.dfg.n_ops());
        assert!(reg.get("nonesuch").is_none());
    }

    #[test]
    fn kernel_ids_are_dense_and_stable() {
        let reg = registry();
        // Ids follow bench-suite insertion order, densely from 0.
        for (i, name) in bench_suite::all_names().iter().enumerate() {
            let id = reg.id_of(name).unwrap();
            assert_eq!(id.index(), i, "{name}");
            assert_eq!(reg.kernel(id).unwrap().name, *name);
        }
        assert_eq!(reg.names(), bench_suite::all_names());
        assert!(reg.id_of("nonesuch").is_none());
        assert!(reg.kernel(KernelId(999)).is_none());
        assert_eq!(format!("{}", KernelId(3)), "kernel#3");
    }

    #[test]
    fn fabric_cycles_guarded_against_empty_batch() {
        let reg = registry();
        let k = reg.get("gradient").unwrap();
        // The unguarded formula `latency + (n-1)*ii` underflows at n=0.
        assert_eq!(
            fabric_exec_cycles(k, 0),
            Err(ExecError::EmptyBatch {
                kernel: "gradient".into()
            })
        );
        assert_eq!(fabric_exec_cycles(k, 1).unwrap(), k.latency);
        assert_eq!(
            fabric_exec_cycles(k, 5).unwrap(),
            k.latency + 4 * k.ii as u64
        );
    }

    #[test]
    fn validate_batch_rejects_bad_shapes() {
        let reg = registry();
        let k = reg.get("gradient").unwrap();
        assert!(matches!(
            validate_batch(k, &FlatBatch::new(5)),
            Err(ExecError::EmptyBatch { .. })
        ));
        assert_eq!(
            validate_batch(k, &batch_of(&[vec![1, 2]])),
            Err(ExecError::WrongArity {
                kernel: "gradient".into(),
                expected: 5,
                got: 2
            })
        );
        assert!(validate_batch(k, &batch_of(&[vec![0; 5]])).is_ok());
    }

    #[test]
    fn backend_kind_round_trips_names() {
        for k in BackendKind::ALL {
            assert_eq!(k.name().parse::<BackendKind>().unwrap(), k);
        }
        assert!("tpu".parse::<BackendKind>().is_err());
        let err = "tpu".parse::<BackendKind>().unwrap_err();
        assert!(err.contains("unknown backend 'tpu'"), "{err}");
    }

    fn test_backend(kind: BackendKind) -> Result<Box<dyn Backend>> {
        make_backend(kind, Path::new("artifacts"), 1, 4096)
    }

    #[test]
    fn artifact_free_backends_construct_via_factory() {
        let reg = registry();
        for kind in [BackendKind::Ref, BackendKind::Sim, BackendKind::Turbo] {
            let mut b = test_backend(kind).unwrap();
            assert_eq!(b.name(), kind.name());
            let k = reg.get("gradient").unwrap();
            let r = b.execute(k, &batch_of(&[vec![3, 5, 2, 7, 1]])).unwrap();
            assert_eq!(r.outputs.to_rows(), vec![vec![36]]);
        }
    }

    #[test]
    fn pjrt_backend_fails_cleanly_without_artifacts() {
        assert!(
            make_backend(BackendKind::Pjrt, Path::new("/definitely/not/here"), 1, 4096).is_err()
        );
    }

    /// Capabilities claims are consistent with [`BackendKind`] and
    /// with observed behavior.
    #[test]
    fn capabilities_are_consistent() {
        let b = test_backend(BackendKind::Ref).unwrap();
        assert!(!b.capabilities().cycle_accurate);
        assert!(!b.capabilities().needs_artifacts);
        assert!(!BackendKind::Ref.needs_artifacts());
        let b = test_backend(BackendKind::Turbo).unwrap();
        assert!(!b.capabilities().cycle_accurate);
        assert!(!b.capabilities().needs_artifacts);
        assert!(!BackendKind::Turbo.needs_artifacts());
        let b = test_backend(BackendKind::Sim).unwrap();
        let caps = b.capabilities();
        assert!(caps.cycle_accurate);
        assert!(caps.models_context_switch);
        assert!(!caps.needs_artifacts);
        assert!(!BackendKind::Sim.needs_artifacts());
        assert!(BackendKind::Pjrt.needs_artifacts());
    }

    /// `execute_into` refills one caller-owned report — natively for
    /// ref/turbo, via the default delegation for sim — and always
    /// agrees with `execute`, across kernels of differing arity (the
    /// report reshape path) and on error inputs (no panic, no stale
    /// reads required).
    #[test]
    fn execute_into_agrees_with_execute_and_reuses_the_report() {
        let reg = registry();
        let mut rng = Rng::new(0x51AB);
        for kind in [BackendKind::Ref, BackendKind::Turbo, BackendKind::Sim] {
            let mut b = test_backend(kind).unwrap();
            let mut report = ExecReport::default();
            for name in ["poly6", "gradient", "chebyshev"] {
                let k = reg.get(name).unwrap();
                // A LANES-straddling row count exercises partial chunks.
                let rows: Vec<Vec<i32>> = (0..21)
                    .map(|_| (0..k.n_inputs).map(|_| rng.next_i32()).collect())
                    .collect();
                let batch = FlatBatch::from_rows(k.n_inputs, &rows);
                let want = b.execute(k, &batch).unwrap();
                b.execute_into(k, &batch, &mut report).unwrap();
                assert_eq!(report.outputs, want.outputs, "{name} ({kind})");
                assert_eq!(report.outputs.n_rows(), rows.len(), "{name} ({kind})");
                assert_eq!(report.outputs.arity(), k.n_outputs, "{name} ({kind})");
            }
            // Shape errors surface structurally through the _into path.
            let k = reg.get("gradient").unwrap();
            assert!(matches!(
                b.execute_into(k, &FlatBatch::new(5), &mut report),
                Err(ExecError::EmptyBatch { .. })
            ));
            assert!(matches!(
                b.execute_into(k, &FlatBatch::from_rows(2, &[vec![1, 2]]), &mut report),
                Err(ExecError::WrongArity { .. })
            ));
        }
    }

    /// The three artifact-free substrates agree bit-for-bit on every
    /// benchmark kernel (the serving-layer analogue of the arch-level
    /// oracle tests), and the sim backend charges context-switch
    /// cycles exactly once per kernel change.
    #[test]
    fn backends_agree_and_switch_costs_are_charged() {
        let reg = Arc::new(registry());
        let mut rb = RefBackend::new();
        let mut tb = TurboBackend::new();
        let mut sb = SimBackend::new(1, 4096).unwrap();
        let mut rng = Rng::new(2024);
        for name in bench_suite::all_names() {
            let k = reg.get(name).unwrap();
            let mut batch = FlatBatch::with_capacity(k.n_inputs, 6);
            for _ in 0..6 {
                batch.push_iter((0..k.n_inputs).map(|_| rng.range_i64(-2000, 2000) as i32));
            }
            let want: Vec<Vec<i32>> = batch.iter().map(|p| eval(&k.dfg, p)).collect();
            let r = rb.execute(k, &batch).unwrap();
            assert_eq!(r.outputs.to_rows(), want, "{name} (ref)");
            assert_eq!(r.switch_cycles, 0);
            let t = tb.execute(k, &batch).unwrap();
            assert_eq!(t.outputs.to_rows(), want, "{name} (turbo)");
            let s = sb.execute(k, &batch).unwrap();
            assert_eq!(s.outputs.to_rows(), want, "{name} (sim)");
            // First visit to this kernel: the daisy-chain load runs.
            assert_eq!(s.switch_cycles, k.context_words as u64, "{name}");
            assert!(s.fabric_cycles.unwrap_or(0) > 0, "{name}");
            // Re-execute without switching: no context cost.
            let one = FlatBatch::from_rows(k.n_inputs, &[batch.row(0).to_vec()]);
            let s2 = sb.execute(k, &one).unwrap();
            assert_eq!(s2.switch_cycles, 0, "{name}");
        }
    }
}
