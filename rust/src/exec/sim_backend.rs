//! Cycle-accurate overlay backend: serves requests through the
//! simulated DSP48E1 pipeline (paper Figs. 2–4).
//!
//! * Configured [`Overlay`]s are built **once per kernel** and cached;
//!   a context switch re-points the backend at the cached overlay
//!   instead of reconstructing pipelines from scratch.
//! * Every switch clocks the kernel's full 40-bit context stream
//!   through the daisy-chained config port
//!   ([`config_port::load_image`]), so the modeled switch cost is the
//!   *simulated* word-per-cycle load, not just an analytical count.
//! * Batches run through the replicated pipelines round-robin; the
//!   report carries the fabric cycles actually simulated.
//!
//! The overlay model streams packets as row vectors, so this backend
//! explodes the incoming [`FlatBatch`] at its boundary — acceptable
//! because the simulator spends thousands of modeled cycles per
//! packet; the flat fast path belongs to `ref`/`turbo`.

use super::{
    validate_batch, Backend, Capabilities, CompiledKernel, ExecError, ExecReport, FlatBatch,
};
use crate::arch::{config_port, Overlay};
use anyhow::Result;
use std::collections::btree_map::Entry;
use std::collections::BTreeMap;

/// The cycle-accurate overlay backend.
#[derive(Debug)]
pub struct SimBackend {
    /// Pipeline replicas per overlay (paper Fig. 4 replication).
    replicas: usize,
    fifo_capacity: usize,
    /// Kernel name -> configured overlay, built once and reused.
    overlays: BTreeMap<String, Overlay>,
    /// Currently resident kernel context.
    context: Option<String>,
    /// Cumulative simulated context-switch cycles.
    pub total_switch_cycles: u64,
    /// Cumulative simulated execution cycles.
    pub total_fabric_cycles: u64,
}

impl SimBackend {
    pub fn new(replicas: usize, fifo_capacity: usize) -> Result<SimBackend> {
        anyhow::ensure!(replicas >= 1, "sim backend needs at least one replica");
        anyhow::ensure!(fifo_capacity >= 64, "sim FIFO capacity unreasonably small");
        Ok(SimBackend {
            replicas,
            fifo_capacity,
            overlays: BTreeMap::new(),
            context: None,
            total_switch_cycles: 0,
            total_fabric_cycles: 0,
        })
    }

    /// The kernel currently configured on the fabric.
    pub fn resident_context(&self) -> Option<&str> {
        self.context.as_deref()
    }

    fn backend_err(message: String) -> ExecError {
        ExecError::Backend {
            backend: "sim",
            message,
        }
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            cycle_accurate: true,
            needs_artifacts: false,
            models_context_switch: true,
            max_batch: None,
        }
    }

    fn execute(
        &mut self,
        kernel: &CompiledKernel,
        batch: &FlatBatch,
    ) -> Result<ExecReport, ExecError> {
        validate_batch(kernel, batch)?;
        // Context switch: clock the 40-bit stream through the daisy
        // chain (verifies the round-trip and yields the cycle count).
        let mut switch_cycles = 0u64;
        if self.context.as_deref() != Some(kernel.name.as_str()) {
            let loaded = config_port::load_image(&kernel.context)
                .map_err(|e| Self::backend_err(format!("context load: {e}")))?;
            switch_cycles = loaded.cycles;
            self.total_switch_cycles += switch_cycles;
            self.context = Some(kernel.name.clone());
        }
        // Configured overlays are cached across switches (the hardware
        // analogue: per-kernel context images stay in the config BRAM).
        // Single `entry` lookup instead of contains_key + insert + get.
        let ov = match self.overlays.entry(kernel.name.clone()) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(v) => {
                let ov = Overlay::new(&kernel.program, self.replicas, self.fifo_capacity)
                    .map_err(|e| Self::backend_err(format!("building overlay: {e}")))?;
                v.insert(ov)
            }
        };
        // Generous per-batch cycle budget: fill + n initiations + slack.
        let budget = kernel.latency + (batch.n_rows() as u64 + 4) * kernel.ii as u64 + 1024;
        let before = ov.batch_cycles();
        let rows = batch.to_rows();
        let outputs = ov
            .run(&rows, budget)
            .map_err(|e| Self::backend_err(format!("{e}")))?;
        let fabric_cycles = ov.batch_cycles().saturating_sub(before);
        self.total_fabric_cycles += fabric_cycles;
        Ok(ExecReport {
            outputs: FlatBatch::from_rows(kernel.n_outputs, &outputs),
            switch_cycles,
            fabric_cycles: Some(fabric_cycles),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::eval;
    use crate::exec::KernelRegistry;

    fn rows(r: &[Vec<i32>]) -> FlatBatch {
        FlatBatch::from_rows(r[0].len(), r)
    }

    #[test]
    fn matches_oracle_and_reuses_overlays_across_switches() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let grad = reg.get("gradient").unwrap();
        let cheb = reg.get("chebyshev").unwrap();
        let mut b = SimBackend::new(1, 4096).unwrap();
        // gradient -> chebyshev -> gradient: two kernels, three switches.
        let r1 = b.execute(grad, &rows(&[vec![3, 5, 2, 7, 1]])).unwrap();
        assert_eq!(r1.outputs.to_rows(), vec![vec![36]]);
        assert_eq!(r1.switch_cycles, grad.context_words as u64);
        let r2 = b.execute(cheb, &rows(&[vec![2]])).unwrap();
        assert_eq!(r2.outputs.to_rows(), vec![eval(&cheb.dfg, &[2])]);
        assert_eq!(r2.switch_cycles, cheb.context_words as u64);
        let r3 = b.execute(grad, &rows(&[vec![1, 1, 1, 1, 1]])).unwrap();
        assert_eq!(r3.outputs.to_rows(), vec![vec![0]]);
        // Switching back re-charges the load but reuses the overlay.
        assert_eq!(r3.switch_cycles, grad.context_words as u64);
        assert_eq!(b.overlays.len(), 2);
        assert_eq!(
            b.total_switch_cycles,
            2 * grad.context_words as u64 + cheb.context_words as u64
        );
        assert_eq!(b.resident_context(), Some("gradient"));
    }

    #[test]
    fn replication_preserves_order() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let k = reg.get("mibench").unwrap();
        let mut b = SimBackend::new(3, 4096).unwrap();
        let batch: Vec<Vec<i32>> = (0..10).map(|i| vec![i, i + 1, i + 2]).collect();
        let r = b.execute(k, &rows(&batch)).unwrap();
        for (pkt, got) in batch.iter().zip(r.outputs.iter()) {
            assert_eq!(got, &eval(&k.dfg, pkt)[..]);
        }
    }

    #[test]
    fn structured_errors_for_bad_batches() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        let k = reg.get("gradient").unwrap();
        let mut b = SimBackend::new(1, 4096).unwrap();
        assert!(matches!(
            b.execute(k, &FlatBatch::new(5)),
            Err(ExecError::EmptyBatch { .. })
        ));
        assert!(matches!(
            b.execute(k, &rows(&[vec![1]])),
            Err(ExecError::WrongArity { .. })
        ));
        // Failed validation must not have charged a switch.
        assert_eq!(b.total_switch_cycles, 0);
    }

    #[test]
    fn rejects_degenerate_configuration() {
        assert!(SimBackend::new(0, 4096).is_err());
        assert!(SimBackend::new(1, 1).is_err());
    }
}
