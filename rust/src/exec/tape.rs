//! Tape compilation: a scheduled kernel lowered to a flat linear op
//! tape — the software analogue of the overlay's 40-bit instruction
//! stream (DESIGN.md §3).
//!
//! At registry-compile time each kernel's [`Program`] is walked stage
//! by stage and every arithmetic instruction becomes one [`TapeOp`]
//! with **pre-resolved scratch-slot indices**: no node lookups, no
//! `match` on node kinds, no bounds-derived indirection left on the
//! request path. Bypass instructions vanish entirely — in a flat
//! scratch arena a value is addressable from every "stage", so the
//! inter-FU data movement the hardware pays for is free here. Tape
//! length therefore tracks the kernel's context words minus its bypass
//! words (`poly6`: 44 tape ops vs 59 context instruction words).
//!
//! Execution is batch-major and lane-chunked: packets are processed
//! [`LANES`] at a time against a slot-major scratch arena
//! (`scratch[slot * LANES + lane]`), and each tape op is lowered to an
//! **explicitly vectorized** per-op kernel: the [`LANES`]-wide block is
//! split into two [`CHUNK`]-wide halves and each half is computed as a
//! fixed-size array literal of independent lane results — the exact
//! shape LLVM turns into vector instructions at `opt-level 3` without
//! having to prove anything about loop trip counts or aliasing (the
//! `&[i32; N]` array references carry both facts in the type). Slot
//! indices are strictly increasing (`dst > a, b` by construction),
//! which both proves the tape race-free and lets the interpreter split
//! the arena into disjoint read/write regions without unsafe code.
//!
//! The arena itself lives in a [`TapeArena`] owned by the caller
//! (worker thread / backend) and carries the tape's **epoch**: each
//! compiled tape gets a unique generation number, and the constant
//! preload — the only per-call arena setup — runs only when the arena
//! last served a *different* tape. Steady-state same-kernel traffic
//! therefore does no arena writes at all before the gather loop.

use super::FlatBatch;
use crate::dfg::{Dfg, NodeId, NodeKind, OpKind};
use crate::sched::Program;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Packets processed per scratch block. 16 lanes of i32 fill one or
/// two cache lines per slot and give each tape op two full 256-bit
/// vector registers' worth of independent work.
pub const LANES: usize = 16;

/// Width of the explicit vector kernels: 8 × i32 = one 256-bit vector
/// register. A [`LANES`] block is two chunks.
const CHUNK: usize = 8;

/// Global tape-generation counter. Starts at 1 so a fresh
/// [`TapeArena`] (`loaded_epoch == 0`) can never alias a real tape.
static TAPE_EPOCH: AtomicU64 = AtomicU64::new(1);

/// One pre-resolved tape instruction: `slot[dst] = op(slot[a], slot[b])`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TapeOp {
    pub op: OpKind,
    pub a: u32,
    pub b: u32,
    pub dst: u32,
}

/// Caller-owned execution state for [`Tape::execute_into`]: the
/// slot-major scratch arena plus the epoch of the tape whose constants
/// are currently resident. One arena per worker thread serves every
/// kernel forever — it is sized (and its constant slots preloaded)
/// only when the executing tape changes.
#[derive(Debug, Default)]
pub struct TapeArena {
    /// Slot-major lane storage: `scratch[slot * LANES + lane]`.
    scratch: Vec<i32>,
    /// Epoch of the tape whose shape + constants are loaded (0 = none).
    loaded_epoch: u64,
}

impl TapeArena {
    pub fn new() -> TapeArena {
        TapeArena::default()
    }

    /// Current arena size in bytes (tests: proves reuse, no regrowth).
    pub fn scratch_bytes(&self) -> usize {
        self.scratch.len() * std::mem::size_of::<i32>()
    }

    /// Epoch of the tape currently resident (tests: proves the
    /// constant preload is skipped on same-kernel traffic).
    pub fn loaded_epoch(&self) -> u64 {
        self.loaded_epoch
    }
}

/// A kernel compiled to its flat executable form.
#[derive(Debug, Clone, PartialEq)]
pub struct Tape {
    ops: Vec<TapeOp>,
    /// Constants preloaded into the arena: (slot, value).
    consts: Vec<(u32, i32)>,
    /// Slots emitted per packet, in output declaration order.
    outputs: Vec<u32>,
    n_inputs: usize,
    /// Scratch slots per lane (inputs + consts + one per op).
    n_slots: usize,
    /// Unique generation number keying [`TapeArena`] residency.
    epoch: u64,
}

// ---------------------------------------------------------------------
// Explicit vector kernels
// ---------------------------------------------------------------------

/// Build one per-op lane kernel: a LANES-wide block computed as two
/// CHUNK-wide array literals of independent lane results. `$f` is the
/// scalar lane function; the array-literal form (rather than a lane
/// loop) is what LLVM reliably lowers to vector instructions.
macro_rules! lane_kernel {
    ($name:ident, $f:expr) => {
        #[inline(always)]
        fn $name(d: &mut [i32; LANES], a: &[i32; LANES], b: &[i32; LANES]) {
            #[inline(always)]
            fn v8(d: &mut [i32; CHUNK], a: &[i32; CHUNK], b: &[i32; CHUNK]) {
                let f = $f;
                *d = [
                    f(a[0], b[0]),
                    f(a[1], b[1]),
                    f(a[2], b[2]),
                    f(a[3], b[3]),
                    f(a[4], b[4]),
                    f(a[5], b[5]),
                    f(a[6], b[6]),
                    f(a[7], b[7]),
                ];
            }
            let (d_lo, d_hi) = d.split_at_mut(CHUNK);
            let (a_lo, a_hi) = a.split_at(CHUNK);
            let (b_lo, b_hi) = b.split_at(CHUNK);
            v8(
                d_lo.try_into().unwrap(),
                a_lo.try_into().unwrap(),
                b_lo.try_into().unwrap(),
            );
            v8(
                d_hi.try_into().unwrap(),
                a_hi.try_into().unwrap(),
                b_hi.try_into().unwrap(),
            );
        }
    };
}

lane_kernel!(lanes_add, |x: i32, y: i32| x.wrapping_add(y));
lane_kernel!(lanes_sub, |x: i32, y: i32| x.wrapping_sub(y));
lane_kernel!(lanes_mul, |x: i32, y: i32| x.wrapping_mul(y));
lane_kernel!(lanes_and, |x: i32, y: i32| x & y);
lane_kernel!(lanes_or, |x: i32, y: i32| x | y);
lane_kernel!(lanes_xor, |x: i32, y: i32| x ^ y);

impl Tape {
    /// Lower a scheduled program to a tape. Walking the schedule (not
    /// the raw DFG) keeps the tape's issue order identical to the
    /// overlay's — stage by stage, each stage's ops in issue order —
    /// so tape results are bit-for-bit the pipeline's results by
    /// construction, not by coincidence of traversal order.
    pub fn compile(g: &Dfg, p: &Program) -> Result<Tape> {
        let mut slot: BTreeMap<NodeId, u32> = BTreeMap::new();
        let mut next = 0u32;
        // Inputs occupy the first slots, in declaration order — the
        // gather loop streams them straight from the FlatBatch rows.
        let inputs = g.inputs();
        for &id in &inputs {
            slot.insert(id, next);
            next += 1;
        }
        let mut consts: Vec<(u32, i32)> = Vec::new();
        let mut ops: Vec<TapeOp> = Vec::new();
        for st in &p.stages {
            for &op_id in &st.ops {
                let n = g.node(op_id);
                let opk = match n.kind {
                    NodeKind::Op { op } => op,
                    _ => bail!("tape: scheduled node {op_id} is not an op"),
                };
                let mut arg_slot = |arg: NodeId| -> Result<u32> {
                    if let Some(&s) = slot.get(&arg) {
                        return Ok(s);
                    }
                    // First use of a constant: give it a slot below the
                    // destination (keeps `dst > a, b`).
                    if let NodeKind::Const { value } = g.node(arg).kind {
                        let s = next;
                        next += 1;
                        slot.insert(arg, s);
                        consts.push((s, value));
                        return Ok(s);
                    }
                    bail!("tape: operand {arg} used before production")
                };
                let a = arg_slot(n.args[0])?;
                let b = arg_slot(n.args[1])?;
                let dst = next;
                next += 1;
                slot.insert(op_id, dst);
                debug_assert!(a < dst && b < dst);
                ops.push(TapeOp { op: opk, a, b, dst });
            }
        }
        if ops.is_empty() {
            bail!("tape: kernel '{}' has no operations", g.name);
        }
        let mut outputs = Vec::new();
        for out_id in g.outputs() {
            let src = g.node(out_id).args[0];
            match slot.get(&src) {
                Some(&s) => outputs.push(s),
                // A constant emitted directly as an output never passes
                // Program::schedule today (consts are not final-stage
                // emissions), but lowering stays total over valid DFGs:
                // give it a slot, the preload covers it.
                None => {
                    if let NodeKind::Const { value } = g.node(src).kind {
                        let s = next;
                        next += 1;
                        consts.push((s, value));
                        outputs.push(s);
                    } else {
                        bail!("tape: output {out_id} reads unproduced value {src}");
                    }
                }
            }
        }
        Ok(Tape {
            ops,
            consts,
            outputs,
            n_inputs: inputs.len(),
            n_slots: next as usize,
            epoch: TAPE_EPOCH.fetch_add(1, Ordering::Relaxed),
        })
    }

    /// Tape length in ops (compare against the kernel's context words).
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }

    pub fn n_outputs(&self) -> usize {
        self.outputs.len()
    }

    /// This tape's generation number (unique per compile; keys
    /// [`TapeArena`] constant-preload residency).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The flat op stream in issue order (verifier / mutation-harness
    /// introspection).
    pub fn ops(&self) -> &[TapeOp] {
        &self.ops
    }

    /// Constant preloads as `(slot, value)` pairs.
    pub fn consts(&self) -> &[(u32, i32)] {
        &self.consts
    }

    /// Output slots in declaration order.
    pub fn outputs(&self) -> &[u32] {
        &self.outputs
    }

    /// Assemble a tape directly from its parts, bypassing
    /// [`Tape::compile`]. The parts are **not** validated — this exists
    /// for `verify::mutate`, whose whole point is constructing broken
    /// tapes the static verifier must reject; executing an invalid
    /// tape panics on its safe slice indexing rather than corrupting
    /// memory. Gets a fresh epoch so a stale arena never masks the
    /// mutation.
    pub fn from_raw_parts(
        ops: Vec<TapeOp>,
        consts: Vec<(u32, i32)>,
        outputs: Vec<u32>,
        n_inputs: usize,
        n_slots: usize,
    ) -> Tape {
        Tape {
            ops,
            consts,
            outputs,
            n_inputs,
            n_slots,
            epoch: TAPE_EPOCH.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// Bytes of scratch arena one executor lane block needs.
    pub fn scratch_bytes(&self) -> usize {
        self.n_slots * LANES * std::mem::size_of::<i32>()
    }

    /// Size the arena for this tape and preload its constant slots,
    /// unless this tape is already resident. Constant slots are written
    /// by nothing else (inputs gather below them, ops write above), so
    /// residency makes the whole preload skippable.
    fn load_arena(&self, arena: &mut TapeArena) {
        if arena.loaded_epoch == self.epoch {
            debug_assert_eq!(arena.scratch.len(), self.n_slots * LANES);
            return;
        }
        arena.scratch.clear();
        arena.scratch.resize(self.n_slots * LANES, 0);
        for &(s, v) in &self.consts {
            let base = s as usize * LANES;
            arena.scratch[base..base + LANES].fill(v);
        }
        arena.loaded_epoch = self.epoch;
    }

    /// Execute a batch through the tape, appending one output row per
    /// input row to `out`.
    ///
    /// `arena` is the caller's reusable execution state — typically one
    /// per worker thread, serving every kernel for the thread's whole
    /// life. It is resized and its constant slots preloaded only when
    /// the executing tape changes ([`TapeArena::loaded_epoch`]), so the
    /// steady-state call performs **no allocation and no arena setup**:
    /// gather, the vectorized op kernels, scatter. `out` must already
    /// be shaped to this kernel's output arity; rows are appended, so
    /// callers reusing one output batch `reset` it between calls.
    pub fn execute_into(&self, batch: &FlatBatch, arena: &mut TapeArena, out: &mut FlatBatch) {
        debug_assert_eq!(batch.arity(), self.n_inputs, "tape input arity");
        debug_assert_eq!(out.arity(), self.n_outputs(), "tape output arity");
        self.load_arena(arena);
        let n = batch.n_rows();
        let n_in = self.n_inputs;
        let data = batch.data();
        out.reserve_rows(n);
        let scratch = arena.scratch.as_mut_slice();
        let mut row = 0usize;
        while row < n {
            let chunk = LANES.min(n - row);
            // Gather: packet words -> slot-major lanes. Lanes past the
            // chunk keep stale values; every op wraps, so garbage lanes
            // are computed and discarded rather than branched around.
            for i in 0..n_in {
                let base = i * LANES;
                for l in 0..chunk {
                    scratch[base + l] = data[(row + l) * n_in + i];
                }
            }
            // The tape proper: one explicitly vectorized kernel call
            // per op, with the op dispatch hoisted out of the lanes.
            // `dst > a, b` lets split_at_mut prove disjointness; the
            // fixed-size array refs carry the trip count in the type.
            for t in &self.ops {
                let (lo, hi) = scratch.split_at_mut(t.dst as usize * LANES);
                let d: &mut [i32; LANES] = (&mut hi[..LANES]).try_into().unwrap();
                let a_base = t.a as usize * LANES;
                let b_base = t.b as usize * LANES;
                let a: &[i32; LANES] = lo[a_base..a_base + LANES].try_into().unwrap();
                let b: &[i32; LANES] = lo[b_base..b_base + LANES].try_into().unwrap();
                match t.op {
                    OpKind::Add => lanes_add(d, a, b),
                    OpKind::Sub => lanes_sub(d, a, b),
                    OpKind::Mul => lanes_mul(d, a, b),
                    OpKind::And => lanes_and(d, a, b),
                    OpKind::Or => lanes_or(d, a, b),
                    OpKind::Xor => lanes_xor(d, a, b),
                }
            }
            // Scatter: lane results -> row-major output packets.
            for l in 0..chunk {
                out.push_iter(self.outputs.iter().map(|&s| scratch[s as usize * LANES + l]));
            }
            row += chunk;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dfg::eval;
    use crate::util::prng::Rng;

    fn tape_for(name: &str) -> (Dfg, Tape) {
        let g = bench_suite::load(name).unwrap();
        let p = Program::schedule(&g).unwrap();
        let t = Tape::compile(&g, &p).unwrap();
        (g, t)
    }

    fn run(t: &Tape, g: &Dfg, rows: &[Vec<i32>]) -> Vec<Vec<i32>> {
        let batch = FlatBatch::from_rows(g.inputs().len(), rows);
        let mut arena = TapeArena::new();
        let mut out = FlatBatch::new(g.outputs().len());
        t.execute_into(&batch, &mut arena, &mut out);
        out.to_rows()
    }

    #[test]
    fn gradient_tape_shape() {
        let (g, t) = tape_for("gradient");
        assert_eq!(t.len(), g.n_ops());
        assert_eq!(t.n_inputs(), 5);
        assert_eq!(t.n_outputs(), 1);
        // slots = inputs + consts + ops.
        assert_eq!(t.n_slots(), 5 + t.consts.len() + t.len());
        // Slot indices strictly increase along the tape.
        for op in &t.ops {
            assert!(op.a < op.dst && op.b < op.dst);
        }
    }

    #[test]
    fn tape_drops_bypasses_relative_to_context() {
        // chebyshev's deep chain is bypass-heavy: 13 context instruction
        // words but only 7 arithmetic ops reach the tape.
        let (g, t) = tape_for("chebyshev");
        let p = Program::schedule(&g).unwrap();
        let ctx_words = p.context_image().unwrap().n_instrs();
        assert_eq!(t.len(), 7);
        assert_eq!(ctx_words, 13);
        assert!(t.len() <= ctx_words);
    }

    #[test]
    fn matches_oracle_on_every_benchmark() {
        let mut rng = Rng::new(0x7A9E);
        for name in bench_suite::all_names() {
            let (g, t) = tape_for(name);
            let n_in = g.inputs().len();
            let rows: Vec<Vec<i32>> = (0..53) // deliberately not a LANES multiple
                .map(|_| (0..n_in).map(|_| rng.next_i32()).collect())
                .collect();
            let got = run(&t, &g, &rows);
            for (pkt, o) in rows.iter().zip(&got) {
                assert_eq!(o, &eval(&g, pkt), "{name} diverged on {pkt:?}");
            }
        }
    }

    #[test]
    fn wrapping_extremes_bitexact() {
        // i32::MIN propagation and (1<<17)^2 wraparound — the edges the
        // DSP model is also tested against.
        let (g, t) = tape_for("poly6");
        let rows = vec![
            vec![i32::MIN, i32::MAX, -1],
            vec![1 << 17, 1 << 17, 1 << 17],
            vec![0, 0, 0],
            vec![i32::MIN, i32::MIN, i32::MIN],
        ];
        let got = run(&t, &g, &rows);
        for (pkt, o) in rows.iter().zip(&got) {
            assert_eq!(o, &eval(&g, pkt));
        }
    }

    #[test]
    fn partial_chunks_do_not_leak_stale_lanes() {
        let (g, t) = tape_for("mibench");
        // Two passes over the same arena with different row counts:
        // stale lanes from the longer pass must not surface. The arena
        // stays resident between calls (same tape), so this also pins
        // down that the skipped constant preload cannot go stale.
        let mut arena = TapeArena::new();
        let long: Vec<Vec<i32>> = (0..LANES + 3).map(|k| vec![k as i32, 2, 3]).collect();
        let short = vec![vec![9, 9, 9]];
        let b_long = FlatBatch::from_rows(3, &long);
        let b_short = FlatBatch::from_rows(3, &short);
        let mut out = FlatBatch::new(1);
        t.execute_into(&b_long, &mut arena, &mut out);
        let mut out2 = FlatBatch::new(1);
        t.execute_into(&b_short, &mut arena, &mut out2);
        assert_eq!(out2.to_rows(), vec![eval(&g, &short[0])]);
        assert_eq!(out.n_rows(), LANES + 3);
    }

    #[test]
    fn arena_is_reusable_across_kernels() {
        let mut arena = TapeArena::new();
        for name in ["poly6", "chebyshev", "gradient"] {
            let (g, t) = tape_for(name);
            let n_in = g.inputs().len();
            let rows = vec![vec![3; n_in], vec![-7; n_in]];
            let batch = FlatBatch::from_rows(n_in, &rows);
            let mut out = FlatBatch::new(g.outputs().len());
            t.execute_into(&batch, &mut arena, &mut out);
            for (pkt, o) in rows.iter().zip(out.to_rows().iter()) {
                assert_eq!(o, &eval(&g, pkt), "{name}");
            }
        }
    }

    #[test]
    fn arena_residency_is_keyed_by_epoch() {
        let (g, t) = tape_for("poly6");
        let (g2, t2) = tape_for("chebyshev");
        assert_ne!(t.epoch(), t2.epoch(), "every compile gets a fresh epoch");
        let mut arena = TapeArena::new();
        assert_eq!(arena.loaded_epoch(), 0, "fresh arena aliases no tape");
        let batch = FlatBatch::from_rows(3, &[vec![4, -2, 11]]);
        let mut out = FlatBatch::new(1);
        t.execute_into(&batch, &mut arena, &mut out);
        assert_eq!(arena.loaded_epoch(), t.epoch());
        assert_eq!(arena.scratch_bytes(), t.scratch_bytes());
        // Same tape again: resident, the preload is skipped, results
        // stay oracle-exact (constants were not clobbered).
        let mut out2 = FlatBatch::new(1);
        t.execute_into(&batch, &mut arena, &mut out2);
        assert_eq!(out2.to_rows(), vec![eval(&g, &[4, -2, 11])]);
        assert_eq!(arena.loaded_epoch(), t.epoch());
        // Switch kernels: the arena reloads for the new tape and the
        // new kernel's constants land correctly.
        let row2 = vec![5; g2.inputs().len()];
        let b2 = FlatBatch::from_rows(g2.inputs().len(), &[row2.clone()]);
        let mut out3 = FlatBatch::new(g2.outputs().len());
        t2.execute_into(&b2, &mut arena, &mut out3);
        assert_eq!(out3.to_rows(), vec![eval(&g2, &row2)]);
        assert_eq!(arena.loaded_epoch(), t2.epoch());
        // A recompile of the same kernel is a new epoch: the arena
        // must not treat it as resident.
        let (_, t_again) = tape_for("poly6");
        assert_ne!(t_again.epoch(), t.epoch());
    }
}
