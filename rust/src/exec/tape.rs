//! Tape compilation: a scheduled kernel lowered to a flat linear op
//! tape — the software analogue of the overlay's 40-bit instruction
//! stream (DESIGN.md §3).
//!
//! At registry-compile time each kernel's [`Program`] is walked stage
//! by stage and every arithmetic instruction becomes one [`TapeOp`]
//! with **pre-resolved scratch-slot indices**: no node lookups, no
//! `match` on node kinds, no bounds-derived indirection left on the
//! request path. Bypass instructions vanish entirely — in a flat
//! scratch arena a value is addressable from every "stage", so the
//! inter-FU data movement the hardware pays for is free here. Tape
//! length therefore tracks the kernel's context words minus its bypass
//! words (`poly6`: 44 tape ops vs 59 context instruction words).
//!
//! Execution is batch-major and lane-chunked: packets are processed
//! [`LANES`] at a time against a slot-major scratch arena
//! (`scratch[slot * LANES + lane]`), so each tape op becomes one tight
//! fixed-trip loop over the lane block — the shape auto-vectorizers
//! want. Slot indices are strictly increasing (`dst > a, b` by
//! construction), which both proves the tape race-free and lets the
//! interpreter split the arena into disjoint read/write regions
//! without unsafe code.

use super::FlatBatch;
use crate::dfg::{Dfg, NodeId, NodeKind, OpKind};
use crate::sched::Program;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Packets processed per scratch block. 16 lanes of i32 fill one or
/// two cache lines per slot and give the compiler a full vector
/// register's worth of independent work per tape op.
pub const LANES: usize = 16;

/// One pre-resolved tape instruction: `slot[dst] = op(slot[a], slot[b])`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TapeOp {
    pub op: OpKind,
    pub a: u32,
    pub b: u32,
    pub dst: u32,
}

/// A kernel compiled to its flat executable form.
#[derive(Debug, Clone, PartialEq)]
pub struct Tape {
    ops: Vec<TapeOp>,
    /// Constants preloaded into the arena: (slot, value).
    consts: Vec<(u32, i32)>,
    /// Slots emitted per packet, in output declaration order.
    outputs: Vec<u32>,
    n_inputs: usize,
    /// Scratch slots per lane (inputs + consts + one per op).
    n_slots: usize,
}

impl Tape {
    /// Lower a scheduled program to a tape. Walking the schedule (not
    /// the raw DFG) keeps the tape's issue order identical to the
    /// overlay's — stage by stage, each stage's ops in issue order —
    /// so tape results are bit-for-bit the pipeline's results by
    /// construction, not by coincidence of traversal order.
    pub fn compile(g: &Dfg, p: &Program) -> Result<Tape> {
        let mut slot: BTreeMap<NodeId, u32> = BTreeMap::new();
        let mut next = 0u32;
        // Inputs occupy the first slots, in declaration order — the
        // gather loop streams them straight from the FlatBatch rows.
        let inputs = g.inputs();
        for &id in &inputs {
            slot.insert(id, next);
            next += 1;
        }
        let mut consts: Vec<(u32, i32)> = Vec::new();
        let mut ops: Vec<TapeOp> = Vec::new();
        for st in &p.stages {
            for &op_id in &st.ops {
                let n = g.node(op_id);
                let opk = match n.kind {
                    NodeKind::Op { op } => op,
                    _ => bail!("tape: scheduled node {op_id} is not an op"),
                };
                let mut arg_slot = |arg: NodeId| -> Result<u32> {
                    if let Some(&s) = slot.get(&arg) {
                        return Ok(s);
                    }
                    // First use of a constant: give it a slot below the
                    // destination (keeps `dst > a, b`).
                    if let NodeKind::Const { value } = g.node(arg).kind {
                        let s = next;
                        next += 1;
                        slot.insert(arg, s);
                        consts.push((s, value));
                        return Ok(s);
                    }
                    bail!("tape: operand {arg} used before production")
                };
                let a = arg_slot(n.args[0])?;
                let b = arg_slot(n.args[1])?;
                let dst = next;
                next += 1;
                slot.insert(op_id, dst);
                debug_assert!(a < dst && b < dst);
                ops.push(TapeOp { op: opk, a, b, dst });
            }
        }
        if ops.is_empty() {
            bail!("tape: kernel '{}' has no operations", g.name);
        }
        let mut outputs = Vec::new();
        for out_id in g.outputs() {
            let src = g.node(out_id).args[0];
            match slot.get(&src) {
                Some(&s) => outputs.push(s),
                // A constant emitted directly as an output never passes
                // Program::schedule today (consts are not final-stage
                // emissions), but lowering stays total over valid DFGs:
                // give it a slot, the preload covers it.
                None => {
                    if let NodeKind::Const { value } = g.node(src).kind {
                        let s = next;
                        next += 1;
                        consts.push((s, value));
                        outputs.push(s);
                    } else {
                        bail!("tape: output {out_id} reads unproduced value {src}");
                    }
                }
            }
        }
        Ok(Tape {
            ops,
            consts,
            outputs,
            n_inputs: inputs.len(),
            n_slots: next as usize,
        })
    }

    /// Tape length in ops (compare against the kernel's context words).
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }

    pub fn n_outputs(&self) -> usize {
        self.outputs.len()
    }

    /// Bytes of scratch arena one executor lane block needs.
    pub fn scratch_bytes(&self) -> usize {
        self.n_slots * LANES * std::mem::size_of::<i32>()
    }

    /// Execute a batch through the tape, appending one output row per
    /// input row to `out`. `scratch` is the caller's reusable arena —
    /// resized on first use, never reallocated in steady state. `out`
    /// must already be shaped to this kernel's output arity.
    pub fn execute_into(&self, batch: &FlatBatch, scratch: &mut Vec<i32>, out: &mut FlatBatch) {
        debug_assert_eq!(batch.arity(), self.n_inputs, "tape input arity");
        debug_assert_eq!(out.arity(), self.n_outputs(), "tape output arity");
        scratch.resize(self.n_slots * LANES, 0);
        // Constants load once per call: their slots are written by
        // nothing else (inputs gather below them, ops write above).
        for &(s, v) in &self.consts {
            let base = s as usize * LANES;
            scratch[base..base + LANES].fill(v);
        }
        let n = batch.n_rows();
        let n_in = self.n_inputs;
        let data = batch.data();
        out.reserve_rows(n);
        let mut row = 0usize;
        while row < n {
            let chunk = LANES.min(n - row);
            // Gather: packet words -> slot-major lanes. Lanes past the
            // chunk keep stale values; every op wraps, so garbage lanes
            // are computed and discarded rather than branched around.
            for i in 0..n_in {
                let base = i * LANES;
                for l in 0..chunk {
                    scratch[base + l] = data[(row + l) * n_in + i];
                }
            }
            // The tape proper: one fixed-trip lane loop per op, with
            // the op match hoisted out of the lane loop.
            for t in &self.ops {
                let (lo, hi) = scratch.split_at_mut(t.dst as usize * LANES);
                let d = &mut hi[..LANES];
                let a = &lo[t.a as usize * LANES..t.a as usize * LANES + LANES];
                let b = &lo[t.b as usize * LANES..t.b as usize * LANES + LANES];
                match t.op {
                    OpKind::Add => {
                        for l in 0..LANES {
                            d[l] = a[l].wrapping_add(b[l]);
                        }
                    }
                    OpKind::Sub => {
                        for l in 0..LANES {
                            d[l] = a[l].wrapping_sub(b[l]);
                        }
                    }
                    OpKind::Mul => {
                        for l in 0..LANES {
                            d[l] = a[l].wrapping_mul(b[l]);
                        }
                    }
                    OpKind::And => {
                        for l in 0..LANES {
                            d[l] = a[l] & b[l];
                        }
                    }
                    OpKind::Or => {
                        for l in 0..LANES {
                            d[l] = a[l] | b[l];
                        }
                    }
                    OpKind::Xor => {
                        for l in 0..LANES {
                            d[l] = a[l] ^ b[l];
                        }
                    }
                }
            }
            // Scatter: lane results -> row-major output packets.
            for l in 0..chunk {
                out.push_iter(self.outputs.iter().map(|&s| scratch[s as usize * LANES + l]));
            }
            row += chunk;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dfg::eval;
    use crate::util::prng::Rng;

    fn tape_for(name: &str) -> (Dfg, Tape) {
        let g = bench_suite::load(name).unwrap();
        let p = Program::schedule(&g).unwrap();
        let t = Tape::compile(&g, &p).unwrap();
        (g, t)
    }

    fn run(t: &Tape, g: &Dfg, rows: &[Vec<i32>]) -> Vec<Vec<i32>> {
        let batch = FlatBatch::from_rows(g.inputs().len(), rows);
        let mut scratch = Vec::new();
        let mut out = FlatBatch::new(g.outputs().len());
        t.execute_into(&batch, &mut scratch, &mut out);
        out.to_rows()
    }

    #[test]
    fn gradient_tape_shape() {
        let (g, t) = tape_for("gradient");
        assert_eq!(t.len(), g.n_ops());
        assert_eq!(t.n_inputs(), 5);
        assert_eq!(t.n_outputs(), 1);
        // slots = inputs + consts + ops.
        assert_eq!(t.n_slots(), 5 + t.consts.len() + t.len());
        // Slot indices strictly increase along the tape.
        for op in &t.ops {
            assert!(op.a < op.dst && op.b < op.dst);
        }
    }

    #[test]
    fn tape_drops_bypasses_relative_to_context() {
        // chebyshev's deep chain is bypass-heavy: 13 context instruction
        // words but only 7 arithmetic ops reach the tape.
        let (g, t) = tape_for("chebyshev");
        let p = Program::schedule(&g).unwrap();
        let ctx_words = p.context_image().unwrap().n_instrs();
        assert_eq!(t.len(), 7);
        assert_eq!(ctx_words, 13);
        assert!(t.len() <= ctx_words);
    }

    #[test]
    fn matches_oracle_on_every_benchmark() {
        let mut rng = Rng::new(0x7A9E);
        for name in bench_suite::all_names() {
            let (g, t) = tape_for(name);
            let n_in = g.inputs().len();
            let rows: Vec<Vec<i32>> = (0..53) // deliberately not a LANES multiple
                .map(|_| (0..n_in).map(|_| rng.next_i32()).collect())
                .collect();
            let got = run(&t, &g, &rows);
            for (pkt, o) in rows.iter().zip(&got) {
                assert_eq!(o, &eval(&g, pkt), "{name} diverged on {pkt:?}");
            }
        }
    }

    #[test]
    fn wrapping_extremes_bitexact() {
        // i32::MIN propagation and (1<<17)^2 wraparound — the edges the
        // DSP model is also tested against.
        let (g, t) = tape_for("poly6");
        let rows = vec![
            vec![i32::MIN, i32::MAX, -1],
            vec![1 << 17, 1 << 17, 1 << 17],
            vec![0, 0, 0],
            vec![i32::MIN, i32::MIN, i32::MIN],
        ];
        let got = run(&t, &g, &rows);
        for (pkt, o) in rows.iter().zip(&got) {
            assert_eq!(o, &eval(&g, pkt));
        }
    }

    #[test]
    fn partial_chunks_do_not_leak_stale_lanes() {
        let (g, t) = tape_for("mibench");
        // Two passes over the same scratch with different row counts:
        // stale lanes from the longer pass must not surface.
        let mut scratch = Vec::new();
        let long: Vec<Vec<i32>> = (0..LANES + 3).map(|k| vec![k as i32, 2, 3]).collect();
        let short = vec![vec![9, 9, 9]];
        let b_long = FlatBatch::from_rows(3, &long);
        let b_short = FlatBatch::from_rows(3, &short);
        let mut out = FlatBatch::new(1);
        t.execute_into(&b_long, &mut scratch, &mut out);
        let mut out2 = FlatBatch::new(1);
        t.execute_into(&b_short, &mut scratch, &mut out2);
        assert_eq!(out2.to_rows(), vec![eval(&g, &short[0])]);
        assert_eq!(out.n_rows(), LANES + 3);
    }

    #[test]
    fn scratch_is_reusable_across_kernels() {
        let mut scratch = Vec::new();
        for name in ["poly6", "chebyshev", "gradient"] {
            let (g, t) = tape_for(name);
            let n_in = g.inputs().len();
            let rows = vec![vec![3; n_in], vec![-7; n_in]];
            let batch = FlatBatch::from_rows(n_in, &rows);
            let mut out = FlatBatch::new(g.outputs().len());
            t.execute_into(&batch, &mut scratch, &mut out);
            for (pkt, o) in rows.iter().zip(out.to_rows().iter()) {
                assert_eq!(o, &eval(&g, pkt), "{name}");
            }
        }
    }
}
