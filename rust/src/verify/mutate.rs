//! Adversarial mutation harness for the static verifier.
//!
//! The verifier's job is *completeness*: no corrupted artifact that
//! misbehaves at runtime may pass. This module manufactures the
//! corruption — lowered tapes with bumped slots, dropped ops, swapped
//! issue order, truncated output routes; committed interchange JSON
//! with the same classes of damage — and the integration suite
//! (`rust/tests/verify.rs`) cross-checks every mutant against the
//! ref/turbo differential oracle: anything the oracle shows
//! misbehaving must be rejected statically.
//!
//! Tape mutants are built through [`Tape::from_raw_parts`], which
//! deliberately skips validation. Every mutation changes at least one
//! tape field, and `check_tape_against` diffs all fields against a
//! fresh lowering — so every tape mutant is rejected, a strict
//! superset of the zero-false-negative requirement.
//!
//! Artifact mutants carry a [`must_reject`](ArtifactMutant::must_reject)
//! flag: structural damage to the `schedule` section must fail
//! verification, while a *semantically consistent* rewrite of the
//! `dfg` section (a constant with a different value, recompiled
//! consistently) legitimately verifies clean — the document then
//! describes a different, but well-formed, kernel on which the ref and
//! turbo backends still agree.

use crate::exec::{CompiledKernel, Tape, TapeOp};
use crate::util::json::Json;
use crate::util::prng::Rng;

/// One corrupted tape plus a description of the damage.
#[derive(Debug, Clone)]
pub struct TapeMutant {
    pub tape: Tape,
    pub desc: String,
}

/// One corrupted artifact document.
#[derive(Debug, Clone)]
pub struct ArtifactMutant {
    pub doc: Json,
    pub desc: String,
    /// Structural corruption the verifier is required to reject.
    /// `false` marks semantically-consistent rewrites that may pass.
    pub must_reject: bool,
}

/// Number of distinct tape-mutation classes [`tape_mutants`] draws
/// from (kept public so tests can demand coverage of each).
pub const TAPE_MUTATION_KINDS: usize = 10;

fn rebuild(
    k: &CompiledKernel,
    ops: Vec<TapeOp>,
    consts: Vec<(u32, i32)>,
    outputs: Vec<u32>,
    n_slots: usize,
) -> Tape {
    Tape::from_raw_parts(ops, consts, outputs, k.tape.n_inputs(), n_slots)
}

/// Generate one tape mutant of the given kind, or `None` when the
/// kernel is too small for that mutation (e.g. a single-op tape has
/// no pair to swap).
pub fn tape_mutant(k: &CompiledKernel, kind: usize, rng: &mut Rng) -> Option<TapeMutant> {
    let t = &k.tape;
    let ops = t.ops().to_vec();
    let consts = t.consts().to_vec();
    let outputs = t.outputs().to_vec();
    let n_slots = t.n_slots();
    let (tape, desc) = match kind % TAPE_MUTATION_KINDS {
        // Slot bumps: nudge one field of one op.
        0 => {
            let i = rng.index(ops.len());
            let mut ops = ops;
            ops[i].dst += 1;
            let d = format!("op {i}: dst slot bumped to {}", ops[i].dst);
            (rebuild(k, ops, consts, outputs, n_slots), d)
        }
        1 => {
            let i = rng.index(ops.len());
            let mut ops = ops;
            ops[i].a = n_slots as u32; // out-of-range read
            let d = format!("op {i}: a slot set out of range ({n_slots})");
            (rebuild(k, ops, consts, outputs, n_slots), d)
        }
        2 => {
            let i = rng.index(ops.len());
            let mut ops = ops;
            ops[i].b += 1;
            let d = format!("op {i}: b slot bumped to {}", ops[i].b);
            (rebuild(k, ops, consts, outputs, n_slots), d)
        }
        // Dropped op.
        3 => {
            if ops.len() < 2 {
                return None;
            }
            let i = rng.index(ops.len());
            let mut ops = ops;
            ops.remove(i);
            let d = format!("op {i} dropped");
            (rebuild(k, ops, consts, outputs, n_slots), d)
        }
        // Swapped issue order ("swapped cycles" at tape granularity).
        4 => {
            if ops.len() < 2 {
                return None;
            }
            let i = rng.index(ops.len() - 1);
            let j = i + 1 + rng.index(ops.len() - i - 1);
            let mut ops = ops;
            ops.swap(i, j);
            let d = format!("ops {i} and {j} swapped");
            (rebuild(k, ops, consts, outputs, n_slots), d)
        }
        // Truncated output route.
        5 => {
            let mut outputs = outputs;
            outputs.pop();
            let d = "last output route truncated".to_string();
            (rebuild(k, ops, consts, outputs, n_slots), d)
        }
        // Output route bumped (possibly out of range).
        6 => {
            let i = rng.index(outputs.len());
            let mut outputs = outputs;
            outputs[i] += 1;
            let d = format!("output {i} route bumped to slot {}", outputs[i]);
            (rebuild(k, ops, consts, outputs, n_slots), d)
        }
        // Constant drift (invisible to bounds checks; the recompile
        // diff must catch it).
        7 => {
            if consts.is_empty() {
                return None;
            }
            let i = rng.index(consts.len());
            let mut consts = consts;
            consts[i].1 = consts[i].1.wrapping_add(1);
            let d = format!("const {i} value drifted");
            (rebuild(k, ops, consts, outputs, n_slots), d)
        }
        // Arena shrunk under the tape.
        8 => {
            let d = format!("n_slots shrunk to {}", n_slots - 1);
            (rebuild(k, ops, consts, outputs, n_slots - 1), d)
        }
        // Opcode swap: structurally identical, semantically different.
        _ => {
            let i = rng.index(ops.len());
            let mut ops = ops;
            let all = crate::dfg::OpKind::ALL;
            let cur = all.iter().position(|&o| o == ops[i].op).unwrap_or(0);
            ops[i].op = all[(cur + 1) % all.len()];
            let d = format!("op {i} opcode swapped to {}", ops[i].op.name());
            (rebuild(k, ops, consts, outputs, n_slots), d)
        }
    };
    Some(TapeMutant {
        tape,
        desc: format!("{}: {desc}", k.name),
    })
}

/// Generate `n` random tape mutants for one compiled kernel, cycling
/// through every mutation class.
pub fn tape_mutants(k: &CompiledKernel, rng: &mut Rng, n: usize) -> Vec<TapeMutant> {
    let mut out = Vec::with_capacity(n);
    let mut kind = 0;
    while out.len() < n {
        if let Some(m) = tape_mutant(k, kind, rng) {
            out.push(m);
        }
        kind += 1;
        if kind > n * TAPE_MUTATION_KINDS {
            break; // kernel too small for the remaining classes
        }
    }
    out
}

// ---------------------------------------------------------------------
// Artifact (interchange JSON) mutants
// ---------------------------------------------------------------------

fn obj_mut<'a>(v: &'a mut Json, key: &str) -> Option<&'a mut Json> {
    match v {
        Json::Obj(m) => m.get_mut(key),
        _ => None,
    }
}

fn arr_mut(v: &mut Json) -> Option<&mut Vec<Json>> {
    match v {
        Json::Arr(a) => Some(a),
        _ => None,
    }
}

fn bump_int(v: &mut Json) -> bool {
    if let Json::Int(i) = v {
        *i += 1;
        return true;
    }
    false
}

/// Number of distinct artifact-mutation classes.
pub const ARTIFACT_MUTATION_KINDS: usize = 10;

/// Generate one artifact mutant of the given kind from a pristine
/// interchange document, or `None` when inapplicable.
pub fn artifact_mutant(doc: &Json, kind: usize, rng: &mut Rng) -> Option<ArtifactMutant> {
    let mut m = doc.clone();
    let n_stages = doc.get("schedule").get("stages").as_arr()?.len();
    let stage = rng.index(n_stages);
    let (desc, must_reject) = match kind % ARTIFACT_MUTATION_KINDS {
        0 => {
            bump_int(obj_mut(obj_mut(&mut m, "schedule")?, "ii")?).then_some(())?;
            ("schedule.ii bumped".to_string(), true)
        }
        1 => {
            bump_int(obj_mut(obj_mut(&mut m, "schedule")?, "latency")?).then_some(())?;
            ("schedule.latency bumped".to_string(), true)
        }
        2 => {
            bump_int(obj_mut(obj_mut(&mut m, "schedule")?, "n_stages")?).then_some(())?;
            ("schedule.n_stages bumped".to_string(), true)
        }
        3 => {
            let stages = arr_mut(obj_mut(obj_mut(&mut m, "schedule")?, "stages")?)?;
            let ops = arr_mut(obj_mut(&mut stages[stage], "ops")?)?;
            if ops.is_empty() {
                return None;
            }
            ops.remove(rng.index(ops.len()));
            (format!("stage {stage}: op dropped"), true)
        }
        4 => {
            if n_stages < 2 {
                return None;
            }
            let stages = arr_mut(obj_mut(obj_mut(&mut m, "schedule")?, "stages")?)?;
            let i = rng.index(n_stages - 1);
            stages.swap(i, i + 1);
            (format!("stages {i} and {} swapped", i + 1), true)
        }
        5 => {
            let stages = arr_mut(obj_mut(obj_mut(&mut m, "schedule")?, "stages")?)?;
            let arrivals = arr_mut(obj_mut(&mut stages[stage], "arrivals")?)?;
            if arrivals.is_empty() {
                return None;
            }
            arrivals.pop();
            (format!("stage {stage}: arrivals truncated"), true)
        }
        6 => {
            let order = arr_mut(obj_mut(obj_mut(&mut m, "schedule")?, "output_order")?)?;
            let i = rng.index(order.len());
            bump_int(obj_mut(&mut order[i], "pos")?).then_some(())?;
            (format!("output_order[{i}].pos bumped"), true)
        }
        7 => {
            let stages = arr_mut(obj_mut(obj_mut(&mut m, "schedule")?, "stages")?)?;
            let consts = arr_mut(obj_mut(&mut stages[stage], "consts")?)?;
            if consts.is_empty() {
                return None;
            }
            let i = rng.index(consts.len());
            bump_int(obj_mut(&mut consts[i], "value")?).then_some(())?;
            (format!("stage {stage}: const {i} value bumped"), true)
        }
        8 => {
            // Dangling node reference in the dfg section: point an op
            // arg past the end of the node list.
            let nodes = arr_mut(obj_mut(obj_mut(&mut m, "dfg")?, "nodes")?)?;
            let n_nodes = nodes.len() as i64;
            let arg0 = nodes
                .iter_mut()
                .find_map(|n| obj_mut(n, "args").and_then(arr_mut))?
                .first_mut()?;
            *arg0 = Json::Int(n_nodes);
            ("dfg: op arg pointed past the node list".to_string(), true)
        }
        // Semantically-consistent rewrite: a const node's value
        // changes, the schedule section is regenerated to match by the
        // caller being *unable* to — so this one mutates dfg+schedule
        // coherently by bumping the value in both places when present;
        // if the schedule carries no copy, the verifier still rejects
        // the stale schedule, so only emit when both sides updated.
        _ => {
            let nodes = arr_mut(obj_mut(obj_mut(&mut m, "dfg")?, "nodes")?)?;
            let mut old_value = None;
            for n in nodes.iter_mut() {
                if n.get("kind").as_str() == Some("const") {
                    if let Some(v) = obj_mut(n, "value") {
                        if let Json::Int(i) = v {
                            old_value = Some(*i);
                            *i += 1;
                        }
                        break;
                    }
                }
            }
            let old = old_value?;
            // Update every schedule-side copy of that constant so the
            // document stays self-consistent.
            let stages = arr_mut(obj_mut(obj_mut(&mut m, "schedule")?, "stages")?)?;
            for st in stages.iter_mut() {
                if let Some(consts) = obj_mut(st, "consts").and_then(arr_mut) {
                    for c in consts.iter_mut() {
                        if c.get("value").as_i64() == Some(old) {
                            if let Some(v) = obj_mut(c, "value") {
                                *v = Json::Int(old + 1);
                            }
                        }
                    }
                }
            }
            (
                "dfg+schedule: const value rewritten coherently".to_string(),
                false,
            )
        }
    };
    Some(ArtifactMutant {
        doc: m,
        desc,
        must_reject,
    })
}

/// Generate `n` artifact mutants from a pristine document, cycling
/// through every mutation class.
pub fn artifact_mutants(doc: &Json, rng: &mut Rng, n: usize) -> Vec<ArtifactMutant> {
    let mut out = Vec::with_capacity(n);
    let mut kind = 0;
    while out.len() < n {
        if let Some(m) = artifact_mutant(doc, kind, rng) {
            out.push(m);
        }
        kind += 1;
        if kind > n * ARTIFACT_MUTATION_KINDS {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::sched::{program_to_json, Program};
    use crate::verify;

    #[test]
    fn every_tape_mutant_is_rejected() {
        let mut rng = Rng::new(0xC0FFEE);
        for name in bench_suite::all_names() {
            let k = CompiledKernel::compile(bench_suite::load(name).unwrap()).unwrap();
            for m in tape_mutants(&k, &mut rng, 2 * TAPE_MUTATION_KINDS) {
                assert!(
                    verify::check_tape_against(&k.name, &k.dfg, &k.program, &m.tape).is_err(),
                    "mutant passed verification: {}",
                    m.desc
                );
            }
        }
    }

    #[test]
    fn structural_artifact_mutants_are_rejected() {
        let mut rng = Rng::new(0xBADF00D);
        let g = bench_suite::load("gradient").unwrap();
        let p = Program::schedule(&g).unwrap();
        let doc = program_to_json(&g, &p);
        verify::verify_artifact_json("gradient", &doc).unwrap();
        let mutants = artifact_mutants(&doc, &mut rng, 2 * ARTIFACT_MUTATION_KINDS);
        assert!(!mutants.is_empty());
        for m in mutants {
            let verdict = verify::verify_artifact_json("gradient", &m.doc);
            if m.must_reject {
                assert!(verdict.is_err(), "structural mutant passed: {}", m.desc);
            }
        }
    }
}
