//! Static verification of compiled kernels (DESIGN.md §12).
//!
//! The overlay compiles each kernel DFG once — schedule, 40-bit
//! context image, flat op tape — and then replays the artifact
//! millions of times. A single bad artifact (a tape slot out of
//! range, a def-after-use schedule, a context that decodes to a
//! different op sequence) silently corrupts every subsequent packet.
//! This module is the static counterpart to the runtime's
//! differential oracles: it proves, per kernel, that
//!
//! * the **DFG** is well-formed ([`check_dfg`]): acyclic, every node
//!   reference resolved, arity consistent, outputs declared;
//! * the **schedule** is legal ([`check_schedule`]): 1-based
//!   contiguous stage numbering within the linear FU array, every
//!   value defined before use across stages, register-file and
//!   instruction-memory bounds respected, instructions re-derivable
//!   from the scheduled ops, and output routing pointing at exactly
//!   the DFG's output values;
//! * the **tape** is safe ([`check_tape_against`]): every slot index
//!   below the arena size, constant and input slots never written,
//!   every scratch slot covered exactly once, and the whole tape
//!   equal field-for-field to a fresh lowering of the schedule — so
//!   the SIMD interpreter's bounds assumptions are proved, not
//!   assumed, and *any* tape corruption is rejected (zero false
//!   negatives by construction);
//! * the **context image** is consistent ([`check_context`]): valid
//!   under the ISA depth limits, byte round-trip stable, equal to a
//!   fresh encoding, and executing the same op sequence the tape
//!   encodes.
//!
//! [`verify_kernel`] runs all four; [`verify_registry`] covers a whole
//! compiled registry (the `OverlayService` builder gate); and
//! [`verify_artifact_str`] / [`verify_artifacts_dir`] validate the
//! committed `benchmarks/dfg/*.json` interchange files offline
//! (`tmfu verify`, CI). Failures are structured [`VerifyError`]s with
//! kernel/op/stage provenance. [`mutate`] is the adversarial half:
//! it manufactures corrupted tapes and artifacts the integration
//! suite feeds back through these checks.

pub mod diag;
pub mod mutate;

pub use diag::{Check, VerifyError};

use crate::dfg::{self, Dfg, NodeKind};
use crate::exec::{CompiledKernel, KernelRegistry, Tape};
use crate::isa::{ContextImage, FuInstr};
use crate::sched::{program_to_json, Program, Timing};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::Path;

/// Instruction-memory depth per FU (32 entries, paper §III).
const IM_DEPTH: usize = crate::bench_suite::constants::IM_DEPTH;
/// Register-file depth per FU (32 entries, paper §III).
const RF_DEPTH: usize = crate::bench_suite::constants::RF_DEPTH;
/// The context word's FU tag is 5 bits, so a linear array is at most
/// 32 FUs long — one stage per FU.
const MAX_FUS: usize = 32;

fn err(kernel: &str, check: Check, detail: impl Into<String>) -> VerifyError {
    VerifyError::new(kernel, check, detail)
}

// ---------------------------------------------------------------------
// DFG well-formedness
// ---------------------------------------------------------------------

/// DFG well-formedness: delegates to [`Dfg::validate`] (whose
/// forward-reference rule — every arg id strictly below the node id —
/// makes the graph acyclic *and* free of dangling references at once)
/// and re-states the result as a [`VerifyError`].
pub fn check_dfg(name: &str, g: &Dfg) -> Result<(), VerifyError> {
    g.validate()
        .map_err(|e| err(name, Check::Dfg, e.to_string()))?;
    if g.name != name {
        return Err(err(
            name,
            Check::Dfg,
            format!("dfg names itself '{}'", g.name),
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Schedule legality
// ---------------------------------------------------------------------

/// Schedule legality for `p` against its source graph `g`.
pub fn check_schedule(name: &str, g: &Dfg, p: &Program) -> Result<(), VerifyError> {
    let serr = |detail: String| err(name, Check::Schedule, detail);
    if p.kernel != g.name {
        return Err(serr(format!(
            "program is for kernel '{}', dfg is '{}'",
            p.kernel, g.name
        )));
    }
    if p.stages.is_empty() {
        return Err(serr("program has no stages".to_string()));
    }
    if p.stages.len() > MAX_FUS {
        return Err(serr(format!(
            "{} stages exceed the {MAX_FUS}-FU linear array",
            p.stages.len()
        )));
    }
    let n_nodes = g.len() as u32;
    for (i, st) in p.stages.iter().enumerate() {
        let stage_no = (i + 1) as u32;
        let serr = |detail: String| err(name, Check::Schedule, detail).at_stage(stage_no);
        if st.stage != stage_no {
            return Err(serr(format!(
                "stage numbered {} at position {}",
                st.stage,
                i + 1
            )));
        }
        // Every node the stage touches must resolve in the DFG with
        // the right kind.
        for &id in st.ops.iter().chain(&st.bypasses).chain(&st.arrivals) {
            if id >= n_nodes {
                return Err(serr(format!("node {id} outside dfg ({n_nodes} nodes)")).at_op(id));
            }
        }
        for &id in &st.ops {
            if !g.node(id).is_op() {
                return Err(serr(format!("scheduled node {id} is not an op")).at_op(id));
            }
        }
        for &(id, value) in &st.consts {
            if id >= n_nodes {
                return Err(serr(format!("const node {id} outside dfg")).at_op(id));
            }
            match g.node(id).kind {
                NodeKind::Const { value: v } if v == value => {}
                NodeKind::Const { value: v } => {
                    return Err(
                        serr(format!("const node {id} is {v} in the dfg, {value} here")).at_op(id),
                    )
                }
                _ => return Err(serr(format!("const entry {id} is not a const node")).at_op(id)),
            }
        }
        // Register-file bounds, and every operand the instructions
        // will read must own a slot.
        for (&id, &slot) in &st.rf_slot {
            if (slot as usize) >= RF_DEPTH {
                return Err(
                    serr(format!("rf slot {slot} for node {id} exceeds depth {RF_DEPTH}"))
                        .at_op(id),
                );
            }
        }
        // Re-derive the instruction stream from the scheduled ops and
        // bypasses; the committed instrs must match exactly — a route
        // target pointing anywhere else is a corrupt schedule.
        let mut want: Vec<FuInstr> = Vec::with_capacity(st.ops.len() + st.bypasses.len());
        for &id in &st.ops {
            let node = g.node(id);
            let op = match node.kind {
                NodeKind::Op { op } => op,
                _ => unreachable!("checked above"),
            };
            let rs = |arg: u32| -> Result<u8, VerifyError> {
                st.rf_slot.get(&arg).copied().ok_or_else(|| {
                    err(
                        name,
                        Check::Schedule,
                        format!("operand {arg} of op {id} has no rf slot"),
                    )
                    .at_stage(stage_no)
                    .at_op(id)
                })
            };
            want.push(FuInstr::Arith {
                op,
                rs1: rs(node.args[0])?,
                rs2: rs(node.args[1])?,
            });
        }
        for &id in &st.bypasses {
            let rs = st.rf_slot.get(&id).copied().ok_or_else(|| {
                err(
                    name,
                    Check::Schedule,
                    format!("bypassed node {id} has no rf slot"),
                )
                .at_stage(stage_no)
                .at_op(id)
            })?;
            want.push(FuInstr::Bypass { rs });
        }
        if want.len() > IM_DEPTH {
            return Err(serr(format!(
                "{} instructions exceed IM depth {IM_DEPTH}",
                want.len()
            )));
        }
        if st.instrs != want {
            return Err(serr(format!(
                "instruction stream diverges from the scheduled ops \
                 ({} committed vs {} derived)",
                st.instrs.len(),
                want.len()
            )));
        }
    }
    // First-stage loads come from the outside world: only input nodes.
    for &id in &p.stages[0].arrivals {
        if !g.node(id).is_input() {
            return Err(err(
                name,
                Check::Schedule,
                format!("stage 1 loads node {id}, which is not a dfg input"),
            )
            .at_stage(1)
            .at_op(id));
        }
    }
    // Def-before-use across stages: each stage's arrivals must be an
    // ordered, complete relabeling of the previous stage's emissions.
    p.check_dataflow()
        .map_err(|e| err(name, Check::Schedule, e.to_string()))?;
    // Output routing: exactly the DFG's outputs, each exactly once,
    // each position pointing at the emission that carries its value.
    let last = p.stages.last().expect("non-empty checked above");
    let emissions = last.emissions();
    let outputs = g.outputs();
    if p.output_order.len() != outputs.len() {
        return Err(serr(format!(
            "output_order has {} entries for {} dfg outputs",
            p.output_order.len(),
            outputs.len()
        )));
    }
    let mut by_name: BTreeMap<&str, u32> = BTreeMap::new();
    for &id in &outputs {
        if let NodeKind::Output { ref name } = g.node(id).kind {
            by_name.insert(name.as_str(), id);
        }
    }
    let mut seen: Vec<&str> = Vec::new();
    for (out_name, pos) in &p.output_order {
        let &id = by_name.get(out_name.as_str()).ok_or_else(|| {
            serr(format!("output_order names unknown output '{out_name}'"))
        })?;
        if seen.contains(&out_name.as_str()) {
            return Err(serr(format!("output '{out_name}' routed twice")));
        }
        seen.push(out_name.as_str());
        let &src = emissions.get(*pos).ok_or_else(|| {
            serr(format!(
                "output '{out_name}' routed to position {pos}, final stage emits {}",
                emissions.len()
            ))
        })?;
        let want = g.node(id).args[0];
        if src != want {
            return Err(serr(format!(
                "output '{out_name}' routed to node {src}, dfg says node {want}"
            ))
            .at_op(id));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Tape safety
// ---------------------------------------------------------------------

/// Tape safety for an arbitrary tape claimed to implement `(g, p)`.
///
/// Two layers: first the internal invariants the SIMD interpreter's
/// bounds-check elision rests on (every index in range, write-once
/// coverage, inputs/constants read-only, strictly increasing
/// destinations); then a field-for-field diff against a *fresh*
/// lowering of the same schedule. The diff is what makes the pass
/// complete: any corruption of any tape field differs from the
/// recompilation and is rejected — the mutation harness
/// ([`mutate`]) can never construct a misbehaving tape this function
/// accepts.
pub fn check_tape_against(
    name: &str,
    g: &Dfg,
    p: &Program,
    tape: &Tape,
) -> Result<(), VerifyError> {
    let terr = |detail: String| err(name, Check::Tape, detail);
    let n_slots = tape.n_slots();
    let n_inputs = tape.n_inputs();
    if tape.ops().is_empty() {
        return Err(terr("tape has no ops".to_string()));
    }
    if n_slots != n_inputs + tape.consts().len() + tape.ops().len() {
        return Err(terr(format!(
            "slot arithmetic broken: {n_slots} slots != {n_inputs} inputs \
             + {} consts + {} ops",
            tape.consts().len(),
            tape.ops().len()
        )));
    }
    // Constants: unique slots, above the input block, in range.
    let mut written = vec![false; n_slots];
    for &(slot, _) in tape.consts() {
        let s = slot as usize;
        if s >= n_slots {
            return Err(terr(format!("const slot {s} out of range ({n_slots} slots)")));
        }
        if s < n_inputs {
            return Err(terr(format!("const slot {s} inside the input block (0..{n_inputs})")));
        }
        if written[s] {
            return Err(terr(format!("const slot {s} assigned twice")));
        }
        written[s] = true;
    }
    // Ops: reads below the destination (so already-produced), writes
    // strictly increasing, never into inputs or constants, each slot
    // exactly once.
    let mut last_dst: Option<u32> = None;
    for (i, op) in tape.ops().iter().enumerate() {
        let oerr = |detail: String| terr(detail).at_op(i as u32);
        let (a, b, dst) = (op.a as usize, op.b as usize, op.dst as usize);
        if dst >= n_slots {
            return Err(oerr(format!("dst slot {dst} out of range ({n_slots} slots)")));
        }
        if a >= n_slots || b >= n_slots {
            return Err(oerr(format!(
                "operand slot out of range (a={a}, b={b}, {n_slots} slots)"
            )));
        }
        if op.a >= op.dst || op.b >= op.dst {
            return Err(oerr(format!(
                "operand not produced before use (a={a}, b={b}, dst={dst})"
            )));
        }
        if dst < n_inputs {
            return Err(oerr(format!("op writes input slot {dst} (inputs are read-only)")));
        }
        if written[dst] {
            return Err(oerr(format!("slot {dst} written twice (const or earlier op)")));
        }
        if let Some(prev) = last_dst {
            if op.dst <= prev {
                return Err(oerr(format!(
                    "dst slots not strictly increasing ({} after {prev})",
                    op.dst
                )));
            }
        }
        last_dst = Some(op.dst);
        written[dst] = true;
    }
    // Coverage: with the counts equal (checked above) and no slot
    // written twice, every non-input slot is covered exactly once.
    for (s, w) in written.iter().enumerate().skip(n_inputs) {
        if !*w {
            return Err(terr(format!("slot {s} never produced")));
        }
    }
    // Outputs: one per DFG output, all readable.
    if tape.outputs().len() != g.outputs().len() {
        return Err(terr(format!(
            "{} output slots for {} dfg outputs",
            tape.outputs().len(),
            g.outputs().len()
        )));
    }
    for (i, &slot) in tape.outputs().iter().enumerate() {
        if (slot as usize) >= n_slots {
            return Err(terr(format!(
                "output {i} reads slot {slot}, out of range ({n_slots} slots)"
            ))
            .at_op(i as u32));
        }
    }
    if n_inputs != g.inputs().len() {
        return Err(terr(format!(
            "tape gathers {n_inputs} inputs, dfg declares {}",
            g.inputs().len()
        )));
    }
    // The completeness backstop: recompile the schedule and require
    // field-for-field equality (the epoch is a generation number, not
    // semantics, and is deliberately excluded).
    let fresh = Tape::compile(g, p).map_err(|e| terr(format!("relowering failed: {e}")))?;
    if tape.ops() != fresh.ops() {
        return Err(terr("op stream diverges from a fresh lowering".to_string()));
    }
    if tape.consts() != fresh.consts() {
        return Err(terr("constant preloads diverge from a fresh lowering".to_string()));
    }
    if tape.outputs() != fresh.outputs() {
        return Err(terr("output routing diverges from a fresh lowering".to_string()));
    }
    if tape.n_inputs() != fresh.n_inputs() || tape.n_slots() != fresh.n_slots() {
        return Err(terr("slot layout diverges from a fresh lowering".to_string()));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Context consistency
// ---------------------------------------------------------------------

/// ISA-context consistency: the 40-bit image must satisfy the depth
/// limits, survive a byte round-trip, equal a fresh encoding of the
/// schedule, and execute the same op sequence the tape encodes.
pub fn check_context(
    name: &str,
    p: &Program,
    context: &ContextImage,
    tape: &Tape,
) -> Result<(), VerifyError> {
    let cerr = |detail: String| err(name, Check::Context, detail);
    context.validate().map_err(|e| cerr(e.to_string()))?;
    if context.kernel != p.kernel {
        return Err(cerr(format!(
            "context is for kernel '{}', program is '{}'",
            context.kernel, p.kernel
        )));
    }
    let fresh = p
        .context_image()
        .map_err(|e| cerr(format!("re-encoding failed: {e}")))?;
    if context.fus != fresh.fus {
        return Err(cerr("context image diverges from a fresh encoding".to_string()));
    }
    let bytes = context.to_bytes().map_err(|e| cerr(e.to_string()))?;
    let back = ContextImage::from_bytes(&context.kernel, context.fus.len(), &bytes)
        .map_err(|e| cerr(format!("byte round-trip failed: {e}")))?;
    if back.fus != context.fus {
        return Err(cerr("context image does not round-trip through bytes".to_string()));
    }
    // The arithmetic op sequence, FU by FU in daisy-chain order, is
    // exactly the tape's op stream: two encodings of one schedule.
    let ctx_ops: Vec<_> = context
        .fus
        .iter()
        .flat_map(|fu| &fu.instrs)
        .filter_map(|i| match i {
            FuInstr::Arith { op, .. } => Some(*op),
            FuInstr::Bypass { .. } => None,
        })
        .collect();
    let tape_ops: Vec<_> = tape.ops().iter().map(|t| t.op).collect();
    if ctx_ops != tape_ops {
        return Err(cerr(format!(
            "context executes {} arith ops, tape encodes {} — sequences diverge",
            ctx_ops.len(),
            tape_ops.len()
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Whole-kernel / whole-registry entry points
// ---------------------------------------------------------------------

/// Run every check on one compiled kernel, including the cached
/// timing/arity fields the serving layer trusts.
pub fn verify_kernel(k: &CompiledKernel) -> Result<(), VerifyError> {
    check_dfg(&k.name, &k.dfg)?;
    check_schedule(&k.name, &k.dfg, &k.program)?;
    check_tape_against(&k.name, &k.dfg, &k.program, &k.tape)?;
    check_context(&k.name, &k.program, &k.context, &k.tape)?;
    let serr = |check: Check, detail: String| err(&k.name, check, detail);
    if k.n_inputs != k.dfg.inputs().len() || k.n_outputs != k.dfg.outputs().len() {
        return Err(serr(
            Check::Dfg,
            format!(
                "cached arity {}→{} disagrees with the dfg ({}→{})",
                k.n_inputs,
                k.n_outputs,
                k.dfg.inputs().len(),
                k.dfg.outputs().len()
            ),
        ));
    }
    let t = Timing::of(&k.program);
    if k.ii != t.ii || k.latency != t.latency() {
        return Err(serr(
            Check::Schedule,
            format!(
                "cached timing II={} latency={} disagrees with the schedule \
                 (II={} latency={})",
                k.ii,
                k.latency,
                t.ii,
                t.latency()
            ),
        ));
    }
    let words = k
        .context
        .load_cycles()
        .map_err(|e| serr(Check::Context, e.to_string()))?;
    if k.context_words != words {
        return Err(serr(
            Check::Context,
            format!(
                "cached context_words {} disagrees with the image ({words})",
                k.context_words
            ),
        ));
    }
    Ok(())
}

/// Verify every kernel in a compiled registry; first failure wins.
/// This is the `OverlayService::builder()` gate: a registry that fails
/// here is never loaded.
pub fn verify_registry(reg: &KernelRegistry) -> Result<(), VerifyError> {
    for k in reg.iter() {
        verify_kernel(k)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Committed-artifact verification (benchmarks/dfg/*.json)
// ---------------------------------------------------------------------

/// Verify one committed DFG+schedule interchange document (the
/// `tmfu export-dfg` format). `name` is the artifact's identity —
/// normally the file stem — and must match the embedded kernel name.
///
/// The document's `dfg` section is parsed and recompiled from scratch;
/// the whole compiled kernel is then [`verify_kernel`]-checked, and
/// the document must equal, subtree for subtree, a fresh
/// [`program_to_json`] of that compilation. Regeneration equality is
/// the artifact-side completeness argument: any structural corruption
/// of the schedule section differs from the recomputation and is
/// rejected.
pub fn verify_artifact_str(name: &str, text: &str) -> Result<(), VerifyError> {
    let aerr = |detail: String| err(name, Check::Artifact, detail);
    let doc = json::parse(text).map_err(|e| aerr(format!("json parse: {e}")))?;
    let dfg_j = doc.get("dfg");
    if dfg_j.as_obj().is_none() {
        return Err(aerr("missing 'dfg' section".to_string()));
    }
    let g = dfg::dfg_from_json(dfg_j).map_err(|e| aerr(format!("dfg section: {e}")))?;
    if g.name != name {
        return Err(aerr(format!("artifact '{name}' holds kernel '{}'", g.name)));
    }
    let k = CompiledKernel::compile(g).map_err(|e| aerr(format!("recompile failed: {e}")))?;
    verify_kernel(&k)?;
    let fresh = program_to_json(&k.dfg, &k.program);
    if doc.get("dfg") != fresh.get("dfg") {
        return Err(aerr("dfg section is not in canonical interchange form".to_string()));
    }
    if doc.get("schedule") != fresh.get("schedule") {
        return Err(aerr(
            "schedule section diverges from recompiling the dfg section".to_string(),
        ));
    }
    if let Some(obj) = doc.as_obj() {
        if obj.keys().any(|k| k != "dfg" && k != "schedule") {
            return Err(aerr("unexpected top-level sections".to_string()));
        }
    } else {
        return Err(aerr("document is not an object".to_string()));
    }
    Ok(())
}

/// A pre-parsed artifact mutant ([`mutate`]) checked without a disk
/// round-trip.
pub fn verify_artifact_json(name: &str, doc: &Json) -> Result<(), VerifyError> {
    verify_artifact_str(name, &doc.to_string_compact())
}

/// Verify every `*.json` under `dir` (sorted, so failures are
/// deterministic). Returns the verified kernel names.
pub fn verify_artifacts_dir(dir: &Path) -> Result<Vec<String>, VerifyError> {
    let derr = |detail: String| err(&dir.display().to_string(), Check::Artifact, detail);
    let mut files: Vec<_> = std::fs::read_dir(dir)
        .map_err(|e| derr(format!("read dir: {e}")))?
        .filter_map(|entry| entry.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    files.sort();
    if files.is_empty() {
        return Err(derr("no .json artifacts found".to_string()));
    }
    let mut names = Vec::with_capacity(files.len());
    for path in files {
        let stem = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("artifact")
            .to_string();
        let text = std::fs::read_to_string(&path)
            .map_err(|e| err(&stem, Check::Artifact, format!("read: {e}")))?;
        verify_artifact_str(&stem, &text)?;
        names.push(stem);
    }
    Ok(names)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::exec::TapeOp;

    fn compiled(name: &str) -> CompiledKernel {
        CompiledKernel::compile(bench_suite::load(name).unwrap()).unwrap()
    }

    #[test]
    fn every_bench_kernel_verifies_clean() {
        for name in bench_suite::all_names() {
            let k = compiled(name);
            verify_kernel(&k).unwrap_or_else(|e| panic!("{e}"));
        }
    }

    #[test]
    fn registry_verifies_clean() {
        let reg = KernelRegistry::compile_bench_suite().unwrap();
        verify_registry(&reg).unwrap();
    }

    #[test]
    fn dfg_check_rejects_mismatched_name() {
        let g = bench_suite::load("poly6").unwrap();
        let e = check_dfg("gradient", &g).unwrap_err();
        assert_eq!(e.check, Check::Dfg);
    }

    #[test]
    fn schedule_check_rejects_renumbered_stage() {
        let k = compiled("gradient");
        let mut p = k.program.clone();
        p.stages[1].stage = 7;
        let e = check_schedule(&k.name, &k.dfg, &p).unwrap_err();
        assert_eq!(e.check, Check::Schedule);
        assert_eq!(e.stage, Some(2));
    }

    #[test]
    fn schedule_check_rejects_dropped_op() {
        let k = compiled("poly6");
        let mut p = k.program.clone();
        let st = p
            .stages
            .iter()
            .position(|s| !s.ops.is_empty())
            .expect("some stage has ops");
        p.stages[st].ops.remove(0);
        assert!(check_schedule(&k.name, &k.dfg, &p).is_err());
    }

    #[test]
    fn schedule_check_rejects_bad_output_route() {
        let k = compiled("gradient");
        let mut p = k.program.clone();
        let last = p.stages.last().unwrap();
        p.output_order[0].1 = last.emissions().len(); // one past the end
        let e = check_schedule(&k.name, &k.dfg, &p).unwrap_err();
        assert_eq!(e.check, Check::Schedule);
    }

    #[test]
    fn schedule_check_rejects_swapped_stages() {
        let k = compiled("poly6");
        let mut p = k.program.clone();
        assert!(p.stages.len() >= 2);
        p.stages.swap(0, 1);
        assert!(check_schedule(&k.name, &k.dfg, &p).is_err());
    }

    #[test]
    fn tape_check_rejects_out_of_range_dst() {
        let k = compiled("gradient");
        let mut ops: Vec<TapeOp> = k.tape.ops().to_vec();
        let last = ops.len() - 1;
        ops[last].dst = k.tape.n_slots() as u32; // one past the arena
        let bad = Tape::from_raw_parts(
            ops,
            k.tape.consts().to_vec(),
            k.tape.outputs().to_vec(),
            k.tape.n_inputs(),
            k.tape.n_slots(),
        );
        let e = check_tape_against(&k.name, &k.dfg, &k.program, &bad).unwrap_err();
        assert_eq!(e.check, Check::Tape);
        assert_eq!(e.op, Some(last as u32));
    }

    #[test]
    fn tape_check_rejects_write_to_input_and_const_slots() {
        let k = compiled("poly6");
        // Write into the input block.
        let mut ops = k.tape.ops().to_vec();
        ops[0].dst = 0;
        ops[0].a = 0;
        ops[0].b = 0;
        let bad = Tape::from_raw_parts(
            ops,
            k.tape.consts().to_vec(),
            k.tape.outputs().to_vec(),
            k.tape.n_inputs(),
            k.tape.n_slots(),
        );
        assert!(check_tape_against(&k.name, &k.dfg, &k.program, &bad).is_err());
        // Write over a constant slot.
        let const_slot = k.tape.consts()[0].0;
        let mut ops = k.tape.ops().to_vec();
        let idx = ops.iter().position(|o| o.dst > const_slot).unwrap();
        ops[idx].dst = const_slot;
        let bad = Tape::from_raw_parts(
            ops,
            k.tape.consts().to_vec(),
            k.tape.outputs().to_vec(),
            k.tape.n_inputs(),
            k.tape.n_slots(),
        );
        assert!(check_tape_against(&k.name, &k.dfg, &k.program, &bad).is_err());
    }

    #[test]
    fn tape_check_rejects_truncated_outputs() {
        let k = compiled("sgfilter");
        let mut outputs = k.tape.outputs().to_vec();
        outputs.pop();
        let bad = Tape::from_raw_parts(
            k.tape.ops().to_vec(),
            k.tape.consts().to_vec(),
            outputs,
            k.tape.n_inputs(),
            k.tape.n_slots(),
        );
        assert!(check_tape_against(&k.name, &k.dfg, &k.program, &bad).is_err());
    }

    #[test]
    fn tape_check_diff_catches_const_value_drift() {
        // Internal invariants alone cannot see a constant whose value
        // changed; the recompile diff must.
        let k = compiled("chebyshev");
        let mut consts = k.tape.consts().to_vec();
        consts[0].1 = consts[0].1.wrapping_add(1);
        let bad = Tape::from_raw_parts(
            k.tape.ops().to_vec(),
            consts,
            k.tape.outputs().to_vec(),
            k.tape.n_inputs(),
            k.tape.n_slots(),
        );
        let e = check_tape_against(&k.name, &k.dfg, &k.program, &bad).unwrap_err();
        assert!(e.detail.contains("fresh lowering"), "{e}");
    }

    #[test]
    fn context_check_rejects_op_sequence_drift() {
        let k = compiled("gradient");
        let mut ctx = k.context.clone();
        // Drop the first FU's first instruction: validate() still
        // passes, but the op sequence no longer matches the tape.
        ctx.fus[0].instrs.remove(0);
        let e = check_context(&k.name, &k.program, &ctx, &k.tape).unwrap_err();
        assert_eq!(e.check, Check::Context);
    }

    #[test]
    fn cached_timing_drift_is_rejected() {
        let mut k = compiled("poly5");
        k.ii += 1;
        let e = verify_kernel(&k).unwrap_err();
        assert_eq!(e.check, Check::Schedule);
        let mut k = compiled("poly5");
        k.context_words += 1;
        let e = verify_kernel(&k).unwrap_err();
        assert_eq!(e.check, Check::Context);
    }

    #[test]
    fn artifact_roundtrip_verifies_and_corruption_is_rejected() {
        let g = bench_suite::load("gradient").unwrap();
        let p = Program::schedule(&g).unwrap();
        let text = program_to_json(&g, &p).to_string_pretty();
        verify_artifact_str("gradient", &text).unwrap();
        // Wrong identity.
        assert!(verify_artifact_str("poly6", &text).is_err());
        // Structural schedule corruption.
        let corrupted = text.replacen("\"ii\"", "\"xx\"", 1);
        assert!(verify_artifact_str("gradient", &corrupted).is_err());
        // Not JSON at all.
        assert!(verify_artifact_str("gradient", "{").is_err());
    }
}
