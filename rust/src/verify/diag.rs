//! Structured diagnostics for the static kernel verifier.
//!
//! Every check in [`super`] reports failure as a [`VerifyError`]: which
//! kernel, which analysis pass ([`Check`]), an optional op/stage
//! provenance, and a human-readable detail. The service layer maps
//! these onto `ServiceError::InvalidKernel` so a bad artifact is a
//! typed, client-visible rejection rather than a loaded time bomb.

use std::fmt;

/// Which analysis pass produced a diagnostic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Check {
    /// DFG well-formedness: acyclic, arity-consistent, no dangling
    /// node references.
    Dfg,
    /// Schedule legality: stage numbering, def-before-use across
    /// stages, register-file/instruction-memory bounds, II and
    /// latency consistency.
    Schedule,
    /// Tape safety: slot bounds, write-once coverage, read-only
    /// inputs/constants, equivalence with a fresh lowering.
    Tape,
    /// ISA context consistency: 40-bit context image round-trip and
    /// op-sequence agreement with the tape.
    Context,
    /// Committed-artifact integrity: parse, regeneration equality,
    /// file-level problems.
    Artifact,
}

impl Check {
    pub fn name(self) -> &'static str {
        match self {
            Check::Dfg => "dfg",
            Check::Schedule => "schedule",
            Check::Tape => "tape",
            Check::Context => "context",
            Check::Artifact => "artifact",
        }
    }
}

impl fmt::Display for Check {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One verifier diagnostic with kernel/op/stage provenance.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifyError {
    /// Kernel (or artifact file stem) the diagnostic is about.
    pub kernel: String,
    /// Analysis pass that failed.
    pub check: Check,
    /// Op index provenance: a tape op index or DFG node id, when the
    /// failure points at one.
    pub op: Option<u32>,
    /// Stage/cycle provenance (1-based stage number), when the
    /// failure points at one.
    pub stage: Option<u32>,
    /// Human-readable description of the violation.
    pub detail: String,
}

impl VerifyError {
    pub fn new(kernel: &str, check: Check, detail: impl Into<String>) -> VerifyError {
        VerifyError {
            kernel: kernel.to_string(),
            check,
            op: None,
            stage: None,
            detail: detail.into(),
        }
    }

    /// Attach an op/node index to the diagnostic.
    pub fn at_op(mut self, op: u32) -> VerifyError {
        self.op = Some(op);
        self
    }

    /// Attach a 1-based stage number to the diagnostic.
    pub fn at_stage(mut self, stage: u32) -> VerifyError {
        self.stage = Some(stage);
        self
    }
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "verify({}): {}", self.kernel, self.check)?;
        if let Some(stage) = self.stage {
            write!(f, ": stage {stage}")?;
        }
        if let Some(op) = self.op {
            write!(f, ": op {op}")?;
        }
        write!(f, ": {}", self.detail)
    }
}

impl std::error::Error for VerifyError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_provenance() {
        let e = VerifyError::new("poly6", Check::Tape, "dst slot 9 out of range")
            .at_op(3)
            .at_stage(2);
        assert_eq!(
            e.to_string(),
            "verify(poly6): tape: stage 2: op 3: dst slot 9 out of range"
        );
        let bare = VerifyError::new("poly6", Check::Dfg, "cycle");
        assert_eq!(bare.to_string(), "verify(poly6): dfg: cycle");
    }

    #[test]
    fn check_names_are_stable() {
        for (c, n) in [
            (Check::Dfg, "dfg"),
            (Check::Schedule, "schedule"),
            (Check::Tape, "tape"),
            (Check::Context, "context"),
            (Check::Artifact, "artifact"),
        ] {
            assert_eq!(c.name(), n);
            assert_eq!(c.to_string(), n);
        }
    }
}
