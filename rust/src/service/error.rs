//! Structured client-visible errors for the service API.
//!
//! Every failure a client can observe through [`super::OverlayService`]
//! / [`super::KernelHandle`] is a typed [`ServiceError`] variant —
//! admission rejection, shape mismatch, shutdown, deadline, backend
//! failure — replacing the stringly `Result<_, String>` replies of the
//! pre-service coordinator. Engine-internal failures travel as
//! [`ExecError`] (the execution layer's vocabulary) and are converted
//! at the service boundary via `From<ExecError>`.

use crate::exec::ExecError;
use std::fmt;

/// A client-visible serving failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// The kernel name is not in this service's registry.
    UnknownKernel(String),
    /// Input arity does not match the kernel's signature.
    ShapeMismatch {
        kernel: String,
        expected: usize,
        got: usize,
    },
    /// A zero-row batch was handed to `call_batch`.
    EmptyBatch { kernel: String },
    /// Admission control rejected the request: the submitting tenant's
    /// quota or the kernel's configured depth limit is full (`queued`
    /// and `limit` describe whichever bound tripped). Back off and
    /// retry — the service sheds load here instead of growing queues
    /// without bound.
    Rejected {
        kernel: String,
        tenant: String,
        queued: usize,
        limit: usize,
    },
    /// The service has shut down (or is draining) and accepts no new
    /// requests.
    ShutDown,
    /// The request's deadline budget was exhausted: a [`super::Pending`]
    /// wait timed out before the reply arrived, the rows expired in the
    /// queue before any worker took them (lazy expiry), or admission
    /// shed the request outright because the estimated queue wait
    /// already exceeded the budget. Only in the wait-timeout case does
    /// the request itself stay in flight — expired and shed requests
    /// never reach a backend.
    DeadlineExceeded { kernel: String },
    /// The worker serving this request disappeared without replying
    /// (worker panic — an engine bug, not a request error).
    Disconnected { kernel: String },
    /// No healthy replica currently owns this kernel (router-level
    /// condition: every backend that serves it is dead or draining).
    /// Retryable — replicas rejoin the routing table on recovery.
    Unavailable { kernel: String },
    /// The execution substrate failed (PJRT load/execute, cycle
    /// budget...).
    Backend { backend: String, message: String },
    /// Static verification rejected the kernel at build time
    /// (`verify`, DESIGN.md §12): the compiled artifact — DFG,
    /// schedule, tape or context image — violates an invariant and
    /// was never loaded. Not retryable: the artifact is broken, not
    /// the service.
    InvalidKernel { kernel: String, detail: String },
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::UnknownKernel(name) => write!(f, "unknown kernel '{name}'"),
            ServiceError::ShapeMismatch {
                kernel,
                expected,
                got,
            } => write!(f, "kernel '{kernel}' expects {expected} inputs, got {got}"),
            ServiceError::EmptyBatch { kernel } => {
                write!(f, "kernel '{kernel}': empty batch (no packets to execute)")
            }
            // Note: `queued` can be well below `limit` when a whole
            // batch is rejected (batch admission is all-or-nothing),
            // so the message states both facts without implying
            // queued >= limit.
            ServiceError::Rejected {
                kernel,
                tenant,
                queued,
                limit,
            } => write!(
                f,
                "kernel '{kernel}': admission rejected for tenant '{tenant}' \
                 ({queued} queued, limit {limit})"
            ),
            ServiceError::ShutDown => write!(f, "service shut down"),
            ServiceError::DeadlineExceeded { kernel } => {
                write!(f, "kernel '{kernel}': reply deadline exceeded")
            }
            ServiceError::Disconnected { kernel } => {
                write!(f, "kernel '{kernel}': worker dropped without replying")
            }
            ServiceError::Unavailable { kernel } => {
                write!(f, "kernel '{kernel}': no healthy replica available")
            }
            ServiceError::Backend { backend, message } => write!(f, "{backend} backend: {message}"),
            ServiceError::InvalidKernel { kernel, detail } => {
                write!(f, "kernel '{kernel}' failed verification: {detail}")
            }
        }
    }
}

impl std::error::Error for ServiceError {}

impl From<ExecError> for ServiceError {
    fn from(e: ExecError) -> ServiceError {
        match e {
            ExecError::UnknownKernel(name) => ServiceError::UnknownKernel(name),
            ExecError::WrongArity {
                kernel,
                expected,
                got,
            } => ServiceError::ShapeMismatch {
                kernel,
                expected,
                got,
            },
            ExecError::EmptyBatch { kernel } => ServiceError::EmptyBatch { kernel },
            ExecError::BatchTooLarge { .. } => ServiceError::Backend {
                backend: "exec".to_string(),
                message: e.to_string(),
            },
            ExecError::Backend { backend, message } => ServiceError::Backend {
                backend: backend.to_string(),
                message,
            },
            ExecError::DeadlineExceeded { kernel } => ServiceError::DeadlineExceeded { kernel },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_are_specific() {
        let e = ServiceError::Rejected {
            kernel: "poly6".into(),
            tenant: "default".into(),
            queued: 8,
            limit: 8,
        };
        assert!(e.to_string().contains("admission rejected"));
        assert!(e.to_string().contains("poly6"));
        assert!(ServiceError::ShutDown.to_string().contains("shut down"));
        let e = ServiceError::DeadlineExceeded {
            kernel: "fir".into(),
        };
        assert!(e.to_string().contains("deadline"));
        let e = ServiceError::Unavailable {
            kernel: "poly6".into(),
        };
        assert!(e.to_string().contains("no healthy replica"));
        assert!(e.to_string().contains("poly6"));
        let e = ServiceError::InvalidKernel {
            kernel: "poly6".into(),
            detail: "verify(poly6): tape: op 3: dst slot out of range".into(),
        };
        assert!(e.to_string().contains("failed verification"));
        assert!(e.to_string().contains("dst slot out of range"));
    }

    #[test]
    fn converts_exec_errors() {
        let e: ServiceError = ExecError::WrongArity {
            kernel: "gradient".into(),
            expected: 5,
            got: 2,
        }
        .into();
        assert_eq!(
            e,
            ServiceError::ShapeMismatch {
                kernel: "gradient".into(),
                expected: 5,
                got: 2
            }
        );
        let e: ServiceError = ExecError::UnknownKernel("nope".into()).into();
        assert_eq!(e, ServiceError::UnknownKernel("nope".into()));
        let e: ServiceError = ExecError::Backend {
            backend: "pjrt",
            message: "client create failed".into(),
        }
        .into();
        assert!(matches!(e, ServiceError::Backend { .. }));
        // Shape of the batch-level conversions.
        let e: ServiceError = ExecError::EmptyBatch {
            kernel: "fir".into(),
        }
        .into();
        assert_eq!(
            e,
            ServiceError::EmptyBatch {
                kernel: "fir".into()
            }
        );
        // Queue expiry arrives typed, not as a stringly backend error.
        let e: ServiceError = ExecError::DeadlineExceeded {
            kernel: "fir".into(),
        }
        .into();
        assert_eq!(
            e,
            ServiceError::DeadlineExceeded {
                kernel: "fir".into()
            }
        );
    }
}
