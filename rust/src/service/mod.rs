//! The public serving API: a layered, typed client/service surface
//! over the execution backends (DESIGN.md §8).
//!
//! ```text
//! ServiceBuilder ──build()──▶ OverlayService ──kernel()──▶ KernelHandle
//!                                   │                          │
//!                                   ▼                          ▼
//!                               Engine (crate-private workers, bounded
//!                               queues, completion slab)  ──▶  exec::Backend
//! ```
//!
//! * [`OverlayService::builder`] configures the substrate (backend
//!   kind, pipelines, max batch, queue depth, registry source) and
//!   compiles every kernel once at `build()`;
//! * [`OverlayService::kernel`] resolves a kernel name to a
//!   [`KernelHandle`] **once** — the handle pre-binds the dense
//!   [`KernelId`] and arity, is `Clone + Send`, and outlives the
//!   service value itself (it holds the engine state by `Arc`), so a
//!   client session never re-resolves strings per call;
//! * [`KernelHandle::call`] / [`KernelHandle::call_batch`] are the
//!   blocking entry points; [`KernelHandle::submit`] /
//!   [`KernelHandle::submit_batch`] are non-blocking and return a
//!   [`Pending`] / [`PendingBatch`] reply with poll/wait/deadline
//!   support;
//! * replies are **completion-slab tickets**, not channels
//!   (DESIGN.md §10): a steady-state `submit` → [`Pending::wait_into`]
//!   round trip performs *zero* heap allocations (audited by bench
//!   §B6), and a whole `call_batch` costs one slot reservation, with
//!   reply rows written in place — never a channel per row;
//! * every failure is a typed [`ServiceError`]; backpressure is
//!   explicit — bounded per-kernel queues make an overloaded service
//!   answer [`ServiceError::Rejected`] instead of growing without
//!   bound;
//! * [`OverlayService::metrics`] returns a typed, JSON-serializable
//!   [`MetricsSnapshot`]; [`OverlayService::shutdown`] drains admitted
//!   work before stopping the workers.
//!
//! ```no_run
//! use tmfu_overlay::exec::BackendKind;
//! use tmfu_overlay::service::OverlayService;
//!
//! fn main() -> Result<(), Box<dyn std::error::Error>> {
//!     let service = OverlayService::builder()
//!         .backend(BackendKind::Turbo)
//!         .pipelines(2)
//!         .build()?;
//!     let poly6 = service.kernel("poly6")?; // id + arity resolved once
//!     assert_eq!(poly6.arity(), 3);
//!     let y = poly6.call(&[1, 2, 3])?; // or submit() -> Pending
//!     println!("poly6(1, 2, 3) = {y:?}");
//!     println!("{}", service.metrics().render());
//!     service.shutdown()?; // drains admitted work
//!     Ok(())
//! }
//! ```
//!
//! The same surface is reachable from other processes over the wire
//! protocol ([`crate::wire`], `tmfu listen`) through the mirroring
//! [`crate::client::OverlayClient`].

pub mod error;
mod metrics;

pub use error::ServiceError;
pub use metrics::{LatencySummary, MetricsSnapshot, TenantMetrics};

use crate::coordinator::completion::{Ticket, WakeTarget};
use crate::coordinator::{Engine, EngineConfig, Shared, SubmitRejection, TenantId, TenantSpec};
use crate::dfg::Dfg;
use crate::exec::{BackendKind, CompiledKernel, FlatBatch, KernelId, KernelRegistry};
use std::fmt;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// Builder
// ---------------------------------------------------------------------

/// Configuration for an [`OverlayService`]. Obtained from
/// [`OverlayService::builder`]; every knob has a serving-ready default.
#[derive(Debug, Clone)]
pub struct ServiceBuilder {
    backend: BackendKind,
    artifacts_dir: PathBuf,
    pipelines: usize,
    max_batch: usize,
    queue_depth: usize,
    sim_replicas: usize,
    sim_fifo_capacity: usize,
    slab_trim_words: usize,
    kernels: Option<Vec<Dfg>>,
    kernel_artifacts: Option<PathBuf>,
    tenants: Vec<TenantSpec>,
}

impl Default for ServiceBuilder {
    fn default() -> ServiceBuilder {
        ServiceBuilder {
            backend: BackendKind::Sim,
            artifacts_dir: PathBuf::from("artifacts"),
            pipelines: 1,
            max_batch: 16,
            queue_depth: 1024,
            sim_replicas: 1,
            sim_fifo_capacity: 4096,
            slab_trim_words: crate::coordinator::completion::DEFAULT_TRIM_WORDS,
            kernels: None,
            kernel_artifacts: None,
            tenants: vec![TenantSpec::default_tenant()],
        }
    }
}

impl ServiceBuilder {
    /// Execution substrate for every worker (default: `sim`).
    pub fn backend(mut self, kind: BackendKind) -> ServiceBuilder {
        self.backend = kind;
        self
    }

    /// AOT artifacts directory (PJRT backend only).
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> ServiceBuilder {
        self.artifacts_dir = dir.into();
        self
    }

    /// Fabric workers — overlay pipeline replicas at the serving level
    /// (default: 1).
    pub fn pipelines(mut self, n: usize) -> ServiceBuilder {
        self.pipelines = n;
        self
    }

    /// Maximum batch a worker takes per dispatch (default: 16).
    pub fn max_batch(mut self, n: usize) -> ServiceBuilder {
        self.max_batch = n;
        self
    }

    /// Per-kernel admission bound (default: 1024). A kernel whose
    /// queue is at this depth answers [`ServiceError::Rejected`] —
    /// note `call_batch` needs the whole batch admitted at once, so
    /// batches larger than this can never be admitted.
    pub fn queue_depth(mut self, n: usize) -> ServiceBuilder {
        self.queue_depth = n;
        self
    }

    /// Pipeline replicas inside each sim-backend overlay (Fig. 4).
    pub fn sim_replicas(mut self, n: usize) -> ServiceBuilder {
        self.sim_replicas = n;
        self
    }

    /// FIFO capacity of each simulated pipeline.
    pub fn sim_fifo_capacity(mut self, n: usize) -> ServiceBuilder {
        self.sim_fifo_capacity = n;
        self
    }

    /// Completion-slot buffer watermark in `i32` words (default:
    /// 64 Ki). Recycled slots shrink buffers grown past this back
    /// down, so one burst batch does not pin its peak allocation on
    /// the pool; buffers under the watermark are never touched.
    pub fn slab_trim_words(mut self, words: usize) -> ServiceBuilder {
        self.slab_trim_words = words;
        self
    }

    /// Find-or-append the named tenant's spec (entry 0 is always the
    /// default tenant; new tenants get weight 1 and unlimited quota
    /// until overridden).
    fn tenant_mut(&mut self, name: &str) -> &mut TenantSpec {
        if let Some(i) = self.tenants.iter().position(|t| t.name == name) {
            return &mut self.tenants[i];
        }
        self.tenants.push(TenantSpec {
            name: name.to_string(),
            weight: 1,
            quota: usize::MAX,
        });
        self.tenants.last_mut().expect("just pushed")
    }

    /// Declare a tenant lane (idempotent). Requests carrying an
    /// unknown tenant name — or none — fall back to the built-in
    /// `default` lane (weight 1, unlimited quota), so a service with
    /// no declared tenants behaves exactly as before multi-tenancy.
    pub fn tenant(mut self, name: &str) -> ServiceBuilder {
        self.tenant_mut(name);
        self
    }

    /// Deficit-round-robin weight for one tenant's lane (declaring it
    /// if needed): under contention a weight-2 tenant drains about
    /// twice the rows of a weight-1 tenant. Must be >= 1.
    pub fn tenant_weight(mut self, name: &str, weight: u32) -> ServiceBuilder {
        assert!(weight >= 1, "tenant weight must be >= 1");
        self.tenant_mut(name).weight = weight;
        self
    }

    /// Admission quota for one tenant (declaring it if needed): the
    /// most rows the tenant may have queued across all kernels;
    /// excess submissions answer [`ServiceError::Rejected`] with the
    /// tenant named. Must be >= 1.
    pub fn tenant_quota(mut self, name: &str, quota: usize) -> ServiceBuilder {
        assert!(quota >= 1, "tenant quota must be >= 1");
        self.tenant_mut(name).quota = quota;
        self
    }

    /// Serve an explicit kernel set instead of the benchmark suite
    /// (custom workloads, tests).
    pub fn kernels(mut self, graphs: Vec<Dfg>) -> ServiceBuilder {
        self.kernels = Some(graphs);
        self
    }

    /// Serve the kernels committed as DFG+schedule interchange JSON
    /// under `dir` (the `tmfu export-dfg` format). Every artifact is
    /// statically verified at `build()` — a corrupted file is a typed
    /// [`ServiceError::InvalidKernel`], never a loaded kernel.
    /// Overrides [`ServiceBuilder::kernels`].
    pub fn kernels_from_artifacts(mut self, dir: impl Into<PathBuf>) -> ServiceBuilder {
        self.kernel_artifacts = Some(dir.into());
        self
    }

    /// Load and statically verify the artifact directory, returning
    /// the parsed graphs.
    fn load_artifact_kernels(dir: &std::path::Path) -> Result<Vec<Dfg>, ServiceError> {
        let invalid = |kernel: String, detail: String| ServiceError::InvalidKernel {
            kernel,
            detail,
        };
        let names = crate::verify::verify_artifacts_dir(dir)
            .map_err(|e| invalid(e.kernel.clone(), e.to_string()))?;
        let mut graphs = Vec::with_capacity(names.len());
        for name in names {
            let path = dir.join(format!("{name}.json"));
            let text = std::fs::read_to_string(&path)
                .map_err(|e| invalid(name.clone(), format!("read {}: {e}", path.display())))?;
            let doc = crate::util::json::parse(&text)
                .map_err(|e| invalid(name.clone(), format!("json parse: {e}")))?;
            let g = crate::dfg::dfg_from_json(doc.get("dfg"))
                .map_err(|e| invalid(name.clone(), format!("dfg section: {e}")))?;
            graphs.push(g);
        }
        Ok(graphs)
    }

    /// Compile the registry, statically verify every kernel
    /// ([`crate::verify`]), spawn the workers, and wait until every
    /// backend is ready to serve. A kernel that fails verification is
    /// a typed [`ServiceError::InvalidKernel`] and is never loaded.
    pub fn build(self) -> Result<OverlayService, ServiceError> {
        let backend = self.backend;
        let kernels = match &self.kernel_artifacts {
            Some(dir) => Some(ServiceBuilder::load_artifact_kernels(dir)?),
            None => self.kernels,
        };
        let registry = match kernels {
            Some(graphs) => KernelRegistry::compile(graphs),
            None => KernelRegistry::compile_bench_suite(),
        }
        .map_err(|e| ServiceError::Backend {
            backend: "compile".to_string(),
            message: format!("{e}"),
        })?;
        crate::verify::verify_registry(&registry).map_err(|e| ServiceError::InvalidKernel {
            kernel: e.kernel.clone(),
            detail: e.to_string(),
        })?;
        let tenant_names: Arc<Vec<Arc<str>>> = Arc::new(
            self.tenants
                .iter()
                .map(|t| Arc::from(t.name.as_str()))
                .collect(),
        );
        let engine = Engine::start(EngineConfig {
            backend,
            artifacts_dir: self.artifacts_dir,
            workers: self.pipelines,
            max_batch: self.max_batch,
            queue_depth: self.queue_depth,
            sim_replicas: self.sim_replicas,
            sim_fifo_capacity: self.sim_fifo_capacity,
            slab_trim_words: self.slab_trim_words,
            registry: Arc::new(registry),
            tenants: self.tenants,
        })
        .map_err(|e| ServiceError::Backend {
            backend: backend.name().to_string(),
            message: format!("{e}"),
        })?;
        Ok(OverlayService {
            engine,
            tenant_names,
        })
    }
}

// ---------------------------------------------------------------------
// Service
// ---------------------------------------------------------------------

/// A running overlay serving instance: compiled kernels, fabric
/// workers, bounded queues, the shared completion slab. Clients
/// interact through [`KernelHandle`] sessions created with
/// [`OverlayService::kernel`].
pub struct OverlayService {
    engine: Engine,
    /// Tenant-lane names, index-aligned with [`TenantId`] (entry 0 is
    /// the default lane).
    tenant_names: Arc<Vec<Arc<str>>>,
}

impl OverlayService {
    /// Start configuring a service.
    pub fn builder() -> ServiceBuilder {
        ServiceBuilder::default()
    }

    /// Resolve a kernel name to a client session handle. The
    /// [`KernelId`] and arity are bound here, once — calls through the
    /// handle never touch strings again.
    pub fn kernel(&self, name: &str) -> Result<KernelHandle, ServiceError> {
        self.kernel_as(name, TenantId::DEFAULT)
    }

    /// [`Self::kernel`], with the handle bound to the named tenant's
    /// lane: its submissions draw on that tenant's quota and weight
    /// and its rejections/latencies land in that tenant's ledger. An
    /// unknown tenant name falls back to the default lane.
    pub fn kernel_for(&self, name: &str, tenant: &str) -> Result<KernelHandle, ServiceError> {
        self.kernel_as(name, self.tenant_id(tenant))
    }

    fn kernel_as(&self, name: &str, tenant: TenantId) -> Result<KernelHandle, ServiceError> {
        let registry = self.engine.registry();
        let id = registry
            .id_of(name)
            .ok_or_else(|| ServiceError::UnknownKernel(name.to_string()))?;
        let kernel = Arc::clone(registry.kernel(id).expect("interned id resolves"));
        Ok(KernelHandle {
            shared: Arc::clone(self.engine.shared()),
            kernel,
            id,
            tenant,
            tenant_name: Arc::clone(&self.tenant_names[tenant.index()]),
        })
    }

    /// Resolve a tenant name to its lane id; unknown names use the
    /// default lane (entry 0).
    fn tenant_id(&self, name: &str) -> TenantId {
        self.tenant_names
            .iter()
            .position(|t| &**t == name)
            // cast-ok: lane count is bounded far below u32::MAX
            .map_or(TenantId::DEFAULT, |i| TenantId(i as u32))
    }

    /// The configured tenant-lane names, in [`TenantId`] order.
    pub fn tenant_names(&self) -> Vec<&str> {
        self.tenant_names.iter().map(|t| &**t).collect()
    }

    /// One handle per registry kernel, in [`KernelId`] order. Each is
    /// resolved through [`Self::kernel`], so ids always come from the
    /// registry's name index rather than a parallel counter.
    pub fn handles(&self) -> Vec<KernelHandle> {
        self.engine
            .registry()
            .names()
            .iter()
            .map(|name| self.kernel(name).expect("registry name resolves"))
            .collect()
    }

    /// [`Self::handles`] bound to the named tenant's lane (unknown
    /// names fall back to the default lane) — the wire server builds
    /// a connection's handle vector with this after resolving the
    /// Hello's tenant.
    pub fn handles_for(&self, tenant: &str) -> Vec<KernelHandle> {
        let tenant = self.tenant_id(tenant);
        self.engine
            .registry()
            .names()
            .iter()
            .map(|name| {
                self.kernel_as(name, tenant)
                    .expect("registry name resolves")
            })
            .collect()
    }

    /// Kernel names in [`KernelId`] order.
    pub fn kernel_names(&self) -> Vec<&str> {
        self.engine.registry().names()
    }

    /// The execution substrate this service serves through.
    pub fn backend(&self) -> BackendKind {
        self.engine.backend()
    }

    /// The shared compiled-kernel registry (oracle checks, tooling).
    pub fn registry(&self) -> &Arc<KernelRegistry> {
        self.engine.registry()
    }

    /// Requests completed so far (lock-free — an atomic load, safe to
    /// poll from a monitoring thread at any rate).
    pub fn completed(&self) -> u64 {
        self.engine.completed()
    }

    /// Completion-slab occupancy: slots currently reserved for
    /// admitted requests whose reply has not been collected or
    /// reclaimed yet. Returns to 0 when every caller has collected,
    /// cancelled, or dropped its pending reply — the leak probe the
    /// wire-path drop-storm regression test watches.
    pub fn live_slots(&self) -> usize {
        self.engine.shared().slab.live_slots()
    }

    /// A typed point-in-time metrics snapshot (render it with
    /// [`MetricsSnapshot::render`], serialize with
    /// [`MetricsSnapshot::to_json`]). The raw sample buffers are
    /// copied out under a short engine lock; the percentile
    /// sorting happens here, on the caller's thread — a metrics poll
    /// (in-process or `GetMetrics` over the wire) can never stall the
    /// workers.
    pub fn metrics(&self) -> MetricsSnapshot {
        let raw = self.engine.raw_metrics();
        MetricsSnapshot::collect(
            raw,
            &self.engine.registry().names(),
            &self.tenant_names(),
            self.engine.backend().name(),
            self.engine.workers(),
            self.engine.queue_depth(),
        )
    }

    /// Graceful shutdown: stop admitting, **drain** every queue (all
    /// admitted requests are replied to), then join the workers.
    /// Outstanding [`KernelHandle`]s stay valid but answer
    /// [`ServiceError::ShutDown`] from then on.
    ///
    /// Takes `&self` and is idempotent, so a service shared behind an
    /// `Arc` (e.g. one a [`crate::wire::server::WireServer`] is
    /// serving) can be shut down while other holders keep their
    /// reference — their subsequent calls see the typed `ShutDown`.
    pub fn shutdown(&self) -> Result<(), ServiceError> {
        self.engine.shutdown().map_err(|e| ServiceError::Backend {
            backend: "engine".to_string(),
            message: format!("{e}"),
        })
    }
}

// ---------------------------------------------------------------------
// Kernel sessions
// ---------------------------------------------------------------------

/// A client session for one kernel: pre-resolved id + arity, cheap to
/// clone, safe to send to other threads, independent of the
/// [`OverlayService`] value's lifetime (it holds the engine state by
/// `Arc`).
#[derive(Clone)]
pub struct KernelHandle {
    shared: Arc<Shared>,
    kernel: Arc<CompiledKernel>,
    id: KernelId,
    tenant: TenantId,
    tenant_name: Arc<str>,
}

impl fmt::Debug for KernelHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "KernelHandle({} -> {})", self.kernel.name, self.id)
    }
}

impl KernelHandle {
    pub fn name(&self) -> &str {
        &self.kernel.name
    }

    pub fn id(&self) -> KernelId {
        self.id
    }

    /// Input arity (words per request row).
    pub fn arity(&self) -> usize {
        self.kernel.n_inputs
    }

    /// Output arity (words per reply row).
    pub fn n_outputs(&self) -> usize {
        self.kernel.n_outputs
    }

    /// The compiled form behind this handle (DFG oracle, schedule,
    /// timing, context image, tape).
    pub fn compiled(&self) -> &Arc<CompiledKernel> {
        &self.kernel
    }

    /// The tenant lane this handle submits on.
    pub fn tenant_name(&self) -> &str {
        &self.tenant_name
    }

    fn rejection(&self, r: SubmitRejection) -> ServiceError {
        match r {
            SubmitRejection::ShutDown => ServiceError::ShutDown,
            SubmitRejection::Full { queued, limit } => ServiceError::Rejected {
                kernel: self.kernel.name.clone(),
                tenant: self.tenant_name.to_string(),
                queued,
                limit,
            },
            // Shed at admission: the queue wait alone would blow the
            // deadline budget, so the request was never admitted.
            SubmitRejection::Infeasible => ServiceError::DeadlineExceeded {
                kernel: self.kernel.name.clone(),
            },
        }
    }

    fn check_arity(&self, got: usize) -> Result<(), ServiceError> {
        if got != self.kernel.n_inputs {
            return Err(ServiceError::ShapeMismatch {
                kernel: self.kernel.name.clone(),
                expected: self.kernel.n_inputs,
                got,
            });
        }
        Ok(())
    }

    /// Non-blocking submit: validates shape, passes admission control,
    /// reserves one completion-slab slot, and returns its [`Pending`]
    /// ticket. Zero heap allocations in steady state.
    pub fn submit(&self, inputs: &[i32]) -> Result<Pending, ServiceError> {
        self.submit_inner(inputs, None, None)
    }

    /// [`Self::submit`] carrying a deadline budget: the request is
    /// shed at admission if the estimated queue wait already exceeds
    /// `budget` (typed [`ServiceError::DeadlineExceeded`], never
    /// queued), and evicted unexecuted if the budget lapses while it
    /// waits in the queue (lazy expiry — the reply is the same typed
    /// error). The budget is relative: it starts counting now.
    pub fn submit_with_deadline(
        &self,
        inputs: &[i32],
        budget: Duration,
    ) -> Result<Pending, ServiceError> {
        self.submit_inner(inputs, Some(budget), None)
    }

    /// [`Self::submit`] with a completion doorbell: `waker` is rung
    /// with `tag` the moment the reply is ready. The wire server's
    /// reactor uses this to wait on thousands of in-flight calls
    /// without a thread (or a blocked `wait`) per call.
    pub(crate) fn submit_tagged(
        &self,
        inputs: &[i32],
        deadline: Option<Duration>,
        waker: WakeTarget,
    ) -> Result<Pending, ServiceError> {
        self.submit_inner(inputs, deadline, Some(waker))
    }

    fn submit_inner(
        &self,
        inputs: &[i32],
        deadline: Option<Duration>,
        waker: Option<WakeTarget>,
    ) -> Result<Pending, ServiceError> {
        self.check_arity(inputs.len())?;
        // An unrepresentable budget (absurdly far future) waits
        // unbounded instead of panicking on Instant overflow.
        let deadline = deadline.and_then(|d| Instant::now().checked_add(d));
        let ticket = self
            .shared
            .submit(
                self.tenant,
                self.id,
                inputs,
                self.kernel.n_outputs,
                deadline,
                waker,
            )
            .map_err(|r| self.rejection(r))?;
        Ok(Pending {
            shared: Arc::clone(&self.shared),
            ticket,
            kernel: Arc::clone(&self.kernel),
            tenant: self.tenant,
            done: false,
        })
    }

    /// Blocking call: submit one request and wait for its reply.
    pub fn call(&self, inputs: &[i32]) -> Result<Vec<i32>, ServiceError> {
        self.submit(inputs)?.wait()
    }

    /// Blocking call under a deadline budget: shed/expiry semantics of
    /// [`Self::submit_with_deadline`], plus the wait itself is bounded
    /// by the same budget. On timeout the request is **cancelled** —
    /// still-queued rows never execute and the slot is reclaimed — so
    /// a deadline miss leaves nothing behind.
    pub fn call_with_deadline(
        &self,
        inputs: &[i32],
        budget: Duration,
    ) -> Result<Vec<i32>, ServiceError> {
        let mut p = self.submit_with_deadline(inputs, budget)?;
        match p.wait_timeout(budget) {
            Err(e @ ServiceError::DeadlineExceeded { .. }) => {
                p.cancel();
                Err(e)
            }
            other => other,
        }
    }

    /// Blocking call writing the reply row into a caller-owned buffer
    /// (cleared first). With a reused `out`, a steady-state call
    /// performs zero heap allocations end to end.
    pub fn call_into(&self, inputs: &[i32], out: &mut Vec<i32>) -> Result<(), ServiceError> {
        self.submit(inputs)?.wait_into(out)
    }

    /// Non-blocking batch submit: the whole batch is admitted
    /// atomically (all rows or [`ServiceError::Rejected`]) and costs
    /// **one** slab reservation regardless of row count. Reply rows
    /// are written in place by the workers, possibly out of order and
    /// by different workers, and come back assembled in row order.
    pub fn submit_batch(&self, batch: &FlatBatch) -> Result<PendingBatch, ServiceError> {
        self.submit_batch_inner(batch, None, None)
    }

    /// [`Self::submit_batch`] carrying a deadline budget (shed at
    /// admission / lazy queue expiry — see
    /// [`Self::submit_with_deadline`]; the budget covers the whole
    /// batch).
    pub fn submit_batch_with_deadline(
        &self,
        batch: &FlatBatch,
        budget: Duration,
    ) -> Result<PendingBatch, ServiceError> {
        self.submit_batch_inner(batch, Some(budget), None)
    }

    /// [`Self::submit_batch`] with a completion doorbell (see
    /// [`Self::submit_tagged`]).
    pub(crate) fn submit_batch_tagged(
        &self,
        batch: &FlatBatch,
        deadline: Option<Duration>,
        waker: WakeTarget,
    ) -> Result<PendingBatch, ServiceError> {
        self.submit_batch_inner(batch, deadline, Some(waker))
    }

    fn submit_batch_inner(
        &self,
        batch: &FlatBatch,
        deadline: Option<Duration>,
        waker: Option<WakeTarget>,
    ) -> Result<PendingBatch, ServiceError> {
        if batch.is_empty() {
            return Err(ServiceError::EmptyBatch {
                kernel: self.kernel.name.clone(),
            });
        }
        self.check_arity(batch.arity())?;
        let deadline = deadline.and_then(|d| Instant::now().checked_add(d));
        let ticket = self
            .shared
            .submit_batch(
                self.tenant,
                self.id,
                batch,
                self.kernel.n_outputs,
                deadline,
                waker,
            )
            .map_err(|r| self.rejection(r))?;
        Ok(PendingBatch {
            shared: Arc::clone(&self.shared),
            ticket,
            kernel: Arc::clone(&self.kernel),
            tenant: self.tenant,
            rows: batch.n_rows(),
            done: false,
        })
    }

    /// Blocking batch call: [`Self::submit_batch`] + wait.
    pub fn call_batch(&self, batch: &FlatBatch) -> Result<FlatBatch, ServiceError> {
        self.submit_batch(batch)?.wait()
    }

    /// Blocking batch call under a deadline budget: on timeout the
    /// batch is cancelled — rows no worker has taken yet never execute
    /// — and the typed [`ServiceError::DeadlineExceeded`] is returned.
    pub fn call_batch_with_deadline(
        &self,
        batch: &FlatBatch,
        budget: Duration,
    ) -> Result<FlatBatch, ServiceError> {
        let mut p = self.submit_batch_with_deadline(batch, budget)?;
        match p.wait_timeout(budget) {
            Err(e @ ServiceError::DeadlineExceeded { .. }) => {
                p.cancel();
                Err(e)
            }
            other => other,
        }
    }

    /// Blocking batch call writing the reply rows into a caller-owned
    /// batch buffer (reshaped in place) — the results land straight in
    /// a buffer the caller can reuse across calls.
    pub fn call_batch_into(
        &self,
        batch: &FlatBatch,
        out: &mut FlatBatch,
    ) -> Result<(), ServiceError> {
        self.submit_batch(batch)?.wait_into(out)
    }
}

// ---------------------------------------------------------------------
// Pending replies
// ---------------------------------------------------------------------

/// A future-like reply to a [`KernelHandle::submit`]: poll it, block
/// on it, or bound the wait with a deadline. It is a thin
/// `{slot, generation}` ticket into the engine's completion slab —
/// not a channel — so it is `Copy`-cheap to create and free to drop
/// (an uncollected reply's slot recycles automatically). One-shot:
/// after a result has been produced, further waits report
/// [`ServiceError::Disconnected`].
pub struct Pending {
    shared: Arc<Shared>,
    ticket: Ticket,
    kernel: Arc<CompiledKernel>,
    tenant: TenantId,
    done: bool,
}

impl fmt::Debug for Pending {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Pending({})", self.kernel.name)
    }
}

impl Pending {
    /// The kernel this reply belongs to.
    pub fn kernel_name(&self) -> &str {
        &self.kernel.name
    }

    /// The one place the "result already taken" state is mapped to
    /// its typed error — every receive path below shares it.
    fn disconnected(&self) -> ServiceError {
        ServiceError::Disconnected {
            kernel: self.kernel.name.clone(),
        }
    }

    /// Non-blocking check: `Some(result)` once the reply has arrived.
    pub fn poll(&mut self) -> Option<Result<Vec<i32>, ServiceError>> {
        let mut out = Vec::new();
        self.poll_into(&mut out).map(|r| r.map(|()| out))
    }

    /// [`Self::poll`] into a caller-owned buffer (cleared on success) —
    /// the allocation-free variant.
    pub fn poll_into(&mut self, out: &mut Vec<i32>) -> Option<Result<(), ServiceError>> {
        if self.done {
            return Some(Err(self.disconnected()));
        }
        let r = self.shared.slab.try_take_row(self.ticket, out)?;
        self.done = true;
        Some(r.map_err(ServiceError::from))
    }

    /// Block until the reply arrives.
    pub fn wait(mut self) -> Result<Vec<i32>, ServiceError> {
        let mut out = Vec::new();
        self.wait_into(&mut out)?;
        Ok(out)
    }

    /// Block until the reply arrives, writing the row into a
    /// caller-owned buffer (cleared first). With a reused `out`, a
    /// steady-state submit → wait round trip performs **zero** heap
    /// allocations (audited by bench §B6).
    pub fn wait_into(&mut self, out: &mut Vec<i32>) -> Result<(), ServiceError> {
        if self.done {
            return Err(self.disconnected());
        }
        let r = self
            .shared
            .slab
            .wait_row(self.ticket, None, out)
            .expect("unbounded wait cannot time out");
        self.done = true;
        r.map_err(ServiceError::from)
    }

    /// Block at most `timeout`; [`ServiceError::DeadlineExceeded`] if
    /// the reply has not arrived by then. The request itself stays in
    /// flight — poll or wait again to pick the reply up later.
    pub fn wait_timeout(&mut self, timeout: Duration) -> Result<Vec<i32>, ServiceError> {
        if self.done {
            return Err(self.disconnected());
        }
        let mut out = Vec::new();
        // An unrepresentable deadline (absurdly long timeout) waits
        // unbounded instead of panicking on Instant overflow.
        let deadline = Instant::now().checked_add(timeout);
        match self.shared.slab.wait_row(self.ticket, deadline, &mut out) {
            Some(r) => {
                self.done = true;
                r.map_err(ServiceError::from)?;
                Ok(out)
            }
            None => Err(ServiceError::DeadlineExceeded {
                kernel: self.kernel.name.clone(),
            }),
        }
    }

    /// Block until `deadline` at the latest (expressed through
    /// [`Self::wait_timeout`] — one timing implementation, not two).
    pub fn wait_deadline(&mut self, deadline: Instant) -> Result<Vec<i32>, ServiceError> {
        self.wait_timeout(deadline.saturating_duration_since(Instant::now()))
    }

    /// Cancel the request: rows still waiting in the queue are removed
    /// and **never execute** (they move to the `cancelled` ledger
    /// term), rows a worker already took finish into the reclaimed
    /// slot, and either way the slot is released without a collect.
    /// Idempotent, and a no-op after the reply was taken. After
    /// cancelling, the reply can no longer be collected.
    pub fn cancel(&mut self) {
        if !self.done {
            self.done = true;
            self.shared.cancel(self.tenant, self.ticket);
        }
    }
}

impl Drop for Pending {
    fn drop(&mut self) {
        // An uncollected reply must not leak its slot: ready slots
        // free now, in-flight ones when their worker finishes.
        if !self.done {
            self.shared.slab.abandon(self.ticket);
        }
    }
}

/// A future-like reply to a [`KernelHandle::submit_batch`]: the whole
/// batch shares one completion-slab slot (one reservation, one
/// in-place reply buffer), becomes ready when its last row completes,
/// and is collected as a row-ordered [`FlatBatch`]. Same one-shot
/// contract as [`Pending`].
pub struct PendingBatch {
    shared: Arc<Shared>,
    ticket: Ticket,
    kernel: Arc<CompiledKernel>,
    tenant: TenantId,
    rows: usize,
    done: bool,
}

impl fmt::Debug for PendingBatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PendingBatch({} x {})", self.kernel.name, self.rows)
    }
}

impl PendingBatch {
    /// The kernel this reply belongs to.
    pub fn kernel_name(&self) -> &str {
        &self.kernel.name
    }

    /// Rows submitted (and rows the reply will carry).
    pub fn n_rows(&self) -> usize {
        self.rows
    }

    fn disconnected(&self) -> ServiceError {
        ServiceError::Disconnected {
            kernel: self.kernel.name.clone(),
        }
    }

    /// Non-blocking check: `Some(rows)` once every row has completed.
    pub fn poll(&mut self) -> Option<Result<FlatBatch, ServiceError>> {
        let mut out = FlatBatch::default();
        self.poll_into(&mut out).map(|r| r.map(|()| out))
    }

    /// [`Self::poll`] into a caller-owned batch buffer.
    pub fn poll_into(&mut self, out: &mut FlatBatch) -> Option<Result<(), ServiceError>> {
        if self.done {
            return Some(Err(self.disconnected()));
        }
        let r = self.shared.slab.try_take_batch(self.ticket, out)?;
        self.done = true;
        Some(r.map_err(ServiceError::from))
    }

    /// Block until every row has completed.
    pub fn wait(mut self) -> Result<FlatBatch, ServiceError> {
        let mut out = FlatBatch::default();
        self.wait_into(&mut out)?;
        Ok(out)
    }

    /// Block until every row has completed, writing the rows into a
    /// caller-owned batch buffer (reshaped in place).
    pub fn wait_into(&mut self, out: &mut FlatBatch) -> Result<(), ServiceError> {
        if self.done {
            return Err(self.disconnected());
        }
        let r = self
            .shared
            .slab
            .wait_batch(self.ticket, None, out)
            .expect("unbounded wait cannot time out");
        self.done = true;
        r.map_err(ServiceError::from)
    }

    /// Block at most `timeout`; [`ServiceError::DeadlineExceeded`] if
    /// the rows have not all completed by then. The batch stays in
    /// flight — poll or wait again later.
    pub fn wait_timeout(&mut self, timeout: Duration) -> Result<FlatBatch, ServiceError> {
        if self.done {
            return Err(self.disconnected());
        }
        let mut out = FlatBatch::default();
        let deadline = Instant::now().checked_add(timeout);
        match self.shared.slab.wait_batch(self.ticket, deadline, &mut out) {
            Some(r) => {
                self.done = true;
                r.map_err(ServiceError::from)?;
                Ok(out)
            }
            None => Err(ServiceError::DeadlineExceeded {
                kernel: self.kernel.name.clone(),
            }),
        }
    }

    /// Cancel the batch (see [`Pending::cancel`]): rows no worker has
    /// taken yet are removed unexecuted, in-flight rows finish into
    /// the reclaimed slot, and the slot is released without a collect.
    pub fn cancel(&mut self) {
        if !self.done {
            self.done = true;
            self.shared.cancel(self.tenant, self.ticket);
        }
    }
}

impl Drop for PendingBatch {
    fn drop(&mut self) {
        if !self.done {
            self.shared.slab.abandon(self.ticket);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::eval;
    use crate::frontend;
    use crate::util::prng::Rng;

    fn service(backend: BackendKind, pipelines: usize, max_batch: usize) -> OverlayService {
        OverlayService::builder()
            .backend(backend)
            .pipelines(pipelines)
            .max_batch(max_batch)
            .build()
            .unwrap()
    }

    fn mixed_workload(svc: &OverlayService, requests: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let handles = svc.handles();
        let mut jobs = Vec::new();
        for _ in 0..requests {
            let h = rng.choose(&handles);
            let inputs: Vec<i32> = (0..h.arity())
                .map(|_| rng.range_i64(-500, 500) as i32)
                .collect();
            let want = eval(&h.compiled().dfg, &inputs);
            jobs.push((h.submit(&inputs).unwrap(), want));
        }
        for (p, want) in jobs {
            assert_eq!(p.wait().unwrap(), want);
        }
    }

    // ---- sim backend: runs unconditionally, zero artifacts ----------

    #[test]
    fn serves_mixed_workload_correctly() {
        let svc = service(BackendKind::Sim, 1, 8);
        mixed_workload(&svc, 40, 5);
        assert_eq!(svc.completed(), 40);
        let snap = svc.metrics();
        assert_eq!(snap.completed, 40);
        assert_eq!(snap.rejected, 0);
        assert!(snap.context_switches > 0);
        assert!(snap.render().contains("context switches"));
        svc.shutdown().unwrap();
    }

    #[test]
    fn call_blocks_for_result() {
        let svc = service(BackendKind::Sim, 1, 4);
        let h = svc.kernel("gradient").unwrap();
        assert_eq!(h.arity(), 5);
        assert_eq!(h.n_outputs(), 1);
        assert_eq!(h.call(&[3, 5, 2, 7, 1]).unwrap(), vec![1 + 9 + 25 + 1]);
        svc.shutdown().unwrap();
    }

    #[test]
    fn call_into_reuses_the_caller_buffer() {
        let svc = service(BackendKind::Turbo, 1, 4);
        let h = svc.kernel("gradient").unwrap();
        let mut out = Vec::new();
        for i in 0..8 {
            h.call_into(&[3, 5, 2, 7, i], &mut out).unwrap();
            assert_eq!(out, vec![1 + 9 + 25 + (2 - i) * (2 - i)]);
        }
        svc.shutdown().unwrap();
    }

    #[test]
    fn unknown_kernel_and_shape_mismatch_are_typed() {
        let svc = service(BackendKind::Sim, 1, 4);
        assert_eq!(
            svc.kernel("nonesuch").unwrap_err(),
            ServiceError::UnknownKernel("nonesuch".to_string())
        );
        let h = svc.kernel("gradient").unwrap();
        // Wrong arity is refused at the handle, before any queueing.
        assert_eq!(
            h.call(&[1, 2]).unwrap_err(),
            ServiceError::ShapeMismatch {
                kernel: "gradient".to_string(),
                expected: 5,
                got: 2
            }
        );
        // Batch shape errors are typed too.
        assert_eq!(
            h.call_batch(&FlatBatch::new(5)).unwrap_err(),
            ServiceError::EmptyBatch {
                kernel: "gradient".to_string()
            }
        );
        assert!(matches!(
            h.call_batch(&FlatBatch::from_rows(2, &[vec![1, 2]])),
            Err(ServiceError::ShapeMismatch { got: 2, .. })
        ));
        svc.shutdown().unwrap();
    }

    #[test]
    fn multiple_sim_workers_serve_concurrently() {
        let svc = service(BackendKind::Sim, 3, 8);
        mixed_workload(&svc, 60, 11);
        assert_eq!(svc.completed(), 60);
        svc.shutdown().unwrap();
    }

    #[test]
    fn ref_backend_serves_too() {
        let svc = service(BackendKind::Ref, 2, 16);
        assert_eq!(svc.backend(), BackendKind::Ref);
        mixed_workload(&svc, 30, 7);
        svc.shutdown().unwrap();
    }

    #[test]
    fn turbo_backend_serves_too() {
        let svc = service(BackendKind::Turbo, 2, 32);
        assert_eq!(svc.backend(), BackendKind::Turbo);
        mixed_workload(&svc, 50, 13);
        assert_eq!(svc.completed(), 50);
        svc.shutdown().unwrap();
    }

    #[test]
    fn call_batch_matches_oracle_rowwise() {
        let svc = service(BackendKind::Turbo, 2, 8);
        let h = svc.kernel("poly6").unwrap();
        let mut rng = Rng::new(99);
        let mut batch = FlatBatch::new(h.arity());
        for _ in 0..23 {
            batch.push_iter((0..h.arity()).map(|_| rng.range_i64(-2000, 2000) as i32));
        }
        let out = h.call_batch(&batch).unwrap();
        assert_eq!(out.n_rows(), 23);
        assert_eq!(out.arity(), h.n_outputs());
        for (i, row) in batch.iter().enumerate() {
            assert_eq!(out.row(i), &eval(&h.compiled().dfg, row)[..]);
        }
        svc.shutdown().unwrap();
    }

    #[test]
    fn submit_batch_is_nonblocking_and_oracle_exact() {
        let svc = service(BackendKind::Turbo, 2, 8);
        let h = svc.kernel("gradient").unwrap();
        let mut rng = Rng::new(123);
        let mut batch = FlatBatch::new(h.arity());
        for _ in 0..37 {
            batch.push_iter((0..h.arity()).map(|_| rng.range_i64(-1000, 1000) as i32));
        }
        let mut p = h.submit_batch(&batch).unwrap();
        assert_eq!(p.n_rows(), 37);
        assert_eq!(p.kernel_name(), "gradient");
        // Poll to completion (exercises the try_take path), then
        // verify row order against the oracle.
        let out = loop {
            if let Some(r) = p.poll() {
                break r.unwrap();
            }
            std::thread::yield_now();
        };
        assert_eq!(out.n_rows(), 37);
        for (i, row) in batch.iter().enumerate() {
            assert_eq!(out.row(i), &eval(&h.compiled().dfg, row)[..], "row {i}");
        }
        // One-shot: the result was taken; the batch reports it.
        assert!(matches!(
            p.poll(),
            Some(Err(ServiceError::Disconnected { .. }))
        ));
        // call_batch_into lands the rows in a reused caller buffer.
        let mut out2 = FlatBatch::default();
        h.call_batch_into(&batch, &mut out2).unwrap();
        assert_eq!(out2, out);
        svc.shutdown().unwrap();
    }

    #[test]
    fn pending_batch_wait_timeout_leaves_the_batch_in_flight() {
        let svc = service(BackendKind::Sim, 1, 4);
        let h = svc.kernel("poly6").unwrap();
        let rows: Vec<Vec<i32>> = (0..16).map(|i| vec![i, i + 1, i + 2]).collect();
        let batch = FlatBatch::from_rows(3, &rows);
        let mut p = h.submit_batch(&batch).unwrap();
        // A zero timeout may or may not beat the workers; both
        // outcomes are legal, and a timeout must not consume the
        // reply.
        match p.wait_timeout(Duration::from_micros(0)) {
            Ok(out) => assert_eq!(out.n_rows(), 16),
            Err(ServiceError::DeadlineExceeded { .. }) => {
                let out = p.wait_timeout(Duration::from_secs(30)).unwrap();
                assert_eq!(out.n_rows(), 16);
            }
            Err(other) => panic!("unexpected error: {other}"),
        }
        svc.shutdown().unwrap();
    }

    #[test]
    fn dropped_pendings_do_not_leak_or_wedge_the_service() {
        let svc = service(BackendKind::Turbo, 2, 8);
        let h = svc.kernel("gradient").unwrap();
        // Drop before completion, drop after completion, drop a batch:
        // the slots must recycle either way and the service stays
        // healthy.
        for i in 0..32 {
            let p = h.submit(&[1, 2, 3, 4, i]).unwrap();
            drop(p);
        }
        let p = h.submit(&[1, 2, 3, 4, 5]).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        drop(p);
        let batch = FlatBatch::from_rows(5, &[vec![0; 5], vec![1; 5]]);
        drop(h.submit_batch(&batch).unwrap());
        // The service still serves correctly afterwards.
        assert_eq!(h.call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);
        svc.shutdown().unwrap();
    }

    #[test]
    fn handles_are_clone_send_sessions() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<KernelHandle>();
        assert_send_sync::<OverlayService>();
        assert_send_sync::<Pending>();
        assert_send_sync::<PendingBatch>();

        let svc = service(BackendKind::Turbo, 2, 16);
        let h = svc.kernel("chebyshev").unwrap();
        let mut threads = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            threads.push(std::thread::spawn(move || {
                for i in 0..10 {
                    let x = t * 10 + i;
                    assert_eq!(h.call(&[x]).unwrap(), vec![eval(&h.compiled().dfg, &[x])[0]]);
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(svc.completed(), 40);
        svc.shutdown().unwrap();
    }

    #[test]
    fn shutdown_drains_then_rejects_new_work() {
        let svc = service(BackendKind::Sim, 1, 8);
        let h = svc.kernel("gradient").unwrap();
        // Admit work, then shut down before collecting: every admitted
        // request must still be answered (drain semantics).
        let mut pendings = Vec::new();
        for i in 0..12 {
            pendings.push(h.submit(&[3, 5, 2, 7, i]).unwrap());
        }
        svc.shutdown().unwrap();
        for (i, p) in pendings.into_iter().enumerate() {
            let i = i as i32;
            assert_eq!(p.wait().unwrap(), vec![1 + 9 + 25 + (2 - i) * (2 - i)]);
        }
        // The handle outlives the service value, but new work is
        // refused with the typed shutdown error.
        assert_eq!(h.call(&[0; 5]).unwrap_err(), ServiceError::ShutDown);
        assert_eq!(h.submit(&[0; 5]).unwrap_err(), ServiceError::ShutDown);
        let one = FlatBatch::from_rows(5, &[vec![0; 5]]);
        assert_eq!(h.call_batch(&one).unwrap_err(), ServiceError::ShutDown);
    }

    #[test]
    fn admission_rejection_is_typed_and_counted() {
        let svc = OverlayService::builder()
            .backend(BackendKind::Ref)
            .pipelines(1)
            .max_batch(4)
            .queue_depth(2)
            .build()
            .unwrap();
        let h = svc.kernel("gradient").unwrap();
        // A batch wider than the whole queue depth is deterministically
        // rejected, whatever the workers are doing.
        let rows: Vec<Vec<i32>> = (0..3).map(|i| vec![i; 5]).collect();
        let batch = FlatBatch::from_rows(5, &rows);
        match h.call_batch(&batch).unwrap_err() {
            ServiceError::Rejected { kernel, limit, .. } => {
                assert_eq!(kernel, "gradient");
                assert_eq!(limit, 2);
            }
            other => panic!("expected Rejected, got {other}"),
        }
        assert_eq!(svc.metrics().rejected, 3);
        assert_eq!(svc.completed(), 0);
        svc.shutdown().unwrap();
    }

    #[test]
    fn tenant_quota_rejects_with_the_tenant_named() {
        let svc = OverlayService::builder()
            .backend(BackendKind::Ref)
            .pipelines(1)
            .queue_depth(64)
            .tenant_weight("greedy", 2)
            .tenant_quota("greedy", 2)
            .build()
            .unwrap();
        assert_eq!(svc.tenant_names(), vec!["default", "greedy"]);
        let h = svc.kernel_for("gradient", "greedy").unwrap();
        assert_eq!(h.tenant_name(), "greedy");
        // A batch wider than greedy's whole quota is deterministically
        // rejected, and the error names the tenant, not just the
        // kernel.
        let rows: Vec<Vec<i32>> = (0..3).map(|i| vec![i; 5]).collect();
        let batch = FlatBatch::from_rows(5, &rows);
        match h.call_batch(&batch).unwrap_err() {
            ServiceError::Rejected { tenant, limit, .. } => {
                assert_eq!(tenant, "greedy");
                assert_eq!(limit, 2);
            }
            other => panic!("expected Rejected, got {other}"),
        }
        // Other lanes are not bound by greedy's quota; unknown tenant
        // names fall back to the default lane.
        let d = svc.kernel_for("gradient", "nonesuch").unwrap();
        assert_eq!(d.tenant_name(), "default");
        assert_eq!(d.call_batch(&batch).unwrap().n_rows(), 3);
        svc.shutdown().unwrap();
    }

    #[test]
    fn call_with_deadline_misses_are_typed_and_reclaim_the_slot() {
        let svc = service(BackendKind::Sim, 1, 8);
        let h = svc.kernel("gradient").unwrap();
        // Saturate the single worker so a zero-budget call cannot win.
        let rows: Vec<Vec<i32>> = (0..1024).map(|i| vec![3, 5, 2, 7, i]).collect();
        let big = FlatBatch::from_rows(5, &rows);
        let pending_big = h.submit_batch(&big).unwrap();
        let err = h.call_with_deadline(&[0; 5], Duration::ZERO).unwrap_err();
        assert!(
            matches!(err, ServiceError::DeadlineExceeded { ref kernel } if kernel == "gradient"),
            "{err}"
        );
        // The miss cancelled itself: once the big batch is collected,
        // no slot lingers from the deadlined call.
        assert_eq!(pending_big.wait().unwrap().n_rows(), 1024);
        assert_eq!(svc.live_slots(), 0);
        // The ledger balances with the new cancelled term (the missed
        // call was either purged from the queue → cancelled, or raced
        // into a worker → completed into the abandoned slot).
        svc.shutdown().unwrap();
        let snap = svc.metrics();
        assert_eq!(
            snap.admitted(),
            snap.completed + snap.failed + snap.cancelled
        );
        svc.shutdown().unwrap();
    }

    #[test]
    fn explicit_cancel_is_idempotent_and_frees_the_slot() {
        let svc = service(BackendKind::Sim, 1, 8);
        let h = svc.kernel("gradient").unwrap();
        let rows: Vec<Vec<i32>> = (0..512).map(|i| vec![3, 5, 2, 7, i]).collect();
        let big = FlatBatch::from_rows(5, &rows);
        let pending_big = h.submit_batch(&big).unwrap();
        let mut p = h.submit(&[0; 5]).unwrap();
        p.cancel();
        p.cancel(); // second cancel is a no-op
        // After cancel the reply is gone for good.
        assert!(matches!(
            p.poll(),
            Some(Err(ServiceError::Disconnected { .. }))
        ));
        let mut pb = h
            .submit_batch(&FlatBatch::from_rows(5, &[vec![0; 5], vec![1; 5]]))
            .unwrap();
        pb.cancel();
        pb.cancel();
        assert_eq!(pending_big.wait().unwrap().n_rows(), 512);
        assert_eq!(svc.live_slots(), 0);
        svc.shutdown().unwrap();
        let snap = svc.metrics();
        assert_eq!(
            snap.admitted(),
            snap.completed + snap.failed + snap.cancelled
        );
    }

    #[test]
    fn pending_polls_to_completion() {
        let svc = service(BackendKind::Turbo, 1, 4);
        let h = svc.kernel("gradient").unwrap();
        let mut p = h.submit(&[3, 5, 2, 7, 1]).unwrap();
        let got = loop {
            if let Some(r) = p.poll() {
                break r.unwrap();
            }
            std::thread::yield_now();
        };
        assert_eq!(got, vec![36]);
        // One-shot contract: a second poll reports the taken state.
        assert!(matches!(
            p.poll(),
            Some(Err(ServiceError::Disconnected { .. }))
        ));
        svc.shutdown().unwrap();
    }

    #[test]
    fn custom_kernel_registry() {
        let g = frontend::compile("kernel twice_plus(a, b) { return a + a + b; }").unwrap();
        let svc = OverlayService::builder()
            .backend(BackendKind::Sim)
            .kernels(vec![g])
            .build()
            .unwrap();
        assert_eq!(svc.kernel_names(), vec!["twice_plus"]);
        let h = svc.kernel("twice_plus").unwrap();
        assert_eq!(h.call(&[10, 3]).unwrap(), vec![23]);
        // The bench suite is not present in a custom registry.
        assert!(svc.kernel("gradient").is_err());
        svc.shutdown().unwrap();
    }

    // ---- PJRT backend: artifact-gated variants ----------------------

    fn artifacts_dir() -> Option<String> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| dir.to_string_lossy().into_owned())
    }

    #[test]
    fn pjrt_serves_when_artifacts_exist() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let svc = OverlayService::builder()
            .backend(BackendKind::Pjrt)
            .artifacts_dir(dir)
            .pipelines(1)
            .max_batch(8)
            .build()
            .unwrap();
        mixed_workload(&svc, 40, 5);
        assert_eq!(svc.completed(), 40);
        svc.shutdown().unwrap();
    }

    #[test]
    fn missing_artifacts_fail_the_build() {
        let err = OverlayService::builder()
            .backend(BackendKind::Pjrt)
            .artifacts_dir("/definitely/not/here")
            .build()
            .unwrap_err();
        match err {
            ServiceError::Backend { backend, message } => {
                assert_eq!(backend, "pjrt");
                assert!(message.contains("artifacts"), "{message}");
            }
            other => panic!("expected Backend error, got {other}"),
        }
    }
}
