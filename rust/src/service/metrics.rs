//! Typed, serializable serving metrics.
//!
//! [`MetricsSnapshot`] is the client-facing view of the engine's raw
//! counters ([`crate::coordinator::metrics::Metrics`]): the engine
//! hands over a detached [`RawMetrics`] copy (sample buffers cloned
//! under a short lock), and *this* module does the expensive part —
//! sorting the latency samples for percentiles — on the caller's
//! thread, outside every engine lock, so a metrics poll (in-process
//! or `GetMetrics` over the wire) can never stall workers mid-batch.
//! The snapshot is plain data (`Clone + PartialEq`), serializes to
//! JSON via [`crate::util::json`] (`tmfu serve --metrics-json`, CI
//! assertions), and renders the human-readable report the CLI prints.
//! It replaces the old string-report API — tooling asserts on fields,
//! not on scraped text.

use crate::coordinator::metrics::RawMetrics;
use crate::util::json::{self, Json};

pub use crate::util::stats::LatencySummary;

/// JSON form of one distribution summary (stable field names).
fn summary_json(s: &LatencySummary) -> Json {
    json::obj(vec![
        ("n", json::i(s.n as i64)),
        ("mean", json::f(s.mean)),
        ("p50", json::f(s.p50)),
        ("p95", json::f(s.p95)),
        ("p99", json::f(s.p99)),
        ("min", json::f(s.min)),
        ("max", json::f(s.max)),
    ])
}

/// One tenant's slice of the snapshot: the admission ledger (after a
/// drain, `admitted == completed + failed`) plus that tenant's own
/// end-to-end latency percentiles — the observable half of the
/// weighted-fairness guarantee.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantMetrics {
    pub name: String,
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    /// Queued rows removed by explicit `Cancel` before execution —
    /// the third settlement term:
    /// `admitted == completed + failed + cancelled`.
    pub cancelled: u64,
    /// Subset of `failed`: rows whose deadline lapsed in the queue
    /// (evicted unexecuted by lazy expiry).
    pub expired_in_queue: u64,
    /// Requests shed at admission because the estimated queue wait
    /// already exceeded their deadline budget (never admitted).
    pub shed_at_admission: u64,
    pub latency_us: Option<LatencySummary>,
}

/// A point-in-time view of everything the service has done.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// Execution substrate name (`"ref"`, `"sim"`, `"pjrt"`, `"turbo"`).
    pub backend: String,
    /// Fabric workers (overlay pipeline replicas).
    pub workers: usize,
    /// Per-kernel admission bound.
    pub queue_depth: usize,
    /// Requests completed successfully (replied `Ok`).
    pub completed: u64,
    /// Requests refused by admission control.
    pub rejected: u64,
    /// Admitted requests whose execution failed (replied `Err` —
    /// backend failure, or queue expiry).
    /// `admitted == completed + failed + cancelled`.
    pub failed: u64,
    /// Queued rows removed by explicit `Cancel` before execution.
    pub cancelled: u64,
    /// Subset of `failed`: rows whose deadline lapsed waiting in the
    /// queue — evicted by lazy expiry, never executed.
    pub expired_in_queue: u64,
    /// Requests shed at admission for an infeasible deadline budget
    /// (never admitted; a sibling of `rejected`).
    pub shed_at_admission: u64,
    /// Batches dispatched to workers.
    pub batches: u64,
    pub mean_batch_size: f64,
    pub context_switches: u64,
    /// Heap allocations observed on the workers' dispatch path
    /// (take → gather → execute → reply, excluding the metrics
    /// sample buffers). 0 in steady state — the bench hard-asserts
    /// it; requires the counting allocator to be installed (bench
    /// binaries), otherwise reads 0.
    pub worker_allocs: u64,
    /// Simulated overlay fabric time (µs at 300 MHz), incl. switches.
    pub fabric_busy_us: f64,
    /// Simulated time spent on context switching only.
    pub fabric_switch_us: f64,
    /// Wall-clock seconds since the service started.
    pub wall_s: f64,
    /// Completed requests per wall-clock second.
    pub requests_per_s: f64,
    /// End-to-end request latency (enqueue → reply), if any completed.
    pub latency_us: Option<LatencySummary>,
    /// Time spent queued before execution, if any completed.
    pub queue_wait_us: Option<LatencySummary>,
    /// Completed requests per kernel, name-sorted (kernels with no
    /// traffic are omitted, as before the dense-counter refactor).
    pub per_kernel: Vec<(String, u64)>,
    /// Per-tenant ledgers + latency, in [`TenantId`]
    /// (lane) order; tenants with no traffic are omitted like idle
    /// kernels.
    ///
    /// [`TenantId`]: crate::coordinator::TenantId
    pub per_tenant: Vec<TenantMetrics>,
}

impl MetricsSnapshot {
    /// Build a snapshot from a detached raw copy. `names` maps dense
    /// [`KernelId`](crate::exec::KernelId) indices back to kernel
    /// names (the engine counts per id; only this boundary speaks
    /// strings). Percentile sorting happens here — on the raw copy,
    /// never under an engine lock.
    pub(crate) fn collect(
        mut raw: RawMetrics,
        names: &[&str],
        tenants: &[&str],
        backend: &str,
        workers: usize,
        queue_depth: usize,
    ) -> MetricsSnapshot {
        let wall_s = raw.wall.as_secs_f64().max(1e-9);
        let mut per_kernel: Vec<(String, u64)> = names
            .iter()
            .zip(&raw.per_kernel)
            .filter(|(_, &count)| count > 0)
            .map(|(name, &count)| (name.to_string(), count))
            .collect();
        per_kernel.sort_by(|a, b| a.0.cmp(&b.0));
        let per_tenant: Vec<TenantMetrics> = tenants
            .iter()
            .zip(raw.per_tenant.iter_mut())
            .filter(|(_, t)| t.admitted + t.rejected + t.shed_at_admission > 0)
            .map(|(name, t)| TenantMetrics {
                name: name.to_string(),
                admitted: t.admitted,
                rejected: t.rejected,
                completed: t.completed,
                failed: t.failed,
                cancelled: t.cancelled,
                expired_in_queue: t.expired_in_queue,
                shed_at_admission: t.shed_at_admission,
                latency_us: t.latency_us.summarize(),
            })
            .collect();
        MetricsSnapshot {
            backend: backend.to_string(),
            workers,
            queue_depth,
            completed: raw.completed,
            rejected: raw.rejected,
            failed: raw.failed,
            cancelled: raw.cancelled,
            expired_in_queue: raw.expired_in_queue,
            shed_at_admission: raw.shed_at_admission,
            batches: raw.batches,
            mean_batch_size: raw.mean_batch_size(),
            context_switches: raw.context_switches,
            worker_allocs: raw.worker_allocs,
            fabric_busy_us: raw.fabric_busy_us,
            fabric_switch_us: raw.fabric_switch_us,
            wall_s,
            requests_per_s: raw.completed as f64 / wall_s,
            latency_us: raw.latency_us.summarize(),
            queue_wait_us: raw.queue_wait_us.summarize(),
            per_kernel,
            per_tenant,
        }
    }

    /// Total rows admitted across every tenant lane. With the ledger
    /// settled (post-drain), `admitted() == completed + failed +
    /// cancelled` — the extended settlement invariant the deadline
    /// tests assert at every layer.
    pub fn admitted(&self) -> u64 {
        // Idle tenants are omitted from `per_tenant`, but an omitted
        // tenant admitted nothing, so the sum is exact.
        self.per_tenant.iter().map(|t| t.admitted).sum()
    }

    /// Machine-readable form (stable field names; `tmfu serve
    /// --metrics-json`, CI assertions, `tools/`).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("backend", json::s(&self.backend)),
            ("workers", json::i(self.workers as i64)),
            ("queue_depth", json::i(self.queue_depth as i64)),
            ("completed", json::i(self.completed as i64)),
            ("rejected", json::i(self.rejected as i64)),
            ("failed", json::i(self.failed as i64)),
            ("cancelled", json::i(self.cancelled as i64)),
            ("expired_in_queue", json::i(self.expired_in_queue as i64)),
            ("shed_at_admission", json::i(self.shed_at_admission as i64)),
            ("batches", json::i(self.batches as i64)),
            ("mean_batch_size", json::f(self.mean_batch_size)),
            ("context_switches", json::i(self.context_switches as i64)),
            ("worker_allocs", json::i(self.worker_allocs as i64)),
            ("fabric_busy_us", json::f(self.fabric_busy_us)),
            ("fabric_switch_us", json::f(self.fabric_switch_us)),
            ("wall_s", json::f(self.wall_s)),
            ("requests_per_s", json::f(self.requests_per_s)),
            (
                "latency_us",
                self.latency_us.as_ref().map_or(Json::Null, summary_json),
            ),
            (
                "queue_wait_us",
                self.queue_wait_us.as_ref().map_or(Json::Null, summary_json),
            ),
            (
                "per_kernel",
                json::obj(
                    self.per_kernel
                        .iter()
                        .map(|(k, v)| (k.as_str(), json::i(*v as i64)))
                        .collect(),
                ),
            ),
            (
                "per_tenant",
                json::obj(
                    self.per_tenant
                        .iter()
                        .map(|t| {
                            (
                                t.name.as_str(),
                                json::obj(vec![
                                    ("admitted", json::i(t.admitted as i64)),
                                    ("rejected", json::i(t.rejected as i64)),
                                    ("completed", json::i(t.completed as i64)),
                                    ("failed", json::i(t.failed as i64)),
                                    ("cancelled", json::i(t.cancelled as i64)),
                                    (
                                        "expired_in_queue",
                                        json::i(t.expired_in_queue as i64),
                                    ),
                                    (
                                        "shed_at_admission",
                                        json::i(t.shed_at_admission as i64),
                                    ),
                                    (
                                        "latency_us",
                                        t.latency_us.as_ref().map_or(Json::Null, summary_json),
                                    ),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// The human-readable report `tmfu serve` prints.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "backend:              {} ({} worker(s), queue depth {})\n",
            self.backend, self.workers, self.queue_depth
        ));
        s.push_str(&format!(
            "requests completed:   {} in {:.3}s ({:.0} req/s wall)\n",
            self.completed, self.wall_s, self.requests_per_s
        ));
        if self.rejected > 0 {
            s.push_str(&format!(
                "admission rejected:   {} (per-kernel queue depth {})\n",
                self.rejected, self.queue_depth
            ));
        }
        if self.failed > 0 {
            s.push_str(&format!(
                "execution failures:   {} (admitted, replied Err)\n",
                self.failed
            ));
        }
        if self.cancelled > 0 {
            s.push_str(&format!(
                "cancelled in queue:   {} (removed unexecuted by Cancel)\n",
                self.cancelled
            ));
        }
        if self.expired_in_queue > 0 {
            s.push_str(&format!(
                "expired in queue:     {} (deadline lapsed, evicted unexecuted)\n",
                self.expired_in_queue
            ));
        }
        if self.shed_at_admission > 0 {
            s.push_str(&format!(
                "shed at admission:    {} (deadline infeasible, never admitted)\n",
                self.shed_at_admission
            ));
        }
        s.push_str(&format!(
            "batches:              {} (mean size {:.1})\n",
            self.batches, self.mean_batch_size
        ));
        s.push_str(&format!(
            "context switches:     {} ({:.2} us simulated switch time total)\n",
            self.context_switches, self.fabric_switch_us
        ));
        s.push_str(&format!(
            "simulated fabric busy: {:.1} us ({:.2}% of wall)\n",
            self.fabric_busy_us,
            self.fabric_busy_us / (self.wall_s * 1e6) * 100.0
        ));
        if let Some(l) = &self.latency_us {
            s.push_str(&format!("request latency:      {}\n", l.render("us")));
        }
        if let Some(q) = &self.queue_wait_us {
            s.push_str(&format!("queue wait:           {}\n", q.render("us")));
        }
        s.push_str("per-kernel requests:  ");
        s.push_str(
            &self
                .per_kernel
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(" "),
        );
        s.push('\n');
        for t in &self.per_tenant {
            s.push_str(&format!(
                "tenant {:<14} admitted={} completed={} failed={} cancelled={} rejected={}",
                t.name, t.admitted, t.completed, t.failed, t.cancelled, t.rejected
            ));
            if t.expired_in_queue > 0 {
                s.push_str(&format!(" expired={}", t.expired_in_queue));
            }
            if t.shed_at_admission > 0 {
                s.push_str(&format!(" shed={}", t.shed_at_admission));
            }
            if let Some(l) = &t.latency_us {
                s.push_str(&format!(" p99={:.1}us", l.p99));
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::{BatchTiming, Metrics};
    use crate::coordinator::TenantId;
    use crate::exec::KernelId;
    use std::time::Duration;

    const NAMES: [&str; 2] = ["gradient", "poly6"];
    const TENANTS: [&str; 1] = ["default"];
    const T0: TenantId = TenantId(0);

    fn sample_raw() -> RawMetrics {
        let m = Metrics::new(2, 1);
        // 14 admitted = 12 completed + 1 failed + 1 cancelled, with
        // the failure being a queue expiry; 2 rejected + 3 shed never
        // entered the ledger.
        m.record_admitted(T0, 14);
        m.record_batch(
            KernelId(0),
            T0,
            8,
            BatchTiming {
                switched: true,
                switch_us: 0.2,
                exec_us_sim: 3.0,
            },
            std::iter::empty(),
        );
        m.record_batch(
            KernelId(1),
            T0,
            4,
            BatchTiming {
                switched: true,
                switch_us: 0.3,
                exec_us_sim: 5.0,
            },
            [120.0, 80.0].into_iter(),
        );
        m.record_rejected(T0, 2);
        m.record_failed(T0, 1);
        m.record_cancelled(T0, 1);
        m.record_expired(T0, 1);
        m.record_shed(T0, 3);
        let mut raw = m.raw_snapshot();
        raw.wall = Duration::from_millis(100);
        raw
    }

    #[test]
    fn collects_typed_fields() {
        let snap = MetricsSnapshot::collect(sample_raw(), &NAMES, &TENANTS, "sim", 2, 64);
        assert_eq!(snap.backend, "sim");
        assert_eq!(snap.workers, 2);
        assert_eq!(snap.queue_depth, 64);
        assert_eq!(snap.completed, 12);
        assert_eq!(snap.rejected, 2);
        assert_eq!(snap.failed, 1);
        assert_eq!(snap.batches, 2);
        assert_eq!(snap.context_switches, 2);
        assert!((snap.mean_batch_size - 6.0).abs() < 1e-12);
        assert!((snap.wall_s - 0.1).abs() < 1e-9);
        assert!((snap.requests_per_s - 120.0).abs() < 1e-6);
        let lat = snap.latency_us.unwrap();
        assert_eq!(lat.n, 2);
        assert!((lat.mean - 100.0).abs() < 1e-9);
        assert!((lat.max - 120.0).abs() < 1e-9);
        assert_eq!(
            snap.per_kernel,
            vec![("gradient".to_string(), 8), ("poly6".to_string(), 4)]
        );
        // The new deadline counters surface globally and the extended
        // settlement invariant holds on the snapshot itself.
        assert_eq!(snap.cancelled, 1);
        assert_eq!(snap.expired_in_queue, 1);
        assert_eq!(snap.shed_at_admission, 3);
        assert_eq!(snap.admitted(), snap.completed + snap.failed + snap.cancelled);
        // The tenant ledger rides along: one active tenant, with the
        // admitted/completed/failed/rejected counters it recorded.
        assert_eq!(snap.per_tenant.len(), 1);
        let t = &snap.per_tenant[0];
        assert_eq!(t.name, "default");
        assert_eq!(t.admitted, 14);
        assert_eq!(t.completed, 12);
        assert_eq!(t.failed, 1);
        assert_eq!(t.rejected, 2);
        assert_eq!(t.cancelled, 1);
        assert_eq!(t.expired_in_queue, 1);
        assert_eq!(t.shed_at_admission, 3);
        let lat = t.latency_us.as_ref().unwrap();
        assert_eq!(lat.n, 2);
        assert!((lat.max - 120.0).abs() < 1e-9);
    }

    #[test]
    fn empty_service_snapshot_is_well_formed() {
        let raw = Metrics::new(2, 1).raw_snapshot();
        let snap = MetricsSnapshot::collect(raw, &NAMES, &TENANTS, "turbo", 1, 16);
        assert_eq!(snap.completed, 0);
        assert_eq!(snap.latency_us, None);
        assert_eq!(snap.queue_wait_us, None);
        assert_eq!(snap.failed, 0);
        // Idle kernels and tenants are omitted, not rendered as zeros.
        assert!(snap.per_kernel.is_empty());
        assert!(snap.per_tenant.is_empty());
        let s = snap.render();
        assert!(s.contains("requests completed:   0"));
        // Rejection/failure lines only appear when they happened.
        assert!(!s.contains("admission rejected"));
        assert!(!s.contains("execution failures"));
    }

    #[test]
    fn renders_report_lines() {
        let snap = MetricsSnapshot::collect(sample_raw(), &NAMES, &TENANTS, "sim", 2, 64);
        let s = snap.render();
        assert!(s.contains("requests completed:   12"));
        assert!(s.contains("admission rejected:   2"));
        assert!(s.contains("execution failures:   1"));
        assert!(s.contains("context switches:     2"));
        assert!(s.contains("gradient=8"));
        assert!(s.contains("request latency:"));
        assert!(s.contains("tenant default"));
        assert!(s.contains("admitted=14"));
        // Deadline lines render only when the counters are non-zero.
        assert!(s.contains("cancelled in queue:   1"));
        assert!(s.contains("expired in queue:     1"));
        assert!(s.contains("shed at admission:    3"));
        assert!(s.contains("cancelled=1"));
        assert!(s.contains(" expired=1"));
        assert!(s.contains(" shed=3"));
    }

    #[test]
    fn json_round_trips_through_the_parser() {
        let snap = MetricsSnapshot::collect(sample_raw(), &NAMES, &TENANTS, "sim", 2, 64);
        let j = snap.to_json();
        let parsed = json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed, j);
        assert_eq!(parsed.get("completed").as_i64(), Some(12));
        assert_eq!(parsed.get("rejected").as_i64(), Some(2));
        assert_eq!(parsed.get("failed").as_i64(), Some(1));
        assert_eq!(parsed.get("backend").as_str(), Some("sim"));
        assert_eq!(parsed.get("per_kernel").get("gradient").as_i64(), Some(8));
        assert_eq!(parsed.get("latency_us").get("n").as_i64(), Some(2));
        assert_eq!(parsed.get("cancelled").as_i64(), Some(1));
        assert_eq!(parsed.get("expired_in_queue").as_i64(), Some(1));
        assert_eq!(parsed.get("shed_at_admission").as_i64(), Some(3));
        let t = parsed.get("per_tenant").get("default");
        assert_eq!(t.get("admitted").as_i64(), Some(14));
        assert_eq!(t.get("rejected").as_i64(), Some(2));
        assert_eq!(t.get("cancelled").as_i64(), Some(1));
        assert_eq!(t.get("expired_in_queue").as_i64(), Some(1));
        assert_eq!(t.get("shed_at_admission").as_i64(), Some(3));
        assert_eq!(t.get("latency_us").get("n").as_i64(), Some(2));
        // Empty distributions serialize as null, not a bogus summary.
        let empty = Metrics::new(2, 1).raw_snapshot();
        let j = MetricsSnapshot::collect(empty, &NAMES, &TENANTS, "ref", 1, 8).to_json();
        assert_eq!(*j.get("latency_us"), Json::Null);
    }
}
