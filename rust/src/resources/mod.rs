//! FPGA resource & frequency models (Xilinx 7-series), calibrated to
//! the paper's ISE synthesis results. See DESIGN.md §2: area results in
//! the paper are primitive counts + slice packing, which a structural
//! model reproduces without silicon.

pub mod device;
pub mod estimate;
pub mod fmax;

pub use device::{Device, VIRTEX7_485T, ZYNQ_Z7020};
pub use estimate::{area_paper_accounting, fu, overlay, pipeline, Resources};
pub use fmax::{pipeline_fmax, FU_FMAX_MHZ, SYSTEM_CLOCK_MHZ};
