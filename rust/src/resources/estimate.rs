//! Structural resource model, calibrated to the paper's ISE 14.6
//! synthesis results (§III.A):
//!
//! * FU standalone: 1 DSP48E1, 160 LUTs, 293 FFs @ 325 MHz (Z7020);
//! * 8-FU pipeline + 2 FIFOs: 8 DSPs, 808 LUTs, 1077 FFs @ 303 MHz
//!   (< 4% of the Zynq device);
//! * e-Slices: `slices + 60 × DSPs` (§V).
//!
//! The per-component constants below decompose those totals; the
//! calibration identities are locked by tests so any model change that
//! breaks the paper's numbers fails loudly.

use super::device::Device;

/// A bundle of FPGA resources.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Resources {
    pub luts: u32,
    pub ffs: u32,
    pub dsps: u32,
    pub bram36: u32,
    /// LUTs used as distributed RAM (subset of `luts`).
    pub lutram: u32,
}

impl Resources {
    pub fn add(&self, other: &Resources) -> Resources {
        Resources {
            luts: self.luts + other.luts,
            ffs: self.ffs + other.ffs,
            dsps: self.dsps + other.dsps,
            bram36: self.bram36 + other.bram36,
            lutram: self.lutram + other.lutram,
        }
    }

    pub fn scale(&self, n: u32) -> Resources {
        Resources {
            luts: self.luts * n,
            ffs: self.ffs * n,
            dsps: self.dsps * n,
            bram36: self.bram36 * n,
            lutram: self.lutram * n,
        }
    }

    /// Slice estimate: 7-series slices hold 4 LUT6 + 8 FFs; the packing
    /// efficiency is calibrated so the standalone FU occupies 81 slices
    /// (the paper's 141 e-Slices = 1 DSP (60) + 81).
    pub fn slices(&self) -> u32 {
        const PACKING_EFF: f64 = 0.494;
        let by_lut = self.luts as f64 / 4.0;
        let by_ff = self.ffs as f64 / 8.0;
        (by_lut.max(by_ff) / PACKING_EFF).round() as u32
    }

    /// The paper's combined metric.
    pub fn eslices(&self, dev: &Device) -> u32 {
        self.slices() + self.dsps * dev.slices_per_dsp()
    }
}

// ---------------------------------------------------------------------
// FU breakdown (sums to the paper's standalone synthesis result)
// ---------------------------------------------------------------------

/// Instruction memory: 32×32 b as 4 × RAM32M (paper §III.A), LUTRAM.
pub const IM_LUTS: u32 = 16;
/// Register file: 32×32 b, 2 read / 1 write, 8 × RAM32M.
pub const RF_LUTS: u32 = 32;
/// Control generator + PC/IC/DC counters + tag compare.
pub const CTRL_LUTS: u32 = 46;
/// RF/DSP operand routing & write-address multiplexing.
pub const MUX_LUTS: u32 = 66;

/// Datapath registers: C-port (32) + output (32) + ALU config (18).
pub const DATAPATH_FFS: u32 = 82;
/// 40-bit daisy-chain context shift register + tag register.
pub const CONTEXT_FFS: u32 = 48;
/// Input data register + valid pipeline.
pub const INPUT_FFS: u32 = 36;
/// Counters (PC/IC/DC, 5 b each) + FSM + flush counter.
pub const CTRL_FFS: u32 = 127;

/// Standalone FU (paper: 1 DSP, 160 LUTs, 293 FFs).
pub fn fu() -> Resources {
    Resources {
        luts: IM_LUTS + RF_LUTS + CTRL_LUTS + MUX_LUTS,
        ffs: DATAPATH_FFS + CONTEXT_FFS + INPUT_FFS + CTRL_FFS,
        dsps: 1,
        bram36: 0,
        lutram: IM_LUTS + RF_LUTS,
    }
}

/// In-pipeline FU: cross-boundary optimization (shared valid/control,
/// trimmed input register) reduces the per-FU cost when the cascade is
/// synthesized as a unit; calibrated so the 8-FU pipeline lands on the
/// paper's 808 LUTs / 1077 FFs.
pub fn fu_in_pipeline() -> Resources {
    Resources {
        luts: 88,
        ffs: 121,
        dsps: 1,
        bram36: 0,
        lutram: IM_LUTS + RF_LUTS,
    }
}

/// The two DRAM FIFOs + pipeline-level control shared by the cascade.
pub fn pipeline_overhead() -> Resources {
    Resources {
        luts: 104,
        ffs: 109,
        dsps: 0,
        bram36: 0,
        lutram: 64,
    }
}

/// A complete n-FU processing pipeline (Fig. 2) as synthesized.
pub fn pipeline(n_fus: u32) -> Resources {
    fu_in_pipeline().scale(n_fus).add(&pipeline_overhead())
}

/// §VI extension: double-buffered-RF FU. The RF doubles (16 RAM32M),
/// plus a bank-select register and a second write-address mux; the IM,
/// control and DSP are unchanged. See `arch::fu_db`.
pub fn fu_double_buffered() -> Resources {
    let base = fu();
    Resources {
        luts: base.luts + RF_LUTS + 6, // second RF bank + bank muxing
        ffs: base.ffs + 3,             // bank select + swap handshake
        dsps: 1,
        bram36: 0,
        lutram: base.lutram + RF_LUTS,
    }
}

/// The paper's Table III area accounting: `n_FUs × 141 e-Slices`
/// (standalone-FU cost per FU; conservative vs the synthesized
/// pipeline).
pub fn area_paper_accounting(n_fus: u32, dev: &Device) -> u32 {
    n_fus * (fu().eslices(dev))
}

/// Memory subsystem of the Fig. 4 overlay: one data BRAM per pipeline
/// plus one shared configuration BRAM.
pub fn memory_subsystem(n_pipelines: u32) -> Resources {
    Resources {
        luts: 120 * n_pipelines + 80, // AXI/DMA glue per pipeline + shared
        ffs: 150 * n_pipelines + 90,
        dsps: 0,
        bram36: n_pipelines + 1,
        lutram: 0,
    }
}

/// Full overlay: `n_pipelines` replicas of an `n_fus` pipeline + memory
/// subsystem.
pub fn overlay(n_pipelines: u32, n_fus: u32) -> Resources {
    pipeline(n_fus)
        .scale(n_pipelines)
        .add(&memory_subsystem(n_pipelines))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::device::ZYNQ_Z7020;

    /// Calibration identity: the standalone FU reproduces §III.A.
    #[test]
    fn fu_matches_paper_synthesis() {
        let r = fu();
        assert_eq!(r.luts, 160);
        assert_eq!(r.ffs, 293);
        assert_eq!(r.dsps, 1);
        assert_eq!(r.slices(), 81);
        assert_eq!(r.eslices(&ZYNQ_Z7020), 141);
    }

    /// Calibration identity: the 8-FU pipeline reproduces §III.A.
    #[test]
    fn pipeline8_matches_paper_synthesis() {
        let r = pipeline(8);
        assert_eq!(r.luts, 808);
        assert_eq!(r.ffs, 1077);
        assert_eq!(r.dsps, 8);
        // "less than 4% of the Zynq FPGA resources"
        assert!(ZYNQ_Z7020.utilization(&r) < 0.04);
    }

    #[test]
    fn paper_accounting_identity() {
        assert_eq!(area_paper_accounting(7, &ZYNQ_Z7020), 987); // chebyshev
        assert_eq!(area_paper_accounting(13, &ZYNQ_Z7020), 1833); // poly7
    }

    #[test]
    fn synthesized_pipeline_cheaper_than_paper_accounting() {
        let dev = &ZYNQ_Z7020;
        for n in [6u32, 7, 8, 9, 11, 13] {
            assert!(
                pipeline(n).eslices(dev) < area_paper_accounting(n, dev),
                "n = {n}"
            );
        }
    }

    #[test]
    fn overlay_scales_with_replicas() {
        let one = overlay(1, 8);
        let four = overlay(4, 8);
        assert_eq!(four.dsps, 4 * one.dsps);
        assert_eq!(four.bram36, 5); // 4 data + 1 config
        assert!(four.luts > 3 * one.luts);
    }

    #[test]
    fn resources_algebra() {
        let a = Resources {
            luts: 10,
            ffs: 20,
            dsps: 1,
            bram36: 0,
            lutram: 4,
        };
        let b = a.scale(3);
        assert_eq!(b.luts, 30);
        assert_eq!(a.add(&b).ffs, 80);
    }
}
