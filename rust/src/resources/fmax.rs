//! Operating-frequency model, calibrated to the paper's synthesis
//! results: standalone FU 325 MHz on the Zynq Z7020; 8-FU pipeline
//! 303 MHz (interconnect/fan-out penalty grows with cascade length);
//! the same pipeline exceeds 600 MHz on a Virtex-7 (§III.A). System
//! clock for the throughput/context numbers is 300 MHz (§V).

use super::device::Device;

/// Standalone FU fmax on the Zynq -1 speed grade, MHz.
pub const FU_FMAX_MHZ: f64 = 325.0;

/// Per-FU cascade penalty (clock skew / valid fan-out), calibrated so
/// an 8-FU pipeline lands on the paper's 303 MHz.
const CASCADE_PENALTY_PER_FU: f64 = 0.00908;

/// fmax of an n-FU pipeline on a device, MHz.
pub fn pipeline_fmax(n_fus: u32, dev: &Device) -> f64 {
    (FU_FMAX_MHZ / (1.0 + CASCADE_PENALTY_PER_FU * n_fus as f64)) * dev.speed_factor
}

/// The system clock used for throughput/context-switch figures (§V).
pub const SYSTEM_CLOCK_MHZ: f64 = 300.0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::device::{VIRTEX7_485T, ZYNQ_Z7020};

    #[test]
    fn pipeline8_matches_paper_303mhz() {
        let f = pipeline_fmax(8, &ZYNQ_Z7020);
        assert!((f - 303.0).abs() < 1.0, "f = {f}");
    }

    #[test]
    fn single_fu_is_325mhz() {
        let f = pipeline_fmax(0, &ZYNQ_Z7020);
        assert!((f - 325.0).abs() < 1e-9);
        let f1 = pipeline_fmax(1, &ZYNQ_Z7020);
        assert!(f1 < 325.0 && f1 > 320.0);
    }

    #[test]
    fn virtex7_exceeds_600mhz() {
        // Paper: "in excess of 600 MHz" for the same 8-FU pipeline.
        let f = pipeline_fmax(8, &VIRTEX7_485T);
        assert!(f > 600.0, "f = {f}");
    }

    #[test]
    fn fmax_decreases_with_depth() {
        let d = &ZYNQ_Z7020;
        assert!(pipeline_fmax(16, d) < pipeline_fmax(8, d));
        // Even a 16-FU cascade stays above the 300 MHz system clock
        // target minus margin.
        assert!(pipeline_fmax(16, d) > 280.0);
    }
}
