//! Target FPGA device models (Xilinx 7-series).
//!
//! Devices carry the totals used for utilization percentages and the
//! slice/DSP equivalence ratio behind the paper's e-Slices metric
//! (§V: "1 DSP block is equivalent to 60 slices based on the ratio of
//! slices/DSP on the Zynq XC7Z020").

/// A 7-series device's relevant capacities.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Device {
    pub name: &'static str,
    pub luts: u32,
    pub ffs: u32,
    pub slices: u32,
    pub dsp48e1: u32,
    pub bram36: u32,
    /// Speed-grade scaling applied to component fmax (1.0 = Zynq -1).
    pub speed_factor: f64,
}

/// Zynq XC7Z020-1CLG484 (the paper's evaluation platform).
pub const ZYNQ_Z7020: Device = Device {
    name: "xc7z020-1clg484",
    luts: 53_200,
    ffs: 106_400,
    slices: 13_300,
    dsp48e1: 220,
    bram36: 140,
    speed_factor: 1.0,
};

/// Virtex-7 XC7VX485T (the paper's >600 MHz datapoint).
pub const VIRTEX7_485T: Device = Device {
    name: "xc7vx485t",
    luts: 303_600,
    ffs: 607_200,
    slices: 75_900,
    dsp48e1: 2_800,
    bram36: 1_030,
    // -2/-3 speed grade + bigger device: the paper reports the same
    // 8-FU pipeline exceeding 600 MHz (vs 303 on the Zynq) => factor 2.
    speed_factor: 2.0,
};

impl Device {
    /// Slices equivalent to one DSP block (the e-Slices exchange rate).
    pub fn slices_per_dsp(&self) -> u32 {
        // 13300 / 220 ≈ 60.45 → the paper rounds to 60.
        (self.slices as f64 / self.dsp48e1 as f64).round() as u32
    }

    /// Utilization fraction for a resource bundle.
    pub fn utilization(&self, r: &super::estimate::Resources) -> f64 {
        let lut = r.luts as f64 / self.luts as f64;
        let ff = r.ffs as f64 / self.ffs as f64;
        let dsp = r.dsps as f64 / self.dsp48e1 as f64;
        let bram = r.bram36 as f64 / self.bram36 as f64;
        lut.max(ff).max(dsp).max(bram)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zynq_eslice_ratio_is_60() {
        assert_eq!(ZYNQ_Z7020.slices_per_dsp(), 60);
    }

    #[test]
    fn virtex_is_bigger_and_faster() {
        assert!(VIRTEX7_485T.slices > ZYNQ_Z7020.slices);
        assert!(VIRTEX7_485T.speed_factor > ZYNQ_Z7020.speed_factor);
    }
}
