//! Simulation harnesses over the cycle-accurate architecture:
//! trace capture and static-vs-dynamic cross-validation.

pub mod trace;

pub use trace::{trace_run, validate_against_schedule, Event, EventKind, TracedRun};
