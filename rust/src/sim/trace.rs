//! Cycle-trace capture for the simulator (debugging + the Table-I
//! cross-check between the static schedule and the dynamic pipeline).

use crate::arch::Pipeline;
use crate::sched::{Program, ScheduleTable};
use anyhow::Result;

/// One traced event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    pub cycle: u64,
    pub what: EventKind,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EventKind {
    PacketIn { index: usize },
    PacketOut { index: usize },
    Backpressure,
}

/// Drive a pipeline while recording packet-level events.
pub struct TracedRun {
    pub events: Vec<Event>,
    pub outputs: Vec<Vec<i32>>,
    pub cycles: u64,
}

/// Run packets through a fresh pipeline, recording events.
pub fn trace_run(p: &Program, packets: &[Vec<i32>], max_cycles: u64) -> Result<TracedRun> {
    let mut pl = Pipeline::new(p, 1024)?;
    let mut events = Vec::new();
    let mut next = 0usize;
    let mut out_idx = 0usize;
    let mut outputs = Vec::new();
    let start_bp = 0u64;
    while outputs.len() < packets.len() {
        if pl.cycle > max_cycles {
            anyhow::bail!("trace: cycle budget exceeded");
        }
        if next < packets.len() && pl.enqueue_packet(&packets[next]) {
            events.push(Event {
                cycle: pl.cycle + 1,
                what: EventKind::PacketIn { index: next },
            });
            next += 1;
        }
        let bp_before = pl.backpressure_cycles;
        pl.step()?;
        if pl.backpressure_cycles > bp_before {
            events.push(Event {
                cycle: pl.cycle,
                what: EventKind::Backpressure,
            });
        }
        while let Some(pkt) = pl.dequeue_packet() {
            outputs.push(pkt);
            events.push(Event {
                cycle: pl.cycle,
                what: EventKind::PacketOut { index: out_idx },
            });
            out_idx += 1;
        }
    }
    let _ = start_bp;
    Ok(TracedRun {
        events,
        outputs,
        cycles: pl.cycle,
    })
}

/// Cross-check: the dynamic first-output cycle equals the static
/// schedule's prediction, and the steady-state output period equals the
/// II of the static [`ScheduleTable`].
pub fn validate_against_schedule(p: &Program, n_packets: usize) -> Result<()> {
    let n_in = p.stages[0].n_loads();
    let packets: Vec<Vec<i32>> = (0..n_packets).map(|k| vec![k as i32; n_in]).collect();
    let run = trace_run(p, &packets, 100_000)?;
    let t = crate::sched::Timing::of(p);
    let out_cycles: Vec<u64> = run
        .events
        .iter()
        .filter_map(|e| match e.what {
            EventKind::PacketOut { .. } => Some(e.cycle),
            _ => None,
        })
        .collect();
    // Last word of packet 0 lands at last_output.
    if out_cycles[0] != t.last_output {
        anyhow::bail!(
            "first packet completed at {} but the timing model says {}",
            out_cycles[0],
            t.last_output
        );
    }
    // Steady state: gaps == II.
    for w in out_cycles.windows(2).skip(1) {
        let gap = w[1] - w[0];
        if gap != t.ii as u64 {
            anyhow::bail!("output gap {gap} != II {}", t.ii);
        }
    }
    let table = ScheduleTable::generate(p, 3 * t.ii as usize);
    debug_assert_eq!(table.ii, t.ii);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::sched::Program;

    #[test]
    fn traces_gradient() {
        let g = bench_suite::load("gradient").unwrap();
        let p = Program::schedule(&g).unwrap();
        let packets: Vec<Vec<i32>> = (0..4).map(|k| vec![k; 5]).collect();
        let run = trace_run(&p, &packets, 10_000).unwrap();
        assert_eq!(run.outputs.len(), 4);
        assert!(run
            .events
            .iter()
            .any(|e| matches!(e.what, EventKind::Backpressure)));
    }

    /// Dynamic simulation agrees with the static timing model for every
    /// benchmark — the architecture-level equivalent of Table I.
    #[test]
    fn dynamic_matches_static_for_all_benchmarks() {
        for name in bench_suite::all_names() {
            let g = bench_suite::load(name).unwrap();
            let p = Program::schedule(&g).unwrap();
            validate_against_schedule(&p, 6).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }
}
