//! Context-switch comparison (§V, final paragraph): proposed overlay
//! (local 40-bit context stream) vs SCFU-SCN (external-memory
//! configuration) vs HLS partial reconfiguration.

use crate::arch::config_port;
use crate::baseline::{hls, scfu};
use crate::bench_suite::{self, constants::CONTEXT_WORD_BITS};
use crate::resources::SYSTEM_CLOCK_MHZ;
use crate::sched::Program;
use crate::util::table::Table;

#[derive(Debug, Clone)]
pub struct Row {
    pub name: String,
    pub context_bytes_instr: usize,
    pub context_bytes_total: usize,
    pub switch_us: f64,
}

pub fn measure() -> crate::Result<Vec<Row>> {
    let mut out = Vec::new();
    for name in bench_suite::table2_names() {
        let g = bench_suite::load(name)?;
        let p = Program::schedule(&g)?;
        let img = p.context_image()?;
        let loaded = config_port::load_image(&img)?;
        out.push(Row {
            name: name.to_string(),
            context_bytes_instr: img.size_bytes_instr_only(),
            context_bytes_total: img.size_bytes_total().map_err(|e| anyhow::anyhow!("{e}"))?,
            switch_us: config_port::switch_time_us(&loaded, SYSTEM_CLOCK_MHZ),
        });
    }
    Ok(out)
}

pub fn render() -> crate::Result<String> {
    let rows = measure()?;
    let mut t = Table::new(&format!(
        "Context switching at {SYSTEM_CLOCK_MHZ} MHz ({CONTEXT_WORD_BITS}-bit context words)"
    ))
    .header(&["kernel", "ctx B (instr)", "ctx B (total)", "switch us"]);
    for r in &rows {
        t.row(&[
            r.name.clone(),
            r.context_bytes_instr.to_string(),
            r.context_bytes_total.to_string(),
            format!("{:.3}", r.switch_us),
        ]);
    }
    let mut s = t.render();
    let worst = rows.iter().map(|r| r.switch_us).fold(0.0f64, f64::max);
    let min_b = rows.iter().map(|r| r.context_bytes_instr).min().unwrap();
    let max_b = rows.iter().map(|r| r.context_bytes_instr).max().unwrap();
    s.push_str(&format!(
        "\nproposed: contexts {min_b}-{max_b} B (paper: 65-410 B), worst switch {:.2} us (paper: 0.27 us)\n\
         SCFU-SCN [13]: worst case {} B from external memory = {:.1} us (paper: 13 us)\n\
         Vivado HLS: {} kB PR bitstream via PCAP = {:.0} us (paper: 200 us)\n\
         speedup vs SCFU-SCN: {:.0}x, vs PR: {:.0}x\n",
        worst,
        scfu::WORST_CASE_CONFIG_BYTES,
        scfu::context_switch_us(scfu::WORST_CASE_CONFIG_BYTES),
        hls::PR_BITSTREAM_BYTES / 1024,
        hls::context_switch_us(hls::PR_BITSTREAM_BYTES),
        scfu::context_switch_us(scfu::WORST_CASE_CONFIG_BYTES) / worst,
        hls::context_switch_us(hls::PR_BITSTREAM_BYTES) / worst,
    ));
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chebyshev_is_65_bytes() {
        let rows = measure().unwrap();
        let cheb = rows.iter().find(|r| r.name == "chebyshev").unwrap();
        assert_eq!(cheb.context_bytes_instr, 65);
    }

    #[test]
    fn all_switches_are_sub_microsecond() {
        for r in measure().unwrap() {
            assert!(r.switch_us < 1.0, "{}: {} us", r.name, r.switch_us);
        }
    }

    #[test]
    fn orders_of_magnitude_match_paper() {
        let s = render().unwrap();
        // proposed ~0.1-0.3us << scfu 13us << PR 200us
        assert!(s.contains("13"));
        assert!(s.contains("200"));
    }
}
