//! Report generation: every table and figure from the paper's
//! evaluation, printed as measured-vs-paper (also exposed through the
//! `tmfu` CLI and the `rust/benches/*` targets).

pub mod ctx_switch;
pub mod fig5;
pub mod fig6;
pub mod resources_report;
pub mod simulate;
pub mod table2;
pub mod table3;
