//! Fig. 6 reproduction: area comparison (e-Slices) across the three
//! implementations, as a table + bar chart.

use super::table3;
use crate::bench_suite::PAPER_ROWS;
use crate::util::table::{BarChart, Table};

pub fn render() -> crate::Result<String> {
    let rows = table3::measure()?;
    let mut t = Table::new("Fig. 6: area in e-Slices (measured | paper)").header(&[
        "benchmark",
        "proposed",
        "SCFU-SCN",
        "Vivado HLS",
        "vs scfu",
        "vs hls",
    ]);
    let mut chart = BarChart::new("\nArea (measured, e-Slices)");
    for (row, paper) in rows.iter().zip(PAPER_ROWS.iter()) {
        let vs_scfu = 1.0 - paper.area_proposed as f64 / paper.area_scfu as f64;
        let vs_hls = paper.area_proposed as f64 / paper.area_hls as f64;
        t.row(&[
            row.name.clone(),
            format!("{} | {}", row.area_proposed, paper.area_proposed),
            format!("{} | {}", row.area_scfu_model, paper.area_scfu),
            format!("{} | {}", row.area_hls_model, paper.area_hls),
            format!("-{:.0}%", vs_scfu * 100.0),
            format!("{vs_hls:.2}x"),
        ]);
        chart.group(
            &row.name,
            &[
                ("prop", row.area_proposed as f64),
                ("scfu", row.area_scfu_model as f64),
                ("hls", row.area_hls_model as f64),
            ],
        );
    }
    let mut s = t.render();
    // Paper: "just 35% more resources than the Vivado implementations"
    // (geomean over the suite).
    let ratios: Vec<f64> = PAPER_ROWS
        .iter()
        .map(|p| p.area_proposed as f64 / p.area_hls as f64)
        .collect();
    let geo = crate::util::stats::geomean(&ratios);
    s.push_str(&format!(
        "\nproposed vs HLS area (paper accounting, geomean): {:.2}x (paper: ~1.35x)\n",
        geo
    ));
    s.push_str(&chart.render());
    Ok(s)
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_and_claims_hold() {
        let s = super::render().unwrap();
        assert!(s.contains("chebyshev"));
        // Geomean proposed/HLS area from the paper's own numbers is
        // printed and sits near the claimed 1.35x... the paper's "just
        // 35% more" is closer to the median; our geomean lands 1.2-1.8.
        assert!(s.contains("geomean"));
    }
}
