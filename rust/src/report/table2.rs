//! Table II reproduction: DFG characteristics of the benchmark set,
//! measured by our frontend + scheduler, printed against the paper.

use crate::bench_suite::{self, PAPER_ROWS};
use crate::dfg::Characteristics;
use crate::sched::{Program, Timing};
use crate::util::table::Table;

/// One measured row.
#[derive(Debug, Clone)]
pub struct Row {
    pub name: String,
    pub c: Characteristics,
    pub ii: u32,
    pub eopc: f64,
}

/// Measure every Table II benchmark.
pub fn measure() -> crate::Result<Vec<Row>> {
    let mut rows = Vec::new();
    for name in bench_suite::table2_names() {
        let g = bench_suite::load(name)?;
        let c = Characteristics::of(&g);
        let p = Program::schedule(&g)?;
        let t = Timing::of(&p);
        rows.push(Row {
            name: name.to_string(),
            eopc: t.eopc(c.n_ops),
            ii: t.ii,
            c,
        });
    }
    Ok(rows)
}

/// Render measured-vs-paper.
pub fn render() -> crate::Result<String> {
    let rows = measure()?;
    let mut t = Table::new("Table II: DFG characteristics (measured | paper)").header(&[
        "benchmark", "i/o", "edges", "ops", "depth", "par", "II", "eOPC",
    ]);
    for (row, paper) in rows.iter().zip(PAPER_ROWS.iter()) {
        t.row(&[
            row.name.clone(),
            format!("{}/{}", row.c.n_inputs, row.c.n_outputs),
            format!("{} | {}", row.c.n_edges, paper.edges),
            format!("{} | {}", row.c.n_ops, paper.ops),
            format!("{} | {}", row.c.depth, paper.depth),
            format!("{:.2} | {:.2}", row.c.avg_parallelism, paper.parallelism),
            format!("{} | {}", row.ii, paper.ii),
            format!("{:.1} | {:.1}", row.eopc, paper.eopc),
        ]);
    }
    Ok(t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_rows() {
        let s = render().unwrap();
        for row in &PAPER_ROWS {
            assert!(s.contains(row.name), "{} missing", row.name);
        }
        assert!(s.contains("11 | 11")); // mibench II
    }

    #[test]
    fn measured_iis_all_match() {
        for (row, paper) in measure().unwrap().iter().zip(PAPER_ROWS.iter()) {
            assert_eq!(row.ii, paper.ii, "{}", row.name);
        }
    }
}
