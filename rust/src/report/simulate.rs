//! `tmfu simulate` — cycle-accurate run of one benchmark with printed
//! metrics (measured II, latency, DSP utilization, oracle check).

use crate::arch::Pipeline;
use crate::bench_suite;
use crate::dfg::eval;
use crate::sched::{Program, Timing};
use crate::util::prng::Rng;

pub fn run_and_print(kernel: &str, n_packets: usize, seed: u64) -> crate::Result<()> {
    let g = bench_suite::load(kernel)?;
    let p = Program::schedule(&g)?;
    let t = Timing::of(&p);
    let mut pl = Pipeline::new(&p, 1024)?;
    let mut rng = Rng::new(seed);
    let n_in = g.inputs().len();
    let packets: Vec<Vec<i32>> = (0..n_packets)
        .map(|_| (0..n_in).map(|_| rng.range_i64(-10_000, 10_000) as i32).collect())
        .collect();
    let out = pl.run(&packets, 1_000_000)?;
    let mut mismatches = 0usize;
    for (pkt, got) in packets.iter().zip(&out) {
        if got != &eval(&g, pkt) {
            mismatches += 1;
        }
    }
    let cycles = pl.cycle;
    println!("kernel {kernel}: {n_packets} packets in {cycles} cycles");
    println!("  stages (FUs):        {}", p.n_stages());
    println!("  model II:            {} cycles", t.ii);
    println!(
        "  amortized II:        {:.2} cycles/packet",
        cycles as f64 / n_packets as f64
    );
    println!("  packet latency:      {} cycles", t.latency());
    println!("  backpressure cycles: {}", pl.backpressure_cycles);
    let utils = pl.dsp_utilizations();
    println!(
        "  DSP utilization:     {}",
        utils
            .iter()
            .enumerate()
            .map(|(i, u)| format!("FU{i}={:.0}%", u * 100.0))
            .collect::<Vec<_>>()
            .join(" ")
    );
    println!(
        "  oracle check:        {}",
        if mismatches == 0 {
            "OK (all outputs match functional evaluation)".to_string()
        } else {
            format!("FAILED ({mismatches} mismatches)")
        }
    );
    if mismatches > 0 {
        anyhow::bail!("simulation diverged from the functional oracle");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn runs_quietly_for_all_kernels() {
        for name in crate::bench_suite::all_names() {
            super::run_and_print(name, 5, 1).unwrap();
        }
    }
}
