//! Table III reproduction: area (e-Slices) and throughput (GOPS) for
//! the proposed overlay, the SCFU-SCN overlay [13] and Vivado HLS.

use crate::baseline::{hls, scfu};
use crate::bench_suite::{self, constants, PAPER_ROWS};
use crate::resources::{self, ZYNQ_Z7020};
use crate::sched::{Program, Timing};
use crate::util::table::Table;

/// Measured row (proposed / scfu / hls, each tput GOPS + area e-Slices).
#[derive(Debug, Clone)]
pub struct Row {
    pub name: String,
    pub tput_proposed: f64,
    pub area_proposed: u32,
    /// Synthesized-pipeline estimate (tighter than the paper accounting).
    pub area_proposed_synth: u32,
    pub tput_scfu: f64,
    pub area_scfu_model: u32,
    pub tput_hls: f64,
    pub area_hls_model: u32,
    pub n_fus: u32,
}

pub fn measure() -> crate::Result<Vec<Row>> {
    let dev = &ZYNQ_Z7020;
    let mut out = Vec::new();
    for name in bench_suite::table2_names() {
        let g = bench_suite::load(name)?;
        let p = Program::schedule(&g)?;
        let t = Timing::of(&p);
        let n_fus = p.n_fus();
        let scfu_m = scfu::map(&g);
        let hls_m = hls::estimate(&g);
        out.push(Row {
            name: name.to_string(),
            tput_proposed: t.gops(g.n_ops(), constants::PROPOSED_FREQ_MHZ),
            area_proposed: resources::area_paper_accounting(n_fus, dev),
            area_proposed_synth: resources::pipeline(n_fus).eslices(dev),
            tput_scfu: scfu::gops(g.n_ops()),
            area_scfu_model: scfu_m.area_eslices(),
            tput_hls: hls_m.gops(g.n_ops()),
            area_hls_model: hls_m.eslices(dev),
            n_fus,
        });
    }
    Ok(out)
}

pub fn render() -> crate::Result<String> {
    let rows = measure()?;
    let mut t = Table::new(
        "Table III: throughput (GOPS) & area (e-Slices), measured | paper",
    )
    .header(&[
        "benchmark",
        "prop Tput",
        "prop Area",
        "scfu Tput",
        "scfu Area",
        "hls Tput",
        "hls Area",
    ]);
    for (row, paper) in rows.iter().zip(PAPER_ROWS.iter()) {
        t.row(&[
            row.name.clone(),
            format!("{:.2} | {:.2}", row.tput_proposed, paper.tput_proposed),
            format!("{} | {}", row.area_proposed, paper.area_proposed),
            format!("{:.2} | {:.2}", row.tput_scfu, paper.tput_scfu),
            format!("{} | {}", row.area_scfu_model, paper.area_scfu),
            format!("{:.2} | {:.2}", row.tput_hls, paper.tput_hls),
            format!("{} | {}", row.area_hls_model, paper.area_hls),
        ]);
    }
    let mut s = t.render();
    // The paper's headline claims, recomputed from the measured rows.
    let max_area_saving = rows
        .iter()
        .zip(PAPER_ROWS.iter())
        .map(|(r, p)| 1.0 - r.area_proposed as f64 / p.area_scfu as f64)
        .fold(0.0f64, f64::max);
    let tput_ratios: Vec<f64> = rows
        .iter()
        .zip(PAPER_ROWS.iter())
        .map(|(r, p)| p.tput_scfu / r.tput_proposed)
        .collect();
    let min_ratio = tput_ratios.iter().cloned().fold(f64::INFINITY, f64::min);
    let max_ratio = tput_ratios.iter().cloned().fold(0.0f64, f64::max);
    s.push_str(&format!(
        "\nheadlines: up to {:.0}% e-Slice reduction vs SCFU-SCN (paper: 85%);\n\
         throughput {:.0}x-{:.0}x lower than SCFU-SCN (paper: 6x-18x)\n",
        max_area_saving * 100.0,
        min_ratio.floor(),
        max_ratio.ceil()
    ));
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proposed_columns_match_paper_exactly() {
        for (row, paper) in measure().unwrap().iter().zip(PAPER_ROWS.iter()) {
            assert!(
                (row.tput_proposed - paper.tput_proposed).abs() < 0.005,
                "{} tput",
                row.name
            );
            assert_eq!(row.area_proposed, paper.area_proposed, "{} area", row.name);
        }
    }

    #[test]
    fn headline_claims_hold() {
        let s = render().unwrap();
        assert!(s.contains("up to 8"), "area headline: {s}");
    }

    #[test]
    fn fus_match_depth_based_counts() {
        for (row, paper) in measure().unwrap().iter().zip(PAPER_ROWS.iter()) {
            assert_eq!(row.n_fus, paper.fus_proposed, "{}", row.name);
        }
    }

    #[test]
    fn throughput_ordering_preserved() {
        // SCFU > HLS > proposed for every benchmark (the paper's shape).
        for row in measure().unwrap() {
            assert!(row.tput_scfu > row.tput_hls, "{}", row.name);
            assert!(row.tput_hls > row.tput_proposed, "{}", row.name);
        }
    }
}
