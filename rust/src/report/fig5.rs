//! Fig. 5 reproduction: number of FUs required per benchmark, proposed
//! overlay vs the SCFU-SCN overlay [13].

use crate::baseline::scfu;
use crate::bench_suite::{self, PAPER_ROWS};
use crate::sched::Program;
use crate::util::table::{BarChart, Table};

#[derive(Debug, Clone)]
pub struct Row {
    pub name: String,
    pub fus_proposed: u32,
    pub fus_scfu_model: u32,
}

pub fn measure() -> crate::Result<Vec<Row>> {
    let mut out = Vec::new();
    for name in bench_suite::table2_names() {
        let g = bench_suite::load(name)?;
        let p = Program::schedule(&g)?;
        out.push(Row {
            name: name.to_string(),
            fus_proposed: p.n_fus(),
            fus_scfu_model: scfu::map(&g).total_fus(),
        });
    }
    Ok(out)
}

pub fn render() -> crate::Result<String> {
    let rows = measure()?;
    let mut t = Table::new("Fig. 5: FUs required (measured | paper)").header(&[
        "benchmark",
        "proposed",
        "SCFU-SCN",
        "reduction",
    ]);
    let mut chart = BarChart::new("\nFUs required (measured)");
    for (row, paper) in rows.iter().zip(PAPER_ROWS.iter()) {
        let reduction = 1.0 - row.fus_proposed as f64 / paper.fus_scfu as f64;
        t.row(&[
            row.name.clone(),
            format!("{} | {}", row.fus_proposed, paper.fus_proposed),
            format!("{} | {}", row.fus_scfu_model, paper.fus_scfu),
            format!("{:.0}%", reduction * 100.0),
        ]);
        chart.group(
            &row.name,
            &[
                ("prop", row.fus_proposed as f64),
                ("scfu", row.fus_scfu_model as f64),
            ],
        );
    }
    let mut s = t.render();
    s.push_str(&chart.render());
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proposed_fu_counts_match_paper() {
        for (row, paper) in measure().unwrap().iter().zip(PAPER_ROWS.iter()) {
            assert_eq!(row.fus_proposed, paper.fus_proposed, "{}", row.name);
        }
    }

    #[test]
    fn scfu_always_needs_more_fus() {
        for row in measure().unwrap() {
            assert!(row.fus_scfu_model > row.fus_proposed, "{}", row.name);
        }
    }

    #[test]
    fn renders() {
        let s = render().unwrap();
        assert!(s.contains("chebyshev"));
        assert!(s.contains('#'));
    }
}
