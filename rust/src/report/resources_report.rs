//! §III.A resource/frequency reproduction: the FU and pipeline
//! synthesis results from the structural model.

use crate::resources::{self, pipeline_fmax, Resources, VIRTEX7_485T, ZYNQ_Z7020};
use crate::util::table::Table;

pub fn render() -> String {
    let mut t = Table::new("FU / pipeline resources (model | paper, Zynq XC7Z020)")
        .header(&["component", "LUTs", "FFs", "DSPs", "slices", "e-Slices", "fmax MHz"]);
    let fu = resources::fu();
    t.row(&[
        "FU (standalone)".to_string(),
        format!("{} | 160", fu.luts),
        format!("{} | 293", fu.ffs),
        format!("{} | 1", fu.dsps),
        fu.slices().to_string(),
        format!("{} | 141", fu.eslices(&ZYNQ_Z7020)),
        format!("{:.0} | 325", resources::FU_FMAX_MHZ),
    ]);
    let p8 = resources::pipeline(8);
    t.row(&[
        "8-FU pipeline + FIFOs".to_string(),
        format!("{} | 808", p8.luts),
        format!("{} | 1077", p8.ffs),
        format!("{} | 8", p8.dsps),
        p8.slices().to_string(),
        p8.eslices(&ZYNQ_Z7020).to_string(),
        format!("{:.0} | 303", pipeline_fmax(8, &ZYNQ_Z7020)),
    ]);
    let mut s = t.render();
    s.push_str(&format!(
        "\nZynq utilization of the 8-FU pipeline: {:.1}% (paper: <4%)\n\
         Virtex-7 XC7VX485T fmax: {:.0} MHz (paper: >600 MHz)\n\
         max config time, 8 FUs x 32 instrs @300 MHz: {:.2} us (paper: 0.85 us)\n",
        ZYNQ_Z7020.utilization(&p8) * 100.0,
        pipeline_fmax(8, &VIRTEX7_485T),
        (8.0 * 32.0) / 300.0,
    ));
    // Component breakdown of the FU.
    let mut b = Table::new("\nFU component breakdown (calibrated model)")
        .header(&["component", "LUTs", "FFs"]);
    b.row(&["instruction memory (4x RAM32M)", &resources::estimate::IM_LUTS.to_string(), "0"]);
    b.row(&["register file (8x RAM32M)", &resources::estimate::RF_LUTS.to_string(), "0"]);
    b.row(&[
        "control (PC/IC/DC + FSM + tag)",
        &resources::estimate::CTRL_LUTS.to_string(),
        &resources::estimate::CTRL_FFS.to_string(),
    ]);
    b.row(&[
        "operand routing / muxes",
        &resources::estimate::MUX_LUTS.to_string(),
        "0",
    ]);
    b.row(&["datapath regs (C, P, config)", "0", &resources::estimate::DATAPATH_FFS.to_string()]);
    b.row(&["context shift reg (40b)", "0", &resources::estimate::CONTEXT_FFS.to_string()]);
    b.row(&["input/valid regs", "0", &resources::estimate::INPUT_FFS.to_string()]);
    s.push_str(&b.render());
    s
}

/// Resources of a full Fig.-4 overlay configuration.
pub fn overlay_summary(n_pipelines: u32, n_fus: u32) -> (Resources, f64) {
    let r = resources::overlay(n_pipelines, n_fus);
    let util = ZYNQ_Z7020.utilization(&r);
    (r, util)
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_calibrated_numbers() {
        let s = super::render();
        assert!(s.contains("160 | 160"));
        assert!(s.contains("808 | 808"));
        assert!(s.contains("141"));
    }

    #[test]
    fn overlay_of_4_pipelines_fits_zynq() {
        let (r, util) = super::overlay_summary(4, 8);
        assert!(util < 0.25, "util {util}");
        assert_eq!(r.dsps, 32);
    }
}
