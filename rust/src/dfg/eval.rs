//! Functional DFG evaluation — the Rust-side oracle.
//!
//! Semantics are wrapping two's-complement int32, matching the DSP48E1
//! model, the jnp reference (`python/compile/kernels/ref.py`) and the
//! Pallas kernel. The cycle-accurate simulator and the PJRT runtime are
//! both checked against this evaluator.

use super::{Dfg, NodeKind};

/// Evaluate the graph for one input vector (values in input declaration
/// order). Returns outputs in output declaration order.
pub fn eval(g: &Dfg, inputs: &[i32]) -> Vec<i32> {
    let input_ids = g.inputs();
    assert_eq!(
        inputs.len(),
        input_ids.len(),
        "kernel '{}' expects {} inputs, got {}",
        g.name,
        input_ids.len(),
        inputs.len()
    );
    let mut value = vec![0i32; g.len()];
    let mut next_input = 0usize;
    let mut outputs = Vec::new();
    for id in g.ids() {
        let n = g.node(id);
        let v = match &n.kind {
            NodeKind::Input { .. } => {
                let v = inputs[next_input];
                next_input += 1;
                v
            }
            NodeKind::Const { value } => *value,
            NodeKind::Op { op } => op.apply(value[n.args[0] as usize], value[n.args[1] as usize]),
            NodeKind::Output { .. } => {
                let v = value[n.args[0] as usize];
                outputs.push(v);
                v
            }
        };
        value[id as usize] = v;
    }
    outputs
}

/// Evaluate over a batch of input vectors (row-major `[batch][n_inputs]`).
pub fn eval_batch(g: &Dfg, batch: &[Vec<i32>]) -> Vec<Vec<i32>> {
    batch.iter().map(|row| eval(g, row)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::{tiny_graph, Dfg, OpKind};

    #[test]
    fn evaluates_tiny() {
        let g = tiny_graph();
        assert_eq!(eval(&g, &[7, 3]), vec![16]); // (7-3)^2
        assert_eq!(eval(&g, &[3, 7]), vec![16]); // (-4)^2
        assert_eq!(eval(&g, &[0, 0]), vec![0]);
    }

    #[test]
    fn evaluates_constants() {
        let mut g = Dfg::new("k");
        let x = g.add_input("x");
        let k = g.add_const(-5);
        let s = g.add_op(OpKind::Mul, x, k);
        g.add_output("y", s);
        assert_eq!(eval(&g, &[10]), vec![-50]);
    }

    #[test]
    fn wrapping_multiply() {
        let mut g = Dfg::new("w");
        let x = g.add_input("x");
        let m = g.add_op(OpKind::Mul, x, x);
        g.add_output("y", m);
        assert_eq!(eval(&g, &[1 << 17]), vec![(1i32 << 17).wrapping_mul(1 << 17)]);
    }

    #[test]
    fn multiple_outputs_in_order() {
        let mut g = Dfg::new("two");
        let a = g.add_input("a");
        let b = g.add_input("b");
        let s = g.add_op(OpKind::Add, a, b);
        let d = g.add_op(OpKind::Sub, a, b);
        g.add_output("sum", s);
        g.add_output("diff", d);
        assert_eq!(eval(&g, &[10, 4]), vec![14, 6]);
    }

    #[test]
    #[should_panic(expected = "expects 2 inputs")]
    fn wrong_arity_panics() {
        eval(&tiny_graph(), &[1]);
    }

    #[test]
    fn batch_matches_scalar() {
        let g = tiny_graph();
        let batch = vec![vec![1, 2], vec![5, -5], vec![i32::MAX, i32::MIN]];
        let out = eval_batch(&g, &batch);
        for (row, o) in batch.iter().zip(&out) {
            assert_eq!(o, &eval(&g, row));
        }
    }
}
