//! Functional DFG evaluation — the Rust-side oracle.
//!
//! Semantics are wrapping two's-complement int32, matching the DSP48E1
//! model, the jnp reference (`python/compile/kernels/ref.py`) and the
//! Pallas kernel. The cycle-accurate simulator, the tape-compiled
//! turbo backend and the PJRT runtime are all checked against this
//! evaluator.

use super::{Dfg, NodeKind};

/// Evaluate the graph for one input vector (values in input declaration
/// order). Returns outputs in output declaration order.
pub fn eval(g: &Dfg, inputs: &[i32]) -> Vec<i32> {
    let mut value = vec![0i32; g.len()];
    let mut outputs = Vec::new();
    eval_into(g, inputs, &mut value, &mut outputs);
    outputs
}

/// Allocation-free core: evaluate one packet into caller-owned
/// scratch. `value` is resized to the node count (reused across calls);
/// outputs are **appended** to `outputs` in declaration order.
pub fn eval_into(g: &Dfg, inputs: &[i32], value: &mut Vec<i32>, outputs: &mut Vec<i32>) {
    let input_ids = g.inputs();
    assert_eq!(
        inputs.len(),
        input_ids.len(),
        "kernel '{}' expects {} inputs, got {}",
        g.name,
        input_ids.len(),
        inputs.len()
    );
    value.clear();
    value.resize(g.len(), 0);
    let mut next_input = 0usize;
    for id in g.ids() {
        let n = g.node(id);
        let v = match &n.kind {
            NodeKind::Input { .. } => {
                let v = inputs[next_input];
                next_input += 1;
                v
            }
            NodeKind::Const { value } => *value,
            NodeKind::Op { op } => op.apply(value[n.args[0] as usize], value[n.args[1] as usize]),
            NodeKind::Output { .. } => {
                let v = value[n.args[0] as usize];
                outputs.push(v);
                v
            }
        };
        value[id as usize] = v;
    }
}

/// Evaluate over a flat row-major batch (`n_inputs` words per packet).
/// Returns flat row-major outputs (`n_outputs` words per packet). The
/// per-node value scratch is hoisted out of the packet loop — the
/// batch shape the serving layer's `FlatBatch` I/O feeds directly.
pub fn eval_batch(g: &Dfg, flat_inputs: &[i32]) -> Vec<i32> {
    let n_in = g.inputs().len();
    assert!(n_in > 0, "kernel '{}' has no inputs", g.name);
    assert_eq!(
        flat_inputs.len() % n_in,
        0,
        "kernel '{}': flat batch of {} words is not a multiple of arity {}",
        g.name,
        flat_inputs.len(),
        n_in
    );
    let n_rows = flat_inputs.len() / n_in;
    let mut value = Vec::with_capacity(g.len());
    let mut outputs = Vec::with_capacity(n_rows * g.outputs().len());
    for row in flat_inputs.chunks_exact(n_in) {
        eval_into(g, row, &mut value, &mut outputs);
    }
    outputs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::{tiny_graph, Dfg, OpKind};

    #[test]
    fn evaluates_tiny() {
        let g = tiny_graph();
        assert_eq!(eval(&g, &[7, 3]), vec![16]); // (7-3)^2
        assert_eq!(eval(&g, &[3, 7]), vec![16]); // (-4)^2
        assert_eq!(eval(&g, &[0, 0]), vec![0]);
    }

    #[test]
    fn evaluates_constants() {
        let mut g = Dfg::new("k");
        let x = g.add_input("x");
        let k = g.add_const(-5);
        let s = g.add_op(OpKind::Mul, x, k);
        g.add_output("y", s);
        assert_eq!(eval(&g, &[10]), vec![-50]);
    }

    #[test]
    fn wrapping_multiply() {
        let mut g = Dfg::new("w");
        let x = g.add_input("x");
        let m = g.add_op(OpKind::Mul, x, x);
        g.add_output("y", m);
        assert_eq!(eval(&g, &[1 << 17]), vec![(1i32 << 17).wrapping_mul(1 << 17)]);
    }

    #[test]
    fn multiple_outputs_in_order() {
        let mut g = Dfg::new("two");
        let a = g.add_input("a");
        let b = g.add_input("b");
        let s = g.add_op(OpKind::Add, a, b);
        let d = g.add_op(OpKind::Sub, a, b);
        g.add_output("sum", s);
        g.add_output("diff", d);
        assert_eq!(eval(&g, &[10, 4]), vec![14, 6]);
    }

    #[test]
    #[should_panic(expected = "expects 2 inputs")]
    fn wrong_arity_panics() {
        eval(&tiny_graph(), &[1]);
    }

    #[test]
    fn flat_batch_matches_scalar() {
        let g = tiny_graph();
        let rows = [vec![1, 2], vec![5, -5], vec![i32::MAX, i32::MIN]];
        let flat: Vec<i32> = rows.iter().flatten().copied().collect();
        let out = eval_batch(&g, &flat);
        assert_eq!(out.len(), rows.len());
        for (row, o) in rows.iter().zip(&out) {
            assert_eq!(*o, eval(&g, row)[0]);
        }
        // Empty flat batch evaluates to no outputs.
        assert!(eval_batch(&g, &[]).is_empty());
    }

    #[test]
    fn eval_into_reuses_scratch_and_appends() {
        let g = tiny_graph();
        let mut value = Vec::new();
        let mut out = Vec::new();
        eval_into(&g, &[7, 3], &mut value, &mut out);
        eval_into(&g, &[3, 7], &mut value, &mut out);
        assert_eq!(out, vec![16, 16]);
        assert_eq!(value.len(), g.len());
    }

    #[test]
    #[should_panic(expected = "not a multiple of arity")]
    fn flat_batch_ragged_panics() {
        eval_batch(&tiny_graph(), &[1, 2, 3]);
    }
}
