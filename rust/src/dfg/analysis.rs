//! DFG analyses: ASAP levels and the Table-II characteristics.
//!
//! Conventions (validated against the paper's own numbers — see
//! DESIGN.md §6):
//!
//! * **level**: inputs and constants sit at level 0; an op's level is
//!   `1 + max(level(arg))`; an output's level equals its operand's level.
//! * **graph depth** = the maximum op level = number of pipeline stages
//!   (= FUs) the linear overlay needs.
//! * **edges** = operand slots referencing non-constant nodes on op
//!   nodes, plus one edge per output node. Constants are preloaded into
//!   the register file and contribute no streaming edge (this exactly
//!   reproduces chebyshev's 12 edges).
//! * **average parallelism** = op nodes / depth.

use super::{Dfg, NodeId, NodeKind};

/// ASAP levels for every node.
#[derive(Debug, Clone)]
pub struct Levels {
    pub level: Vec<u32>,
    /// Max op level (pipeline depth in stages).
    pub depth: u32,
}

impl Levels {
    /// ALAP levels: every op is placed as late as its earliest consumer
    /// allows (outputs exit at the virtual stage `depth+1`). Same depth
    /// as ASAP; ops with slack move toward their consumers, which can
    /// shorten bypass chains (see `bench_ablation` §E).
    pub fn alap(g: &Dfg) -> Levels {
        let asap = Levels::of(g);
        let depth = asap.depth;
        let mut level = vec![0u32; g.len()];
        // Latest allowed stage per node, computed in reverse topological
        // order. Outputs pin their operand to any stage <= depth.
        let mut latest = vec![u32::MAX; g.len()];
        for id in (0..g.len() as NodeId).rev() {
            let n = g.node(id);
            match &n.kind {
                NodeKind::Output { .. } => {
                    let a = n.args[0] as usize;
                    latest[a] = latest[a].min(depth);
                }
                NodeKind::Op { .. } => {
                    let own = if latest[id as usize] == u32::MAX {
                        depth
                    } else {
                        latest[id as usize]
                    };
                    level[id as usize] = own;
                    for &a in &n.args {
                        let a = a as usize;
                        latest[a] = latest[a].min(own - 1);
                    }
                }
                _ => {}
            }
        }
        // Inputs and consts stay at 0; outputs mirror their operand.
        for id in g.ids() {
            let n = g.node(id);
            if n.is_output() {
                level[id as usize] = level[n.args[0] as usize];
            } else if !n.is_op() {
                level[id as usize] = 0;
            }
        }
        Levels { level, depth }
    }

    pub fn of(g: &Dfg) -> Levels {
        let mut level = vec![0u32; g.len()];
        let mut depth = 0;
        for id in g.ids() {
            let n = g.node(id);
            let lvl = match &n.kind {
                NodeKind::Input { .. } | NodeKind::Const { .. } => 0,
                NodeKind::Op { .. } => {
                    1 + n
                        .args
                        .iter()
                        .map(|&a| level[a as usize])
                        .max()
                        .unwrap_or(0)
                }
                NodeKind::Output { .. } => level[n.args[0] as usize],
            };
            level[id as usize] = lvl;
            if n.is_op() {
                depth = depth.max(lvl);
            }
        }
        Levels { level, depth }
    }

    /// Op node ids at each level `1..=depth` (stage s -> ops).
    pub fn stages(&self, g: &Dfg) -> Vec<Vec<NodeId>> {
        let mut stages = vec![Vec::new(); self.depth as usize];
        for id in g.ids() {
            if g.node(id).is_op() {
                let s = self.level[id as usize] as usize;
                stages[s - 1].push(id);
            }
        }
        stages
    }
}

/// The columns of the paper's Table II.
#[derive(Debug, Clone, PartialEq)]
pub struct Characteristics {
    pub name: String,
    pub n_inputs: usize,
    pub n_outputs: usize,
    pub n_edges: usize,
    pub n_ops: usize,
    pub depth: u32,
    pub avg_parallelism: f64,
    /// Widest stage (max ops mapped to one FU).
    pub max_stage_ops: usize,
}

impl Characteristics {
    pub fn of(g: &Dfg) -> Characteristics {
        let levels = Levels::of(g);
        let n_ops = g.n_ops();
        let mut n_edges = 0usize;
        for id in g.ids() {
            let n = g.node(id);
            match &n.kind {
                NodeKind::Op { .. } => {
                    n_edges += n.args.iter().filter(|&&a| !g.node(a).is_const()).count();
                }
                NodeKind::Output { .. } => n_edges += 1,
                _ => {}
            }
        }
        let depth = levels.depth;
        let max_stage_ops = levels
            .stages(g)
            .iter()
            .map(|s| s.len())
            .max()
            .unwrap_or(0);
        Characteristics {
            name: g.name.clone(),
            n_inputs: g.inputs().len(),
            n_outputs: g.outputs().len(),
            n_edges,
            n_ops,
            depth,
            avg_parallelism: if depth == 0 {
                0.0
            } else {
                n_ops as f64 / depth as f64
            },
            max_stage_ops,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::{tiny_graph, OpKind};

    #[test]
    fn levels_of_chain() {
        // out = ((a+b)*c_const)*... : chain levels grow by one per op.
        let mut g = Dfg::new("chain");
        let a = g.add_input("a");
        let b = g.add_input("b");
        let t1 = g.add_op(OpKind::Add, a, b);
        let c = g.add_const(3);
        let t2 = g.add_op(OpKind::Mul, t1, c);
        let t3 = g.add_op(OpKind::Sub, t2, a);
        g.add_output("out", t3);
        let l = Levels::of(&g);
        assert_eq!(l.depth, 3);
        assert_eq!(l.level[t1 as usize], 1);
        assert_eq!(l.level[t2 as usize], 2);
        assert_eq!(l.level[t3 as usize], 3);
    }

    #[test]
    fn stages_partition_ops() {
        let g = tiny_graph();
        let l = Levels::of(&g);
        let stages = l.stages(&g);
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].len(), 1);
        assert_eq!(stages[1].len(), 1);
    }

    #[test]
    fn characteristics_of_tiny() {
        let c = Characteristics::of(&tiny_graph());
        assert_eq!(c.n_inputs, 2);
        assert_eq!(c.n_outputs, 1);
        assert_eq!(c.n_ops, 2);
        assert_eq!(c.depth, 2);
        // edges: sub(a,b)=2, mul(d,d)=2, output=1
        assert_eq!(c.n_edges, 5);
        assert!((c.avg_parallelism - 1.0).abs() < 1e-12);
    }

    #[test]
    fn const_operands_add_no_edges() {
        let mut g = Dfg::new("c");
        let x = g.add_input("x");
        let k = g.add_const(16);
        let m = g.add_op(OpKind::Mul, x, k);
        g.add_output("y", m);
        let c = Characteristics::of(&g);
        assert_eq!(c.n_edges, 2); // x->m, m->out
    }

    #[test]
    fn wide_graph_parallelism() {
        // Four independent adds feeding a reduction tree.
        let mut g = Dfg::new("wide");
        let ins: Vec<_> = (0..8).map(|i| g.add_input(&format!("i{i}"))).collect();
        let l1: Vec<_> = (0..4)
            .map(|i| g.add_op(OpKind::Add, ins[2 * i], ins[2 * i + 1]))
            .collect();
        let l2a = g.add_op(OpKind::Add, l1[0], l1[1]);
        let l2b = g.add_op(OpKind::Add, l1[2], l1[3]);
        let l3 = g.add_op(OpKind::Add, l2a, l2b);
        g.add_output("s", l3);
        let c = Characteristics::of(&g);
        assert_eq!(c.n_ops, 7);
        assert_eq!(c.depth, 3);
        assert_eq!(c.max_stage_ops, 4);
        assert!((c.avg_parallelism - 7.0 / 3.0).abs() < 1e-12);
    }
}
