//! DFG ⇄ JSON interchange.
//!
//! This is the contract with the Python compile path
//! (`python/compile/dfg.py` parses the same format). Schema:
//!
//! ```json
//! {
//!   "name": "gradient",
//!   "nodes": [
//!     {"kind": "input",  "name": "ul"},
//!     {"kind": "const",  "value": 16},
//!     {"kind": "op",     "op": "sub", "args": [0, 1]},
//!     {"kind": "output", "name": "out", "args": [2]}
//!   ]
//! }
//! ```

use super::{Dfg, NodeKind, OpKind};
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};

/// Serialize a DFG to a JSON value.
pub fn dfg_to_json(g: &Dfg) -> Json {
    let nodes: Vec<Json> = g
        .nodes()
        .iter()
        .map(|n| match &n.kind {
            NodeKind::Input { name } => {
                json::obj(vec![("kind", json::s("input")), ("name", json::s(name))])
            }
            NodeKind::Const { value } => json::obj(vec![
                ("kind", json::s("const")),
                ("value", json::i(*value as i64)),
            ]),
            NodeKind::Op { op } => json::obj(vec![
                ("kind", json::s("op")),
                ("op", json::s(op.name())),
                ("args", json::ints(n.args.iter().map(|&a| a as i64))),
            ]),
            NodeKind::Output { name } => json::obj(vec![
                ("kind", json::s("output")),
                ("name", json::s(name)),
                ("args", json::ints(n.args.iter().map(|&a| a as i64))),
            ]),
        })
        .collect();
    json::obj(vec![
        ("name", json::s(&g.name)),
        ("nodes", Json::Arr(nodes)),
    ])
}

/// Deserialize a DFG from a JSON value, validating structure.
pub fn dfg_from_json(v: &Json) -> Result<Dfg> {
    let name = v
        .get("name")
        .as_str()
        .context("dfg json: missing 'name'")?;
    let nodes = v
        .get("nodes")
        .as_arr()
        .context("dfg json: missing 'nodes' array")?;
    let mut g = Dfg::new(name);
    for (idx, n) in nodes.iter().enumerate() {
        let kind = n
            .get("kind")
            .as_str()
            .with_context(|| format!("node {idx}: missing 'kind'"))?;
        match kind {
            "input" => {
                let nm = n
                    .get("name")
                    .as_str()
                    .with_context(|| format!("node {idx}: input missing 'name'"))?;
                g.add_input(nm);
            }
            "const" => {
                let val = n
                    .get("value")
                    .as_i64()
                    .with_context(|| format!("node {idx}: const missing 'value'"))?;
                if val < i32::MIN as i64 || val > i32::MAX as i64 {
                    bail!("node {idx}: const {val} out of i32 range");
                }
                g.add_const(val as i32);
            }
            "op" => {
                let opname = n
                    .get("op")
                    .as_str()
                    .with_context(|| format!("node {idx}: op missing 'op'"))?;
                let op = OpKind::from_name(opname)
                    .with_context(|| format!("node {idx}: unknown op '{opname}'"))?;
                let args = parse_args(n, idx, 2)?;
                g.add_op(op, args[0], args[1]);
            }
            "output" => {
                let nm = n
                    .get("name")
                    .as_str()
                    .with_context(|| format!("node {idx}: output missing 'name'"))?;
                let args = parse_args(n, idx, 1)?;
                g.add_output(nm, args[0]);
            }
            other => bail!("node {idx}: unknown kind '{other}'"),
        }
    }
    g.validate()
        .with_context(|| format!("dfg '{name}' failed validation"))?;
    Ok(g)
}

fn parse_args(n: &Json, idx: usize, want: usize) -> Result<Vec<u32>> {
    let args = n
        .get("args")
        .as_arr()
        .with_context(|| format!("node {idx}: missing 'args'"))?;
    if args.len() != want {
        bail!("node {idx}: expected {want} args, got {}", args.len());
    }
    args.iter()
        .map(|a| {
            a.as_i64()
                .and_then(|v| u32::try_from(v).ok())
                .with_context(|| format!("node {idx}: bad arg"))
        })
        .collect()
}

/// Parse a DFG from JSON text.
pub fn dfg_from_str(text: &str) -> Result<Dfg> {
    let v = json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
    dfg_from_json(&v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::{eval, tiny_graph};

    #[test]
    fn round_trips() {
        let g = tiny_graph();
        let j = dfg_to_json(&g);
        let g2 = dfg_from_json(&j).unwrap();
        assert_eq!(g, g2);
        assert_eq!(eval(&g2, &[9, 4]), vec![25]);
    }

    #[test]
    fn round_trips_via_text() {
        let g = tiny_graph();
        let text = dfg_to_json(&g).to_string_pretty();
        let g2 = dfg_from_str(&text).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn rejects_bad_kind() {
        let bad = r#"{"name":"x","nodes":[{"kind":"frobnicate"}]}"#;
        assert!(dfg_from_str(bad).is_err());
    }

    #[test]
    fn rejects_bad_arity() {
        let bad = r#"{"name":"x","nodes":[
            {"kind":"input","name":"a"},
            {"kind":"op","op":"add","args":[0]}
        ]}"#;
        assert!(dfg_from_str(bad).is_err());
    }

    #[test]
    fn rejects_invalid_graph() {
        // Forward reference caught by validate().
        let bad = r#"{"name":"x","nodes":[
            {"kind":"input","name":"a"},
            {"kind":"op","op":"add","args":[0,2]},
            {"kind":"output","name":"o","args":[1]}
        ]}"#;
        assert!(dfg_from_str(bad).is_err());
    }

    #[test]
    fn rejects_out_of_range_const() {
        let bad = r#"{"name":"x","nodes":[
            {"kind":"const","value":4294967296},
            {"kind":"output","name":"o","args":[0]}
        ]}"#;
        assert!(dfg_from_str(bad).is_err());
    }
}
