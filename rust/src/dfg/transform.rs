//! Classic cleanup transforms applied by the HLL→DFG frontend:
//! constant folding, common-subexpression elimination, dead-code
//! elimination, and the `normalize` pipeline combining them to fixpoint.
//!
//! All transforms preserve evaluation semantics (checked by tests and by
//! the property suite in `rust/tests/`).

use super::{Dfg, NodeId, NodeKind, OpKind};
use std::collections::BTreeMap;

/// Fold ops whose operands are both constants.
pub fn constant_fold(g: &Dfg) -> Dfg {
    rebuild(g, |out, node, map| match &node.kind {
        NodeKind::Op { op } => {
            let a = map[node.args[0] as usize];
            let b = map[node.args[1] as usize];
            let (ca, cb) = (const_value(out, a), const_value(out, b));
            if let (Some(x), Some(y)) = (ca, cb) {
                out.add_const(op.apply(x, y))
            } else {
                out.add_op(*op, a, b)
            }
        }
        _ => clone_node(out, node, map),
    })
}

/// Common-subexpression elimination: identical (op, args) pairs collapse
/// to one node; commutative ops are canonicalized first. Identical
/// constants are merged too.
pub fn cse(g: &Dfg) -> Dfg {
    let mut seen_ops: BTreeMap<(OpKind, NodeId, NodeId), NodeId> = BTreeMap::new();
    let mut seen_consts: BTreeMap<i32, NodeId> = BTreeMap::new();
    rebuild(g, move |out, node, map| match &node.kind {
        NodeKind::Const { value } => {
            if let Some(&id) = seen_consts.get(value) {
                id
            } else {
                let id = out.add_const(*value);
                seen_consts.insert(*value, id);
                id
            }
        }
        NodeKind::Op { op } => {
            let (mut a, mut b) = (map[node.args[0] as usize], map[node.args[1] as usize]);
            if op.commutative() && a > b {
                std::mem::swap(&mut a, &mut b);
            }
            let key = (*op, a, b);
            if let Some(&id) = seen_ops.get(&key) {
                id
            } else {
                let id = out.add_op(*op, a, b);
                seen_ops.insert(key, id);
                id
            }
        }
        _ => clone_node(out, node, map),
    })
}

/// Remove nodes not reachable from any output.
pub fn dce(g: &Dfg) -> Dfg {
    let mut live = vec![false; g.len()];
    for id in g.outputs() {
        mark_live(g, id, &mut live);
    }
    // Inputs always survive (they define the kernel signature / FIFO
    // layout even if unused).
    for id in g.inputs() {
        live[id as usize] = true;
    }
    let mut out = Dfg::new(&g.name);
    let mut map = vec![NodeId::MAX; g.len()];
    for id in g.ids() {
        if live[id as usize] {
            let node = g.node(id);
            map[id as usize] = clone_node(&mut out, node, &map);
        }
    }
    out
}

/// The frontend pipeline: fold → cse → dce, iterated to fixpoint.
pub fn normalize(g: &Dfg) -> Dfg {
    let mut cur = g.clone();
    for _ in 0..16 {
        let next = dce(&cse(&constant_fold(&cur)));
        if next == cur {
            return next;
        }
        cur = next;
    }
    cur
}

fn mark_live(g: &Dfg, id: NodeId, live: &mut [bool]) {
    if live[id as usize] {
        return;
    }
    live[id as usize] = true;
    for &a in &g.node(id).args {
        mark_live(g, a, live);
    }
}

fn const_value(g: &Dfg, id: NodeId) -> Option<i32> {
    match g.node(id).kind {
        NodeKind::Const { value } => Some(value),
        _ => None,
    }
}

fn clone_node(out: &mut Dfg, node: &super::Node, map: &[NodeId]) -> NodeId {
    match &node.kind {
        NodeKind::Input { name } => out.add_input(name),
        NodeKind::Const { value } => out.add_const(*value),
        NodeKind::Op { op } => out.add_op(*op, map[node.args[0] as usize], map[node.args[1] as usize]),
        NodeKind::Output { name } => out.add_output(name, map[node.args[0] as usize]),
    }
}

/// Generic rebuild walking nodes in topological order; `f` maps each old
/// node to a new node id given the old→new id map so far.
fn rebuild<F>(g: &Dfg, mut f: F) -> Dfg
where
    F: FnMut(&mut Dfg, &super::Node, &[NodeId]) -> NodeId,
{
    let mut out = Dfg::new(&g.name);
    let mut map = vec![NodeId::MAX; g.len()];
    for id in g.ids() {
        map[id as usize] = f(&mut out, g.node(id), &map);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::eval;

    #[test]
    fn folds_constant_subtrees() {
        let mut g = Dfg::new("f");
        let x = g.add_input("x");
        let a = g.add_const(3);
        let b = g.add_const(4);
        let s = g.add_op(OpKind::Add, a, b); // 7
        let m = g.add_op(OpKind::Mul, x, s);
        g.add_output("y", m);
        let folded = normalize(&g);
        assert_eq!(folded.n_ops(), 1);
        assert_eq!(eval(&folded, &[6]), vec![42]);
    }

    #[test]
    fn cse_merges_duplicates_including_commuted() {
        let mut g = Dfg::new("c");
        let a = g.add_input("a");
        let b = g.add_input("b");
        let s1 = g.add_op(OpKind::Add, a, b);
        let s2 = g.add_op(OpKind::Add, b, a); // same (commutative)
        let d1 = g.add_op(OpKind::Sub, a, b);
        let d2 = g.add_op(OpKind::Sub, b, a); // different (non-commutative)
        let m1 = g.add_op(OpKind::Mul, s1, d1);
        let m2 = g.add_op(OpKind::Mul, s2, d2);
        let r = g.add_op(OpKind::Add, m1, m2);
        g.add_output("y", r);
        let opt = normalize(&g);
        // add merges, subs stay distinct: ops = add, sub, sub, mul, mul, add
        assert_eq!(opt.n_ops(), 6);
        for ins in [[3, 5], [10, -2], [0, 0]] {
            assert_eq!(eval(&opt, &ins), eval(&g, &ins));
        }
    }

    #[test]
    fn dce_drops_unused_but_keeps_inputs() {
        let mut g = Dfg::new("d");
        let a = g.add_input("a");
        let b = g.add_input("b");
        let dead = g.add_op(OpKind::Mul, b, b);
        let _dead2 = g.add_op(OpKind::Add, dead, a);
        let live = g.add_op(OpKind::Add, a, a);
        g.add_output("y", live);
        let opt = dce(&g);
        assert_eq!(opt.n_ops(), 1);
        assert_eq!(opt.inputs().len(), 2); // b survives as signature
        assert_eq!(eval(&opt, &[5, 100]), vec![10]);
    }

    #[test]
    fn normalize_reaches_fixpoint() {
        let mut g = Dfg::new("fx");
        let x = g.add_input("x");
        let c1 = g.add_const(2);
        let c2 = g.add_const(2);
        let t = g.add_op(OpKind::Mul, c1, c2); // 4
        let u = g.add_op(OpKind::Mul, x, t);
        let v = g.add_op(OpKind::Mul, x, t); // duplicate
        let w = g.add_op(OpKind::Sub, u, v); // == 0 but not constant-foldable
        g.add_output("y", w);
        let n1 = normalize(&g);
        let n2 = normalize(&n1);
        assert_eq!(n1, n2);
        // u==v after CSE, so w = sub(t,t) stays an op (we do not do
        // algebraic identities), but the duplicated mul is gone.
        assert_eq!(n1.n_ops(), 2);
    }

    #[test]
    fn transforms_preserve_semantics_on_random_graphs() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(99);
        for case in 0..30 {
            let g = random_graph(&mut rng, case);
            let opt = normalize(&g);
            for trial in 0..10 {
                let ins: Vec<i32> = (0..g.inputs().len())
                    .map(|i| (trial * 37 + i as i32 * 11) - 50)
                    .collect();
                assert_eq!(eval(&g, &ins), eval(&opt, &ins), "case {case}");
            }
        }
    }

    fn random_graph(rng: &mut crate::util::prng::Rng, case: i32) -> Dfg {
        let mut g = Dfg::new(&format!("rand{case}"));
        let n_in = 1 + rng.index(4);
        let mut vals: Vec<NodeId> = (0..n_in).map(|i| g.add_input(&format!("i{i}"))).collect();
        for _ in 0..rng.index(3) {
            vals.push(g.add_const(rng.range_i64(-8, 8) as i32));
        }
        let ops = [OpKind::Add, OpKind::Sub, OpKind::Mul, OpKind::Xor];
        for _ in 0..(3 + rng.index(12)) {
            let a = *rng.choose(&vals);
            let b = *rng.choose(&vals);
            let op = *rng.choose(&ops);
            vals.push(g.add_op(op, a, b));
        }
        let last = *vals.last().unwrap();
        g.add_output("y", last);
        g
    }
}
