//! Data-flow graph IR.
//!
//! The paper's compiler (§IV) maps "feed-forward data flow graphs" onto
//! the linear overlay: nodes are arithmetic operations executed on the
//! DSP48E1-based FU, edges are value flow. This module provides the IR,
//! structural validation, evaluation (the functional oracle), the Table-II
//! characteristics analysis, classic cleanup transforms, and JSON / DOT
//! interchange.

mod analysis;
mod eval;
mod serde;
mod transform;

pub use analysis::{Characteristics, Levels};
pub use eval::{eval, eval_batch, eval_into};
pub use serde::{dfg_from_json, dfg_from_str, dfg_to_json};
pub use transform::{constant_fold, cse, dce, normalize};

use std::collections::BTreeMap;
use std::fmt;

/// Node index into [`Dfg::nodes`]. Construction keeps nodes topologically
/// ordered: every operand id is smaller than its user's id.
pub type NodeId = u32;

/// Arithmetic operations supported by the DSP48E1-based FU.
///
/// `SQR` in the paper's Table I is `Mul` with both operands equal; the
/// instruction encoding distinguishes them only via operand addresses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    Add,
    Sub,
    Mul,
    And,
    Or,
    Xor,
}

impl OpKind {
    pub const ALL: [OpKind; 6] = [
        OpKind::Add,
        OpKind::Sub,
        OpKind::Mul,
        OpKind::And,
        OpKind::Or,
        OpKind::Xor,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Add => "add",
            OpKind::Sub => "sub",
            OpKind::Mul => "mul",
            OpKind::And => "and",
            OpKind::Or => "or",
            OpKind::Xor => "xor",
        }
    }

    pub fn from_name(s: &str) -> Option<OpKind> {
        OpKind::ALL.iter().copied().find(|o| o.name() == s)
    }

    /// Wrapping two's-complement int32 semantics — identical in the Rust
    /// simulator, the jnp oracle and the Pallas kernel.
    pub fn apply(self, a: i32, b: i32) -> i32 {
        match self {
            OpKind::Add => a.wrapping_add(b),
            OpKind::Sub => a.wrapping_sub(b),
            OpKind::Mul => a.wrapping_mul(b),
            OpKind::And => a & b,
            OpKind::Or => a | b,
            OpKind::Xor => a ^ b,
        }
    }

    /// Is `op(a,b) == op(b,a)` for all inputs?
    pub fn commutative(self) -> bool {
        !matches!(self, OpKind::Sub)
    }
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Node payload.
#[derive(Debug, Clone, PartialEq)]
pub enum NodeKind {
    /// Primary input (streamed from the input FIFO).
    Input { name: String },
    /// Compile-time constant (preloaded into the FU register file at
    /// context-load time; see DESIGN.md on the paper's underspecification).
    Const { value: i32 },
    /// Binary arithmetic operation; `args.len() == 2`.
    Op { op: OpKind },
    /// Primary output (streamed to the output FIFO); `args.len() == 1`.
    Output { name: String },
}

/// One DFG node.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub kind: NodeKind,
    pub args: Vec<NodeId>,
}

impl Node {
    pub fn is_op(&self) -> bool {
        matches!(self.kind, NodeKind::Op { .. })
    }
    pub fn is_input(&self) -> bool {
        matches!(self.kind, NodeKind::Input { .. })
    }
    pub fn is_const(&self) -> bool {
        matches!(self.kind, NodeKind::Const { .. })
    }
    pub fn is_output(&self) -> bool {
        matches!(self.kind, NodeKind::Output { .. })
    }
}

/// A feed-forward data-flow graph in topological order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Dfg {
    pub name: String,
    nodes: Vec<Node>,
}

/// Structural error from [`Dfg::validate`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DfgError {
    ForwardReference(NodeId, NodeId),
    Arity(NodeId, String),
    DuplicateInput(String),
    DuplicateOutput(String),
    NoOutputs,
    OutputUsedAsOperand(NodeId, NodeId),
}

impl fmt::Display for DfgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DfgError::ForwardReference(n, a) => write!(
                f,
                "node {n}: operand {a} is not defined before use (graph must be topological)"
            ),
            DfgError::Arity(n, msg) => write!(f, "node {n}: {msg}"),
            DfgError::DuplicateInput(name) => write!(f, "duplicate input name '{name}'"),
            DfgError::DuplicateOutput(name) => write!(f, "duplicate output name '{name}'"),
            DfgError::NoOutputs => write!(f, "graph has no outputs"),
            DfgError::OutputUsedAsOperand(n, a) => {
                write!(f, "node {n}: operand {a} is an output node")
            }
        }
    }
}

impl std::error::Error for DfgError {}

impl Dfg {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            nodes: Vec::new(),
        }
    }

    // -- construction --------------------------------------------------

    pub fn add_input(&mut self, name: &str) -> NodeId {
        self.push(Node {
            kind: NodeKind::Input {
                name: name.to_string(),
            },
            args: vec![],
        })
    }

    pub fn add_const(&mut self, value: i32) -> NodeId {
        self.push(Node {
            kind: NodeKind::Const { value },
            args: vec![],
        })
    }

    pub fn add_op(&mut self, op: OpKind, a: NodeId, b: NodeId) -> NodeId {
        self.push(Node {
            kind: NodeKind::Op { op },
            args: vec![a, b],
        })
    }

    pub fn add_output(&mut self, name: &str, value: NodeId) -> NodeId {
        self.push(Node {
            kind: NodeKind::Output {
                name: name.to_string(),
            },
            args: vec![value],
        })
    }

    fn push(&mut self, node: Node) -> NodeId {
        let id = self.nodes.len() as NodeId;
        self.nodes.push(node);
        id
    }

    // -- access ---------------------------------------------------------

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id as usize]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn ids(&self) -> impl Iterator<Item = NodeId> {
        0..self.nodes.len() as NodeId
    }

    /// Input node ids in declaration order.
    pub fn inputs(&self) -> Vec<NodeId> {
        self.ids().filter(|&id| self.node(id).is_input()).collect()
    }

    /// Output node ids in declaration order.
    pub fn outputs(&self) -> Vec<NodeId> {
        self.ids().filter(|&id| self.node(id).is_output()).collect()
    }

    pub fn input_names(&self) -> Vec<&str> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.kind {
                NodeKind::Input { name } => Some(name.as_str()),
                _ => None,
            })
            .collect()
    }

    pub fn output_names(&self) -> Vec<&str> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.kind {
                NodeKind::Output { name } => Some(name.as_str()),
                _ => None,
            })
            .collect()
    }

    pub fn n_ops(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_op()).count()
    }

    /// Users of each node (adjacency reversed), computed on demand.
    pub fn users(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for (id, n) in self.nodes.iter().enumerate() {
            for &a in &n.args {
                out[a as usize].push(id as NodeId);
            }
        }
        out
    }

    // -- validation ------------------------------------------------------

    /// Check topological order, arity, name uniqueness, output discipline.
    pub fn validate(&self) -> Result<(), DfgError> {
        let mut input_names = BTreeMap::new();
        let mut output_names = BTreeMap::new();
        let mut has_output = false;
        for (idx, n) in self.nodes.iter().enumerate() {
            let id = idx as NodeId;
            for &a in &n.args {
                if a >= id {
                    return Err(DfgError::ForwardReference(id, a));
                }
                if self.node(a).is_output() {
                    return Err(DfgError::OutputUsedAsOperand(id, a));
                }
            }
            match &n.kind {
                NodeKind::Input { name } => {
                    if !n.args.is_empty() {
                        return Err(DfgError::Arity(id, "input takes no operands".into()));
                    }
                    if input_names.insert(name.clone(), id).is_some() {
                        return Err(DfgError::DuplicateInput(name.clone()));
                    }
                }
                NodeKind::Const { .. } => {
                    if !n.args.is_empty() {
                        return Err(DfgError::Arity(id, "const takes no operands".into()));
                    }
                }
                NodeKind::Op { .. } => {
                    if n.args.len() != 2 {
                        return Err(DfgError::Arity(
                            id,
                            format!("op needs 2 operands, has {}", n.args.len()),
                        ));
                    }
                }
                NodeKind::Output { name } => {
                    has_output = true;
                    if n.args.len() != 1 {
                        return Err(DfgError::Arity(
                            id,
                            format!("output needs 1 operand, has {}", n.args.len()),
                        ));
                    }
                    if output_names.insert(name.clone(), id).is_some() {
                        return Err(DfgError::DuplicateOutput(name.clone()));
                    }
                }
            }
        }
        if !has_output {
            return Err(DfgError::NoOutputs);
        }
        Ok(())
    }

    // -- DOT export -------------------------------------------------------

    /// Graphviz rendering for documentation / debugging.
    pub fn to_dot(&self) -> String {
        let mut s = format!("digraph \"{}\" {{\n  rankdir=TB;\n", self.name);
        for (idx, n) in self.nodes.iter().enumerate() {
            let (label, shape) = match &n.kind {
                NodeKind::Input { name } => (name.clone(), "invtriangle"),
                NodeKind::Const { value } => (value.to_string(), "diamond"),
                NodeKind::Op { op } => (op.name().to_uppercase(), "circle"),
                NodeKind::Output { name } => (name.clone(), "triangle"),
            };
            s.push_str(&format!("  n{idx} [label=\"{label}\", shape={shape}];\n"));
        }
        for (idx, n) in self.nodes.iter().enumerate() {
            for &a in &n.args {
                s.push_str(&format!("  n{a} -> n{idx};\n"));
            }
        }
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
pub(crate) fn tiny_graph() -> Dfg {
    // out = (a - b) * (a - b)  — a SUB feeding a SQR.
    let mut g = Dfg::new("tiny");
    let a = g.add_input("a");
    let b = g.add_input("b");
    let d = g.add_op(OpKind::Sub, a, b);
    let sq = g.add_op(OpKind::Mul, d, d);
    g.add_output("out", sq);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_validates() {
        let g = tiny_graph();
        assert!(g.validate().is_ok());
        assert_eq!(g.n_ops(), 2);
        assert_eq!(g.input_names(), vec!["a", "b"]);
        assert_eq!(g.output_names(), vec!["out"]);
    }

    #[test]
    fn op_semantics_wrap() {
        assert_eq!(OpKind::Add.apply(i32::MAX, 1), i32::MIN);
        assert_eq!(OpKind::Sub.apply(i32::MIN, 1), i32::MAX);
        assert_eq!(OpKind::Mul.apply(1 << 20, 1 << 20), 0);
        assert_eq!(OpKind::Mul.apply(65536, 65537), 65536);
        assert_eq!(OpKind::And.apply(0b1100, 0b1010), 0b1000);
        assert_eq!(OpKind::Or.apply(0b1100, 0b1010), 0b1110);
        assert_eq!(OpKind::Xor.apply(0b1100, 0b1010), 0b0110);
    }

    #[test]
    fn rejects_forward_reference() {
        let mut g = Dfg::new("bad");
        let a = g.add_input("a");
        // Hand-craft a node that references a later id.
        g.nodes.push(Node {
            kind: NodeKind::Op { op: OpKind::Add },
            args: vec![a, 99],
        });
        g.add_output("o", 1);
        assert!(matches!(g.validate(), Err(DfgError::ForwardReference(1, 99))));
    }

    #[test]
    fn rejects_duplicate_names() {
        let mut g = Dfg::new("dup");
        g.add_input("x");
        g.add_input("x");
        let c = g.add_const(1);
        g.add_output("o", c);
        assert_eq!(g.validate(), Err(DfgError::DuplicateInput("x".into())));
    }

    #[test]
    fn rejects_output_as_operand() {
        let mut g = Dfg::new("bad");
        let a = g.add_input("a");
        let o = g.add_output("o", a);
        g.add_output("o2", o);
        assert!(matches!(g.validate(), Err(DfgError::OutputUsedAsOperand(_, _))));
    }

    #[test]
    fn requires_an_output() {
        let mut g = Dfg::new("none");
        g.add_input("a");
        assert_eq!(g.validate(), Err(DfgError::NoOutputs));
    }

    #[test]
    fn users_adjacency() {
        let g = tiny_graph();
        let users = g.users();
        assert_eq!(users[0], vec![2]); // a used by sub
        assert_eq!(users[2], vec![3, 3]); // sub used twice by mul
        assert_eq!(users[3], vec![4]); // mul used by output
    }

    #[test]
    fn dot_contains_nodes_and_edges() {
        let dot = tiny_graph().to_dot();
        assert!(dot.contains("SUB"));
        assert!(dot.contains("MUL"));
        assert!(dot.contains("n2 -> n3;"));
    }

    #[test]
    fn opkind_round_trips_names() {
        for op in OpKind::ALL {
            assert_eq!(OpKind::from_name(op.name()), Some(op));
        }
        assert_eq!(OpKind::from_name("bogus"), None);
    }
}
