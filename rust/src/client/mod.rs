//! Thin wire client: call a `tmfu listen` server from another process.
//!
//! [`OverlayClient::connect`] dials a server (TCP `host:port` or
//! `unix:<path>`), performs the Hello version handshake, and starts
//! one reader thread that demultiplexes reply frames by request id —
//! so a single connection carries any number of in-flight calls from
//! any number of threads. [`OverlayClient::kernel`] resolves a kernel
//! name once into a [`RemoteKernel`] session that mirrors
//! [`KernelHandle`](crate::service::KernelHandle) method for method:
//! [`RemoteKernel::call`], [`RemoteKernel::call_batch`], and
//! non-blocking [`RemoteKernel::submit`] returning a [`RemotePending`]
//! with the same `poll` / `wait` / `wait_timeout` / `wait_deadline`
//! surface as the in-process `Pending`.
//!
//! Every failure is the same typed [`ServiceError`] a linked-in caller
//! would see: service-side errors round-trip the wire bit-exactly
//! (DESIGN.md §9), transport failures surface as
//! `Backend { backend: "wire", .. }`, and a dead connection answers
//! [`ServiceError::Disconnected`]. The client deliberately does **not**
//! pre-validate shapes — the server is authoritative, which is what
//! lets a test observe `ShapeMismatch` or `EmptyBatch` arrive over the
//! socket rather than be short-circuited locally.
//!
//! ```no_run
//! use tmfu_overlay::client::OverlayClient;
//!
//! fn main() -> Result<(), Box<dyn std::error::Error>> {
//!     let client = OverlayClient::connect("127.0.0.1:7700")?;
//!     let gradient = client.kernel("gradient")?;
//!     assert_eq!(gradient.call(&[3, 5, 2, 7, 1])?, vec![36]);
//!     println!("{}", client.metrics()?.to_string_pretty());
//!     Ok(())
//! }
//! ```
//!
//! Lifetime: sessions hold the connection by `Arc`, but dropping the
//! [`OverlayClient`] closes the socket — outstanding [`RemoteKernel`]s
//! and [`RemotePending`]s then answer `Disconnected` (a network
//! session ends with its connection, unlike in-process handles, which
//! outlive the service value).

use crate::exec::FlatBatch;
use crate::service::ServiceError;
use crate::util::json::{self, Json};
use crate::wire::{
    read_frame, write_frame, Frame, ListenAddr, WireStream, WIRE_VERSION_MAX, WIRE_VERSION_MIN,
};
use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// One decoded server reply, routed to the waiting request.
enum ServerReply {
    Info {
        kernel: u32,
        n_inputs: u16,
        n_outputs: u16,
    },
    Rows(FlatBatch),
    Metrics(String),
}

type ReplyResult = Result<ServerReply, ServiceError>;

struct Waiter {
    kernel: String,
    tx: mpsc::Sender<ReplyResult>,
}

/// Connection state shared by the client value, every session and the
/// reader thread.
struct ClientShared {
    writer: Mutex<BufWriter<WireStream>>,
    control: WireStream,
    pending: Mutex<HashMap<u64, Waiter>>,
    next_id: AtomicU64,
    closed: AtomicBool,
    /// A connection-fatal error frame (e.g. `Malformed` with no
    /// correlatable id) reported just before the server hung up;
    /// used to explain the drain to every waiter.
    fatal: Mutex<Option<ServiceError>>,
}

impl ClientShared {
    fn disconnected(&self, kernel: &str) -> ServiceError {
        ServiceError::Disconnected {
            kernel: kernel.to_string(),
        }
    }

    /// Register a waiter, then write the frame built from the fresh
    /// request id. The lock order (pending before writer) is shared
    /// with the reader's completion path, which takes only `pending`.
    fn send(
        &self,
        kernel: &str,
        build: impl FnOnce(u64) -> Frame,
    ) -> Result<mpsc::Receiver<ReplyResult>, ServiceError> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = mpsc::channel();
        {
            // The closed check and the insert share the `pending`
            // critical section with `drain`'s closed-store-and-sweep,
            // so a waiter can never be registered after the drain
            // swept (it would block forever — nothing would ever
            // complete it).
            let mut p = self.pending.lock().unwrap();
            if self.closed.load(Ordering::SeqCst) {
                return Err(self.drain_error(kernel));
            }
            p.insert(
                id,
                Waiter {
                    kernel: kernel.to_string(),
                    tx,
                },
            );
        }
        let frame = build(id);
        let wrote = {
            let mut w = self.writer.lock().unwrap();
            write_frame(&mut *w, &frame).and_then(|()| w.flush())
        };
        if let Err(e) = wrote {
            self.pending.lock().unwrap().remove(&id);
            // `InvalidInput` is the pre-write encode/size failure
            // (oversized arity or batch): nothing reached the socket,
            // the stream is still frame-aligned, and only this one
            // request fails. Anything else is a real I/O failure —
            // the connection is unusable from here on.
            if e.kind() != std::io::ErrorKind::InvalidInput {
                self.closed.store(true, Ordering::SeqCst);
            }
            return Err(ServiceError::Backend {
                backend: "wire".to_string(),
                message: format!("send failed: {e}"),
            });
        }
        Ok(rx)
    }

    /// The error to hand out once the connection is gone.
    fn drain_error(&self, kernel: &str) -> ServiceError {
        self.fatal
            .lock()
            .unwrap()
            .clone()
            .unwrap_or_else(|| self.disconnected(kernel))
    }

    /// Reader-side: complete one request by id.
    fn complete(&self, id: u64, result: ReplyResult) -> bool {
        match self.pending.lock().unwrap().remove(&id) {
            Some(w) => {
                let _ = w.tx.send(result);
                true
            }
            None => false,
        }
    }

    /// Reader-side: the connection is over; fail everything in flight.
    /// The closed-store happens inside the `pending` lock (see `send`)
    /// so no waiter can slip in behind the sweep.
    fn drain(&self) {
        let waiters: Vec<Waiter> = {
            let mut p = self.pending.lock().unwrap();
            self.closed.store(true, Ordering::SeqCst);
            p.drain().map(|(_, w)| w).collect()
        };
        for w in waiters {
            let err = self.drain_error(&w.kernel);
            let _ = w.tx.send(Err(err));
        }
    }
}

/// Takes the handshake-time `BufReader` whole — its buffer may already
/// hold bytes past HelloOk, which a raw-stream restart would lose.
fn reader_loop(shared: Arc<ClientShared>, mut r: BufReader<WireStream>) {
    loop {
        let frame = match read_frame(&mut r) {
            Ok(Some(f)) => f,
            Ok(None) => break,
            Err(e) => {
                *shared.fatal.lock().unwrap() = Some(ServiceError::Backend {
                    backend: "wire".to_string(),
                    message: format!("receive failed: {e}"),
                });
                break;
            }
        };
        let id = frame.request_id();
        match frame {
            Frame::KernelInfo {
                kernel,
                n_inputs,
                n_outputs,
                ..
            } => {
                shared.complete(
                    id,
                    Ok(ServerReply::Info {
                        kernel,
                        n_inputs,
                        n_outputs,
                    }),
                );
            }
            Frame::Reply { batch, .. } => {
                shared.complete(id, Ok(ServerReply::Rows(batch)));
            }
            Frame::Metrics { json, .. } => {
                shared.complete(id, Ok(ServerReply::Metrics(json)));
            }
            Frame::Error { err, .. } => {
                let e = err.into_service_error();
                if !shared.complete(id, Err(e.clone())) {
                    // No waiting request (id 0 / already gone): this is
                    // the server explaining an imminent hang-up.
                    *shared.fatal.lock().unwrap() = Some(e);
                }
            }
            // A server never sends client-side opcodes mid-stream; an
            // unexpected one means the peer is not speaking the
            // protocol. Stop reading rather than guess.
            _ => {
                *shared.fatal.lock().unwrap() = Some(ServiceError::Backend {
                    backend: "wire".to_string(),
                    message: "server sent a client-side frame".to_string(),
                });
                break;
            }
        }
    }
    shared.drain();
}

/// Extract the one reply a request expects, mapping kind mismatches to
/// a transport error.
fn expect_reply(
    rx_result: Result<ReplyResult, mpsc::RecvError>,
    shared: &ClientShared,
    kernel: &str,
) -> Result<ServerReply, ServiceError> {
    match rx_result {
        Ok(Ok(reply)) => Ok(reply),
        Ok(Err(e)) => Err(e),
        Err(_) => Err(shared.drain_error(kernel)),
    }
}

fn bad_reply(kernel: &str) -> ServiceError {
    ServiceError::Backend {
        backend: "wire".to_string(),
        message: format!("unexpected reply kind for kernel '{kernel}'"),
    }
}

// ---------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------

/// A connection to a `tmfu listen` server. One value per connection;
/// cheap sessions come from [`OverlayClient::kernel`]. Dropping the
/// client closes the socket and fails outstanding work with
/// [`ServiceError::Disconnected`].
pub struct OverlayClient {
    shared: Arc<ClientShared>,
    reader: Option<thread::JoinHandle<()>>,
    version: u16,
    backend: String,
}

impl OverlayClient {
    /// Dial `addr` (`host:port` or `unix:<path>`), shake hands, and
    /// start the reply-demultiplexing reader.
    pub fn connect(addr: &str) -> Result<OverlayClient, ServiceError> {
        let addr = ListenAddr::parse(addr);
        let stream = WireStream::connect(&addr).map_err(|e| ServiceError::Backend {
            backend: "wire".to_string(),
            message: format!("connect {addr}: {e}"),
        })?;
        let wire_err = |what: &str, e: std::io::Error| ServiceError::Backend {
            backend: "wire".to_string(),
            message: format!("{what}: {e}"),
        };
        let read_half = stream.try_clone().map_err(|e| wire_err("clone stream", e))?;
        let control = stream.try_clone().map_err(|e| wire_err("clone stream", e))?;
        // Synchronous handshake before any concurrency exists.
        let mut writer = BufWriter::new(stream);
        write_frame(
            &mut writer,
            &Frame::Hello {
                id: 0,
                min: WIRE_VERSION_MIN,
                max: WIRE_VERSION_MAX,
            },
        )
        .and_then(|()| writer.flush())
        .map_err(|e| wire_err("send hello", e))?;
        let mut reader = BufReader::new(read_half);
        let (version, backend) = match read_frame(&mut reader) {
            Ok(Some(Frame::HelloOk {
                version, backend, ..
            })) => (version, backend),
            Ok(Some(Frame::Error { err, .. })) => return Err(err.into_service_error()),
            Ok(Some(_)) => {
                return Err(wire_err(
                    "handshake",
                    std::io::Error::new(std::io::ErrorKind::InvalidData, "unexpected frame"),
                ))
            }
            Ok(None) => {
                return Err(wire_err(
                    "handshake",
                    std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "server hung up"),
                ))
            }
            Err(e) => return Err(wire_err("handshake", e)),
        };
        let shared = Arc::new(ClientShared {
            writer: Mutex::new(writer),
            control,
            pending: Mutex::new(HashMap::new()),
            // Handshake frames used id 0; requests start at 1.
            next_id: AtomicU64::new(1),
            closed: AtomicBool::new(false),
            fatal: Mutex::new(None),
        });
        let reader_shared = Arc::clone(&shared);
        let reader = thread::Builder::new()
            .name("wire-client-read".to_string())
            .spawn(move || reader_loop(reader_shared, reader))
            .map_err(|e| wire_err("spawn reader", e))?;
        Ok(OverlayClient {
            shared,
            reader: Some(reader),
            version,
            backend,
        })
    }

    /// Negotiated protocol version.
    pub fn version(&self) -> u16 {
        self.version
    }

    /// The server's execution-backend name (from the Hello banner).
    pub fn backend(&self) -> &str {
        &self.backend
    }

    /// Resolve a kernel name to a remote session (the wire mirror of
    /// `OverlayService::kernel`): id and arities are fetched once,
    /// then calls move only the dense id.
    pub fn kernel(&self, name: &str) -> Result<RemoteKernel, ServiceError> {
        let rx = self.shared.send(name, |id| Frame::Resolve {
            id,
            name: name.to_string(),
        })?;
        match expect_reply(rx.recv(), &self.shared, name)? {
            ServerReply::Info {
                kernel,
                n_inputs,
                n_outputs,
            } => Ok(RemoteKernel {
                shared: Arc::clone(&self.shared),
                name: name.to_string(),
                kernel,
                n_inputs: n_inputs as usize,
                n_outputs: n_outputs as usize,
            }),
            _ => Err(bad_reply(name)),
        }
    }

    /// Fetch the server's `MetricsSnapshot` as parsed JSON (same
    /// field names as `tmfu serve --metrics-json`).
    pub fn metrics(&self) -> Result<Json, ServiceError> {
        let rx = self.shared.send("", |id| Frame::GetMetrics { id })?;
        match expect_reply(rx.recv(), &self.shared, "")? {
            ServerReply::Metrics(text) => json::parse(&text).map_err(|e| ServiceError::Backend {
                backend: "wire".to_string(),
                message: format!("metrics json: {e}"),
            }),
            _ => Err(bad_reply("metrics")),
        }
    }

    /// Close the connection explicitly (also happens on drop).
    pub fn close(self) {
        let _ = self;
    }
}

impl Drop for OverlayClient {
    fn drop(&mut self) {
        self.shared.closed.store(true, Ordering::SeqCst);
        self.shared.control.shutdown_both();
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
    }
}

// ---------------------------------------------------------------------
// Remote sessions
// ---------------------------------------------------------------------

/// A remote kernel session: pre-resolved id + arities, `Clone + Send`,
/// mirroring [`KernelHandle`](crate::service::KernelHandle). Shapes
/// are **not** validated locally — the server answers the same typed
/// errors the in-process handle would raise.
#[derive(Clone)]
pub struct RemoteKernel {
    shared: Arc<ClientShared>,
    name: String,
    kernel: u32,
    n_inputs: usize,
    n_outputs: usize,
}

impl std::fmt::Debug for RemoteKernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RemoteKernel({} -> kernel#{})", self.name, self.kernel)
    }
}

impl RemoteKernel {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The server-side dense kernel id.
    pub fn id(&self) -> u32 {
        self.kernel
    }

    /// Input arity (words per request row).
    pub fn arity(&self) -> usize {
        self.n_inputs
    }

    /// Output arity (words per reply row).
    pub fn n_outputs(&self) -> usize {
        self.n_outputs
    }

    /// Non-blocking submit: the request is on the wire when this
    /// returns; the reply arrives on the [`RemotePending`].
    pub fn submit(&self, inputs: &[i32]) -> Result<RemotePending, ServiceError> {
        let rx = self.shared.send(&self.name, |id| Frame::Call {
            id,
            kernel: self.kernel,
            inputs: inputs.to_vec(),
        })?;
        Ok(RemotePending {
            rx,
            shared: Arc::clone(&self.shared),
            kernel: self.name.clone(),
        })
    }

    /// Blocking call: submit one row and wait for its reply.
    pub fn call(&self, inputs: &[i32]) -> Result<Vec<i32>, ServiceError> {
        self.submit(inputs)?.wait()
    }

    /// Blocking batch call: rows travel as one contiguous buffer, are
    /// admitted atomically server-side, and come back in row order.
    pub fn call_batch(&self, batch: &FlatBatch) -> Result<FlatBatch, ServiceError> {
        let rx = self.shared.send(&self.name, |id| Frame::CallBatch {
            id,
            kernel: self.kernel,
            batch: batch.clone(),
        })?;
        match expect_reply(rx.recv(), &self.shared, &self.name)? {
            ServerReply::Rows(out) => Ok(out),
            _ => Err(bad_reply(&self.name)),
        }
    }
}

// ---------------------------------------------------------------------
// Pending replies
// ---------------------------------------------------------------------

/// A future-like remote reply, mirroring
/// [`Pending`](crate::service::Pending): poll it, block on it, or
/// bound the wait. `Send`, so replies can be collected on another
/// thread.
pub struct RemotePending {
    rx: mpsc::Receiver<ReplyResult>,
    shared: Arc<ClientShared>,
    kernel: String,
}

impl std::fmt::Debug for RemotePending {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RemotePending({})", self.kernel)
    }
}

impl RemotePending {
    /// The kernel this reply belongs to.
    pub fn kernel_name(&self) -> &str {
        &self.kernel
    }

    fn one_row(&self, reply: ReplyResult) -> Result<Vec<i32>, ServiceError> {
        match reply? {
            ServerReply::Rows(batch) if batch.n_rows() == 1 => Ok(batch.row(0).to_vec()),
            _ => Err(bad_reply(&self.kernel)),
        }
    }

    /// Non-blocking check: `Some(result)` once the reply has arrived.
    pub fn poll(&mut self) -> Option<Result<Vec<i32>, ServiceError>> {
        match self.rx.try_recv() {
            Ok(reply) => Some(self.one_row(reply)),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(self.shared.drain_error(&self.kernel)))
            }
        }
    }

    /// Block until the reply arrives.
    pub fn wait(self) -> Result<Vec<i32>, ServiceError> {
        match self.rx.recv() {
            Ok(reply) => self.one_row(reply),
            Err(_) => Err(self.shared.drain_error(&self.kernel)),
        }
    }

    /// Block at most `timeout`; [`ServiceError::DeadlineExceeded`] if
    /// the reply has not arrived by then. The request stays in flight —
    /// poll or wait again later (same contract as the in-process
    /// `Pending`).
    pub fn wait_timeout(&mut self, timeout: Duration) -> Result<Vec<i32>, ServiceError> {
        match self.rx.recv_timeout(timeout) {
            Ok(reply) => self.one_row(reply),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(ServiceError::DeadlineExceeded {
                kernel: self.kernel.clone(),
            }),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(self.shared.drain_error(&self.kernel))
            }
        }
    }

    /// Block until `deadline` at the latest (expressed through
    /// [`Self::wait_timeout`], the one timing implementation).
    pub fn wait_deadline(&mut self, deadline: Instant) -> Result<Vec<i32>, ServiceError> {
        self.wait_timeout(deadline.saturating_duration_since(Instant::now()))
    }
}
