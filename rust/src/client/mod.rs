//! Thin wire client: call a `tmfu listen` server from another process.
//!
//! [`OverlayClient::connect`] dials a server (TCP `host:port` or
//! `unix:<path>`), performs the Hello version handshake, and starts
//! one reader thread that demultiplexes reply frames by request id —
//! so a single connection carries any number of in-flight calls from
//! any number of threads. [`OverlayClient::kernel`] resolves a kernel
//! name once into a [`RemoteKernel`] session that mirrors
//! [`KernelHandle`](crate::service::KernelHandle) method for method:
//! [`RemoteKernel::call`], [`RemoteKernel::call_batch`], and
//! non-blocking [`RemoteKernel::submit`] returning a [`RemotePending`]
//! with the same `poll` / `wait` / `wait_timeout` / `wait_deadline`
//! surface as the in-process `Pending`.
//!
//! Demultiplexing mirrors the server's completion slab
//! (DESIGN.md §10): each in-flight request is a recycled **reply
//! slot** with its own generation counter, and the request id on the
//! wire *encodes* the slot index and generation
//! (`id = generation << 32 | slot`). The reader resolves a reply to
//! its slot with one index — no hash map, no per-request channel
//! allocation — and a stale id (a slot already recycled) can never
//! complete the wrong request. Each slot carries its own condvar, so
//! completing one request wakes exactly its waiter, not the herd.
//!
//! Every failure is the same typed [`ServiceError`] a linked-in caller
//! would see: service-side errors round-trip the wire bit-exactly
//! (DESIGN.md §9), transport failures surface as
//! `Backend { backend: "wire", .. }`, and a dead connection answers
//! [`ServiceError::Disconnected`]. The client deliberately does **not**
//! pre-validate shapes — the server is authoritative, which is what
//! lets a test observe `ShapeMismatch` or `EmptyBatch` arrive over the
//! socket rather than be short-circuited locally.
//!
//! ```no_run
//! use tmfu_overlay::client::OverlayClient;
//!
//! fn main() -> Result<(), Box<dyn std::error::Error>> {
//!     let client = OverlayClient::connect("127.0.0.1:7700")?;
//!     let gradient = client.kernel("gradient")?;
//!     assert_eq!(gradient.call(&[3, 5, 2, 7, 1])?, vec![36]);
//!     println!("{}", client.metrics()?.to_string_pretty());
//!     Ok(())
//! }
//! ```
//!
//! Lifetime: sessions hold the connection by `Arc`, but dropping the
//! [`OverlayClient`] closes the socket — outstanding [`RemoteKernel`]s
//! and [`RemotePending`]s then answer `Disconnected` (a network
//! session ends with its connection, unlike in-process handles, which
//! outlive the service value).
//!
//! Timeouts: [`OverlayClient::builder`] exposes a connect timeout and
//! a read timeout (both default 30 s). The read timeout is a *silence
//! bound*, not a per-call deadline: if replies are owed and the socket
//! stays silent past it, the connection is declared dead and every
//! waiter gets the typed `Disconnected` instead of blocking forever.
//! Per-call deadlines stay where they were — `wait_timeout` /
//! `wait_deadline` on the pending handle.

use crate::coordinator::completion::WakeTarget;
use crate::exec::FlatBatch;
use crate::service::ServiceError;
use crate::util::json::{self, Json};
use crate::util::prng::Rng;
use crate::wire::{
    read_frame_patient, write_frame, Frame, ListenAddr, PatientRead, TenantToken, WireStream,
    HEALTH_DRAINING, WIRE_VERSION_MAX, WIRE_VERSION_MIN,
};
use std::io::{BufReader, BufWriter, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// One decoded server reply, routed to the waiting request.
enum ServerReply {
    Info {
        kernel: u32,
        n_inputs: u16,
        n_outputs: u16,
    },
    Rows(FlatBatch),
    Metrics(String),
    Health { status: u8, inflight: u32 },
}

type ReplyResult = Result<ServerReply, ServiceError>;

// ---------------------------------------------------------------------
// Reply-slot demux
// ---------------------------------------------------------------------

/// Where one reply slot is in its lifecycle.
enum Phase {
    /// On the free list.
    Free,
    /// A request is in flight under this slot's current generation.
    Waiting,
    /// The reply arrived and awaits collection.
    Done(ReplyResult),
    /// The pending handle was dropped; recycle on completion.
    Abandoned,
    /// The connection died with this request in flight.
    Gone,
}

struct ReplyState {
    generation: u32,
    phase: Phase,
    /// Doorbell rung when this slot settles (reply or connection
    /// death), so a reactor can multiplex many remote calls on one
    /// wake source instead of a thread per call. `None` for plain
    /// condvar waits.
    waker: Option<WakeTarget>,
}

/// One recycled reply slot: its own mutex + condvar, so a completion
/// wakes exactly the thread waiting on *this* request.
struct ReplySlot {
    m: Mutex<ReplyState>,
    cv: Condvar,
}

impl ReplySlot {
    fn new() -> ReplySlot {
        ReplySlot {
            m: Mutex::new(ReplyState {
                // Start at 1 so a live request id is never 0 — id 0 is
                // the handshake convention and doubles as the server's
                // "no correlatable request" sentinel.
                generation: 1,
                phase: Phase::Free,
                waker: None,
            }),
            cv: Condvar::new(),
        }
    }
}

struct DemuxSlots {
    slots: Vec<Arc<ReplySlot>>,
    free: Vec<u32>,
    /// Set (under this lock) when the connection dies, so no slot can
    /// be reserved after the drain sweep — a late reservation would
    /// wait forever.
    closed: bool,
}

/// The client-side completion structure: slot reservation/release plus
/// the id ↔ slot mapping (pure arithmetic — the id carries the slot).
struct Demux {
    m: Mutex<DemuxSlots>,
}

/// A reserved slot: what `send` hands back, and what [`RemotePending`]
/// wraps. The generation pins one life of the slot.
struct ReplyTicket {
    slot: Arc<ReplySlot>,
    idx: u32,
    generation: u32,
}

impl ReplyTicket {
    fn request_id(&self) -> u64 {
        (u64::from(self.generation) << 32) | u64::from(self.idx)
    }
}

/// Outcome of inspecting a slot under its lock.
enum TakeState {
    NotReady,
    Ready(ReplyResult),
    /// Connection died mid-flight (slot still needs releasing).
    Gone,
    /// Generation mismatch: the slot was already recycled. Nothing to
    /// release.
    Stale,
}

impl Demux {
    fn new() -> Demux {
        Demux {
            m: Mutex::new(DemuxSlots {
                slots: Vec::new(),
                free: Vec::new(),
                closed: false,
            }),
        }
    }

    /// Reserve a slot for one request. `None` once the connection is
    /// closed. The `closed` check *and* the Waiting mark share the
    /// demux critical section with [`Self::drain`]'s
    /// closed-store-and-sweep, so a reservation is either refused or
    /// visible to the sweep — it can never slip in behind it and wait
    /// forever. (Nesting the slot lock inside the demux lock here is
    /// the one place the two are held together; every other path
    /// takes them strictly one at a time, so no cycle exists.)
    fn reserve(&self, waker: Option<WakeTarget>) -> Option<ReplyTicket> {
        let mut d = self.m.lock().unwrap();
        if d.closed {
            return None;
        }
        let idx = match d.free.pop() {
            Some(i) => i,
            None => {
                d.slots.push(Arc::new(ReplySlot::new()));
                (d.slots.len() - 1) as u32
            }
        };
        let slot = Arc::clone(&d.slots[idx as usize]);
        let generation = {
            let mut s = slot.m.lock().unwrap();
            debug_assert!(matches!(s.phase, Phase::Free), "reserved a non-free slot");
            s.phase = Phase::Waiting;
            s.waker = waker;
            s.generation
        };
        drop(d);
        Some(ReplyTicket {
            slot,
            idx,
            generation,
        })
    }

    /// Refuse all future reservations. Used when a partial frame may
    /// be stuck on the wire (the stream is no longer frame-aligned);
    /// in-flight slots drain normally once the reader observes the
    /// connection die.
    fn close(&self) {
        self.m.lock().unwrap().closed = true;
    }

    /// Return a slot to the free list (generation bumped first, so
    /// every outstanding id for the old life goes stale).
    fn release(&self, slot: &Arc<ReplySlot>, idx: u32) {
        {
            let mut s = slot.m.lock().unwrap();
            s.generation = s.generation.wrapping_add(1);
            s.phase = Phase::Free;
            s.waker = None;
        }
        self.m.lock().unwrap().free.push(idx);
    }

    /// Whether any request is currently outstanding (Waiting or
    /// Abandoned). Drives the reader's idle handling: silence past the
    /// read timeout only condemns the connection when a reply is
    /// actually owed. (Demux lock then slot lock — the same order as
    /// [`Self::reserve`], so no cycle.)
    fn has_inflight(&self) -> bool {
        let d = self.m.lock().unwrap();
        d.slots.iter().any(|s| {
            matches!(s.m.lock().unwrap().phase, Phase::Waiting | Phase::Abandoned)
        })
    }

    /// Reader-side: complete the request a reply frame names. `false`
    /// when no live request matches (stale generation, unknown slot,
    /// or the id-0 sentinel) — the caller treats that as a
    /// connection-level announcement.
    fn complete(&self, id: u64, result: ReplyResult) -> bool {
        let idx = (id & 0xffff_ffff) as usize;
        let generation = (id >> 32) as u32;
        let slot = {
            let d = self.m.lock().unwrap();
            match d.slots.get(idx) {
                Some(s) => Arc::clone(s),
                None => return false,
            }
        };
        let mut s = slot.m.lock().unwrap();
        if s.generation != generation {
            return false;
        }
        if matches!(s.phase, Phase::Abandoned) {
            // Nobody will collect: recycle now.
            drop(s);
            self.release(&slot, idx as u32);
            return true;
        }
        if matches!(s.phase, Phase::Waiting) {
            s.phase = Phase::Done(result);
            let waker = s.waker.take();
            drop(s);
            slot.cv.notify_all();
            if let Some((w, tag)) = waker {
                w.ring(tag);
            }
            return true;
        }
        false
    }

    /// Reader-side: the connection is over. Mark every in-flight slot
    /// `Gone` (waiters wake and construct their own typed error) and
    /// refuse all future reservations.
    fn drain(&self) {
        let slots: Vec<(Arc<ReplySlot>, u32)> = {
            let mut d = self.m.lock().unwrap();
            d.closed = true;
            d.slots
                .iter()
                .enumerate()
                .map(|(i, s)| (Arc::clone(s), i as u32))
                .collect()
        };
        for (slot, idx) in slots {
            let mut s = slot.m.lock().unwrap();
            if matches!(s.phase, Phase::Waiting) {
                s.phase = Phase::Gone;
                let waker = s.waker.take();
                drop(s);
                slot.cv.notify_all();
                if let Some((w, tag)) = waker {
                    w.ring(tag);
                }
            } else if matches!(s.phase, Phase::Abandoned) {
                drop(s);
                self.release(&slot, idx);
            }
        }
    }
}

impl ReplyTicket {
    /// Inspect the slot once (under its lock).
    fn take_state(&self, s: &mut ReplyState) -> TakeState {
        if s.generation != self.generation {
            return TakeState::Stale;
        }
        if matches!(s.phase, Phase::Done(_)) {
            let Phase::Done(r) = std::mem::replace(&mut s.phase, Phase::Waiting) else {
                unreachable!("checked Done above");
            };
            return TakeState::Ready(r);
        }
        if matches!(s.phase, Phase::Gone) {
            return TakeState::Gone;
        }
        TakeState::NotReady
    }

    /// Blocking (optionally deadline-bounded) take. `None` = deadline
    /// passed, request still in flight. On `Some`, the slot has been
    /// released.
    fn wait_take(
        &self,
        shared: &ClientShared,
        deadline: Option<Instant>,
        kernel: &str,
    ) -> Option<ReplyResult> {
        let mut s = self.slot.m.lock().unwrap();
        loop {
            match self.take_state(&mut s) {
                TakeState::Ready(r) => {
                    drop(s);
                    shared.demux.release(&self.slot, self.idx);
                    return Some(r);
                }
                TakeState::Gone => {
                    drop(s);
                    shared.demux.release(&self.slot, self.idx);
                    return Some(Err(shared.drain_error(kernel)));
                }
                TakeState::Stale => return Some(Err(shared.drain_error(kernel))),
                TakeState::NotReady => {}
            }
            match deadline {
                None => s = self.slot.cv.wait(s).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return None;
                    }
                    s = self.slot.cv.wait_timeout(s, d - now).unwrap().0;
                }
            }
        }
    }

    /// Non-blocking take. Same release semantics as [`Self::wait_take`].
    fn try_take(&self, shared: &ClientShared, kernel: &str) -> Option<ReplyResult> {
        let mut s = self.slot.m.lock().unwrap();
        match self.take_state(&mut s) {
            TakeState::Ready(r) => {
                drop(s);
                shared.demux.release(&self.slot, self.idx);
                Some(r)
            }
            TakeState::Gone => {
                drop(s);
                shared.demux.release(&self.slot, self.idx);
                Some(Err(shared.drain_error(kernel)))
            }
            TakeState::Stale => Some(Err(shared.drain_error(kernel))),
            TakeState::NotReady => None,
        }
    }

    /// The pending handle is going away without collecting.
    fn abandon(&self, shared: &ClientShared) {
        let mut s = self.slot.m.lock().unwrap();
        if s.generation != self.generation {
            return;
        }
        if matches!(s.phase, Phase::Waiting) {
            // The reader (or the drain) recycles it on completion.
            s.phase = Phase::Abandoned;
            return;
        }
        if matches!(s.phase, Phase::Done(_) | Phase::Gone) {
            drop(s);
            shared.demux.release(&self.slot, self.idx);
        }
    }

    /// A send failed before anything reached the socket: cancel the
    /// reservation outright.
    fn cancel(&self, shared: &ClientShared) {
        let s = self.slot.m.lock().unwrap();
        if s.generation != self.generation || !matches!(s.phase, Phase::Waiting) {
            return;
        }
        drop(s);
        shared.demux.release(&self.slot, self.idx);
    }

    /// A `Cancel` frame was sent for this request: the server writes
    /// no reply for a cancelled id, so the slot is recycled
    /// immediately regardless of phase. A reply that raced the cancel
    /// onto the wire arrives with a stale generation and is dropped
    /// by the reader's `complete` — it can never land in the slot's
    /// next life.
    fn discard(&self, shared: &ClientShared) {
        let s = self.slot.m.lock().unwrap();
        if s.generation != self.generation {
            return;
        }
        drop(s);
        shared.demux.release(&self.slot, self.idx);
    }
}

// ---------------------------------------------------------------------
// Connection state
// ---------------------------------------------------------------------

/// Connection state shared by the client value, every session and the
/// reader thread.
struct ClientShared {
    writer: Mutex<BufWriter<WireStream>>,
    control: WireStream,
    demux: Demux,
    /// Negotiated protocol version — gates the v2 extensions
    /// (deadlines on Call frames, Cancel on drop).
    version: u16,
    /// A connection-fatal error frame (e.g. `Malformed` with no
    /// correlatable id) reported just before the server hung up;
    /// used to explain the drain to every waiter.
    fatal: Mutex<Option<ServiceError>>,
}

impl ClientShared {
    fn disconnected(&self, kernel: &str) -> ServiceError {
        ServiceError::Disconnected {
            kernel: kernel.to_string(),
        }
    }

    /// The error to hand out once the connection is gone.
    fn drain_error(&self, kernel: &str) -> ServiceError {
        self.fatal
            .lock()
            .unwrap()
            .clone()
            .unwrap_or_else(|| self.disconnected(kernel))
    }

    /// Reserve a reply slot, then write the frame built from its
    /// encoded request id. The reservation is visible to the reader
    /// before the first byte leaves, so a fast reply always finds its
    /// slot.
    fn send(
        &self,
        kernel: &str,
        build: impl FnOnce(u64) -> Frame,
    ) -> Result<ReplyTicket, ServiceError> {
        self.send_with(kernel, None, build)
    }

    /// [`Self::send`] with an optional completion doorbell, attached
    /// in the same critical section that marks the slot Waiting — so
    /// the waker can never miss a reply that races the send.
    fn send_with(
        &self,
        kernel: &str,
        waker: Option<WakeTarget>,
        build: impl FnOnce(u64) -> Frame,
    ) -> Result<ReplyTicket, ServiceError> {
        let Some(ticket) = self.demux.reserve(waker) else {
            return Err(self.drain_error(kernel));
        };
        let frame = build(ticket.request_id());
        let wrote = {
            let mut w = self.writer.lock().unwrap();
            write_frame(&mut *w, &frame).and_then(|()| w.flush())
        };
        if let Err(e) = wrote {
            // `InvalidInput` is the pre-write encode/size failure
            // (oversized arity or batch): nothing reached the socket,
            // the stream is still frame-aligned, and only this one
            // request fails. Anything else is a real I/O failure that
            // may have left a partial frame on the wire — the stream
            // is no longer frame-aligned, so refuse all future sends
            // and kick the reader so in-flight work drains promptly.
            ticket.cancel(self);
            if e.kind() != std::io::ErrorKind::InvalidInput {
                self.demux.close();
                self.control.shutdown_both();
            }
            return Err(ServiceError::Backend {
                backend: "wire".to_string(),
                message: format!("send failed: {e}"),
            });
        }
        Ok(ticket)
    }

    /// Fire-and-forget `Cancel` for an in-flight request id. The
    /// server never replies to a Cancel, so there is nothing to wait
    /// for; a write failure gets the same frame-alignment treatment
    /// as [`Self::send_with`] (a partial frame poisons the stream).
    fn send_cancel(&self, id: u64) {
        let wrote = {
            let mut w = self.writer.lock().unwrap();
            write_frame(&mut *w, &Frame::Cancel { id }).and_then(|()| w.flush())
        };
        if let Err(e) = wrote {
            if e.kind() != std::io::ErrorKind::InvalidInput {
                self.demux.close();
                self.control.shutdown_both();
            }
        }
    }

    /// Send + block for the one reply a request expects.
    fn call_roundtrip(
        &self,
        kernel: &str,
        build: impl FnOnce(u64) -> Frame,
    ) -> Result<ServerReply, ServiceError> {
        let ticket = self.send(kernel, build)?;
        ticket
            .wait_take(self, None, kernel)
            .expect("unbounded wait cannot time out")
    }
}

fn bad_reply(kernel: &str) -> ServiceError {
    ServiceError::Backend {
        backend: "wire".to_string(),
        message: format!("unexpected reply kind for kernel '{kernel}'"),
    }
}

/// Classify receive failures that mean "the connection is over"
/// rather than "the peer spoke garbage". These leave `fatal` unset, so
/// every waiter gets the typed per-kernel
/// [`ServiceError::Disconnected`] instead of an opaque transport
/// message.
fn is_disconnect(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::UnexpectedEof
            | std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::ConnectionAborted
            | std::io::ErrorKind::BrokenPipe
            | std::io::ErrorKind::TimedOut
    )
}

/// Takes the handshake-time `BufReader` whole — its buffer may already
/// hold bytes past HelloOk, which a raw-stream restart would lose.
fn reader_loop(shared: Arc<ClientShared>, mut r: BufReader<WireStream>) {
    // The drain must run on *every* exit from this thread — including
    // a panic — or waiters block forever on slots nobody will settle.
    struct DrainOnExit(Arc<ClientShared>);
    impl Drop for DrainOnExit {
        fn drop(&mut self) {
            self.0.demux.drain();
        }
    }
    let _drain = DrainOnExit(Arc::clone(&shared));
    // Consecutive read-timeout ticks with replies owed. Two strikes —
    // not one — so a request that lands just before a tick cannot
    // condemn a healthy connection: by the second strike the socket
    // has been silent for a full timeout window *while* that request
    // was outstanding.
    let mut idle_strikes = 0u32;
    loop {
        let frame = match read_frame_patient(&mut r) {
            Ok(PatientRead::Frame(f)) => f,
            // Clean close or reset: leave `fatal` unset — waiters
            // construct the typed per-kernel Disconnected themselves.
            Ok(PatientRead::Eof) => break,
            Ok(PatientRead::Idle) => {
                if !shared.demux.has_inflight() {
                    // Quiet connection, nothing owed: keep waiting.
                    idle_strikes = 0;
                    continue;
                }
                idle_strikes += 1;
                if idle_strikes >= 2 {
                    // Replies owed and the server silent past the
                    // bound: declare the connection dead instead of
                    // letting callers block indefinitely.
                    shared.control.shutdown_both();
                    break;
                }
                continue;
            }
            Err(e) if is_disconnect(&e) => break,
            Err(e) => {
                *shared.fatal.lock().unwrap() = Some(ServiceError::Backend {
                    backend: "wire".to_string(),
                    message: format!("receive failed: {e}"),
                });
                break;
            }
        };
        idle_strikes = 0;
        let id = frame.request_id();
        match frame {
            Frame::KernelInfo {
                kernel,
                n_inputs,
                n_outputs,
                ..
            } => {
                shared.demux.complete(
                    id,
                    Ok(ServerReply::Info {
                        kernel,
                        n_inputs,
                        n_outputs,
                    }),
                );
            }
            Frame::Reply { batch, .. } => {
                shared.demux.complete(id, Ok(ServerReply::Rows(batch)));
            }
            Frame::Metrics { json, .. } => {
                shared.demux.complete(id, Ok(ServerReply::Metrics(json)));
            }
            Frame::HealthOk {
                status, inflight, ..
            } => {
                shared
                    .demux
                    .complete(id, Ok(ServerReply::Health { status, inflight }));
            }
            Frame::Error { err, .. } => {
                let e = err.into_service_error();
                if !shared.demux.complete(id, Err(e.clone())) {
                    // No waiting request (id 0 / already gone): this is
                    // the server explaining an imminent hang-up.
                    *shared.fatal.lock().unwrap() = Some(e);
                }
            }
            // A server never sends client-side opcodes mid-stream; an
            // unexpected one means the peer is not speaking the
            // protocol. Stop reading rather than guess.
            _ => {
                *shared.fatal.lock().unwrap() = Some(ServiceError::Backend {
                    backend: "wire".to_string(),
                    message: "server sent a client-side frame".to_string(),
                });
                break;
            }
        }
    }
    // `DrainOnExit` sweeps the demux here (and on panic).
}

// ---------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------

/// Fresh token nonces: wall-clock nanoseconds mixed with a process
/// counter, so two connects in the same nanosecond (or a clock that
/// stands still in a sandbox) still never reuse a nonce within this
/// process. Servers burn nonces per tenant, so uniqueness per
/// (tenant, secret holder) is what matters.
fn fresh_nonce() -> u64 {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map_or(0, |d| u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    nanos ^ COUNTER.fetch_add(1, Ordering::Relaxed).rotate_left(17)
}

/// Connection configuration for [`OverlayClient`]; obtained from
/// [`OverlayClient::builder`].
#[derive(Debug, Clone)]
pub struct ClientBuilder {
    connect_timeout: Option<Duration>,
    read_timeout: Option<Duration>,
    tenant: Option<String>,
    secret: Option<Vec<u8>>,
}

impl Default for ClientBuilder {
    fn default() -> ClientBuilder {
        ClientBuilder::new()
    }
}

impl ClientBuilder {
    /// Both timeouts default to 30 s.
    pub fn new() -> ClientBuilder {
        ClientBuilder {
            connect_timeout: Some(Duration::from_secs(30)),
            read_timeout: Some(Duration::from_secs(30)),
            tenant: None,
            secret: None,
        }
    }

    /// Tenant name to authenticate as. Takes effect together with
    /// [`Self::secret`]: when both are set, the Hello carries a signed
    /// [`TenantToken`] (wire v2). A name without a secret is sent as
    /// an unsigned attribution label only when the server runs with
    /// auth off — auth-required servers refuse it.
    pub fn tenant(mut self, name: &str) -> ClientBuilder {
        self.tenant = Some(name.to_string());
        self
    }

    /// Shared secret for [`Self::tenant`] (the server holds the same
    /// bytes in its `--tenants` keyring).
    pub fn secret(mut self, secret: &[u8]) -> ClientBuilder {
        self.secret = Some(secret.to_vec());
        self
    }

    /// TCP connect timeout; `None` falls back to the OS default.
    /// Unix-socket connects are a local rendezvous (instant or
    /// refused) and ignore this.
    pub fn connect_timeout(mut self, d: Option<Duration>) -> ClientBuilder {
        self.connect_timeout = d;
        self
    }

    /// Silence bound on the reply stream: with replies owed and the
    /// socket silent for two consecutive windows of this length, the
    /// connection is declared dead and every waiter gets the typed
    /// [`ServiceError::Disconnected`]. `None` disables the bound
    /// (reads block indefinitely).
    pub fn read_timeout(mut self, d: Option<Duration>) -> ClientBuilder {
        self.read_timeout = d;
        self
    }

    /// Dial `addr` with this configuration (see
    /// [`OverlayClient::connect`]).
    pub fn connect(&self, addr: &str) -> Result<OverlayClient, ServiceError> {
        OverlayClient::connect_with(addr, self)
    }
}

/// A connection to a `tmfu listen` server. One value per connection;
/// cheap sessions come from [`OverlayClient::kernel`]. Dropping the
/// client closes the socket and fails outstanding work with
/// [`ServiceError::Disconnected`].
pub struct OverlayClient {
    shared: Arc<ClientShared>,
    reader: Option<thread::JoinHandle<()>>,
    version: u16,
    backend: String,
}

impl OverlayClient {
    /// Connection configuration: connect/read timeouts (default 30 s
    /// each).
    pub fn builder() -> ClientBuilder {
        ClientBuilder::new()
    }

    /// Dial `addr` (`host:port` or `unix:<path>`), shake hands, and
    /// start the reply-demultiplexing reader — with default timeouts
    /// ([`OverlayClient::builder`] to change them).
    pub fn connect(addr: &str) -> Result<OverlayClient, ServiceError> {
        ClientBuilder::new().connect(addr)
    }

    fn connect_with(addr: &str, cfg: &ClientBuilder) -> Result<OverlayClient, ServiceError> {
        let addr = ListenAddr::parse(addr);
        let stream = WireStream::connect_with_timeout(&addr, cfg.connect_timeout).map_err(|e| {
            ServiceError::Backend {
                backend: "wire".to_string(),
                message: format!("connect {addr}: {e}"),
            }
        })?;
        let wire_err = |what: &str, e: std::io::Error| ServiceError::Backend {
            backend: "wire".to_string(),
            message: format!("{what}: {e}"),
        };
        let read_half = stream.try_clone().map_err(|e| wire_err("clone stream", e))?;
        let control = stream.try_clone().map_err(|e| wire_err("clone stream", e))?;
        // The silence bound arms SO_RCVTIMEO on the shared socket; the
        // reader's patient loop turns each expiry into an idle tick.
        read_half
            .set_read_timeout(cfg.read_timeout)
            .map_err(|e| wire_err("set read timeout", e))?;
        // Synchronous handshake before any concurrency exists. A
        // configured tenant signs a fresh-nonce token into the Hello;
        // without a secret the MAC is over empty bytes — a pure
        // attribution label that only an auth-off server accepts.
        let token = cfg.tenant.as_deref().map(|name| {
            let secret: &[u8] = cfg.secret.as_deref().unwrap_or(&[]);
            TenantToken::sign(name, secret, fresh_nonce())
        });
        let mut writer = BufWriter::new(stream);
        write_frame(
            &mut writer,
            &Frame::Hello {
                id: 0,
                min: WIRE_VERSION_MIN,
                max: WIRE_VERSION_MAX,
                token,
            },
        )
        .and_then(|()| writer.flush())
        .map_err(|e| wire_err("send hello", e))?;
        let mut reader = BufReader::new(read_half);
        let (version, backend) = match read_frame_patient(&mut reader) {
            Ok(PatientRead::Frame(Frame::HelloOk {
                version, backend, ..
            })) => (version, backend),
            Ok(PatientRead::Frame(Frame::Error { err, .. })) => {
                return Err(err.into_service_error())
            }
            Ok(PatientRead::Frame(_)) => {
                return Err(wire_err(
                    "handshake",
                    std::io::Error::new(std::io::ErrorKind::InvalidData, "unexpected frame"),
                ))
            }
            Ok(PatientRead::Eof) => {
                return Err(wire_err(
                    "handshake",
                    std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "server hung up"),
                ))
            }
            // One full silent window with the Hello unanswered is a
            // failed handshake, not patience material.
            Ok(PatientRead::Idle) => {
                return Err(wire_err(
                    "handshake",
                    std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "no HelloOk within the read timeout",
                    ),
                ))
            }
            Err(e) => return Err(wire_err("handshake", e)),
        };
        let shared = Arc::new(ClientShared {
            writer: Mutex::new(writer),
            control,
            demux: Demux::new(),
            version,
            fatal: Mutex::new(None),
        });
        let reader_shared = Arc::clone(&shared);
        let reader = thread::Builder::new()
            .name("wire-client-read".to_string())
            .spawn(move || reader_loop(reader_shared, reader))
            .map_err(|e| wire_err("spawn reader", e))?;
        Ok(OverlayClient {
            shared,
            reader: Some(reader),
            version,
            backend,
        })
    }

    /// Negotiated protocol version.
    pub fn version(&self) -> u16 {
        self.version
    }

    /// The server's execution-backend name (from the Hello banner).
    pub fn backend(&self) -> &str {
        &self.backend
    }

    /// Resolve a kernel name to a remote session (the wire mirror of
    /// `OverlayService::kernel`): id and arities are fetched once,
    /// then calls move only the dense id.
    pub fn kernel(&self, name: &str) -> Result<RemoteKernel, ServiceError> {
        let reply = self.shared.call_roundtrip(name, |id| Frame::Resolve {
            id,
            name: name.to_string(),
        })?;
        match reply {
            ServerReply::Info {
                kernel,
                n_inputs,
                n_outputs,
            } => Ok(RemoteKernel {
                shared: Arc::clone(&self.shared),
                name: name.to_string(),
                kernel,
                n_inputs: n_inputs as usize,
                n_outputs: n_outputs as usize,
            }),
            _ => Err(bad_reply(name)),
        }
    }

    /// Fetch the server's `MetricsSnapshot` as parsed JSON (same
    /// field names as `tmfu serve --metrics-json`).
    pub fn metrics(&self) -> Result<Json, ServiceError> {
        match self.shared.call_roundtrip("", |id| Frame::GetMetrics { id })? {
            ServerReply::Metrics(text) => json::parse(&text).map_err(|e| ServiceError::Backend {
                backend: "wire".to_string(),
                message: format!("metrics json: {e}"),
            }),
            _ => Err(bad_reply("metrics")),
        }
    }

    fn require_v2(&self, what: &str) -> Result<(), ServiceError> {
        if self.version >= 2 {
            Ok(())
        } else {
            Err(ServiceError::Backend {
                backend: "wire".to_string(),
                message: format!(
                    "{what} requires protocol v2 (server negotiated v{})",
                    self.version
                ),
            })
        }
    }

    /// Probe the server's health (wire v2): draining flag plus the
    /// count of requests admitted but not yet settled.
    pub fn health(&self) -> Result<HealthReport, ServiceError> {
        self.require_v2("health probe")?;
        match self.shared.call_roundtrip("", |id| Frame::Health { id })? {
            ServerReply::Health { status, inflight } => Ok(HealthReport {
                draining: status == HEALTH_DRAINING,
                inflight,
            }),
            _ => Err(bad_reply("health")),
        }
    }

    /// Ask the server to drain (wire v2): stop accepting connections,
    /// finish in-flight work, then exit. Returns the acknowledgement
    /// report (always draining).
    pub fn drain(&self) -> Result<HealthReport, ServiceError> {
        self.require_v2("drain request")?;
        match self.shared.call_roundtrip("", |id| Frame::Drain { id })? {
            ServerReply::Health { status, inflight } => Ok(HealthReport {
                draining: status == HEALTH_DRAINING,
                inflight,
            }),
            _ => Err(bad_reply("drain")),
        }
    }

    /// Close the connection explicitly (also happens on drop).
    pub fn close(self) {
        let _ = self;
    }
}

/// A point-in-time backend health report (wire v2, from
/// [`OverlayClient::health`] / [`OverlayClient::drain`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HealthReport {
    /// The server is draining: finishing in-flight work, accepting
    /// nothing new.
    pub draining: bool,
    /// Requests admitted but not yet settled server-side.
    pub inflight: u32,
}

impl Drop for OverlayClient {
    fn drop(&mut self) {
        self.shared.control.shutdown_both();
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
    }
}

// ---------------------------------------------------------------------
// Remote sessions
// ---------------------------------------------------------------------

/// A remote kernel session: pre-resolved id + arities, `Clone + Send`,
/// mirroring [`KernelHandle`](crate::service::KernelHandle). Shapes
/// are **not** validated locally — the server answers the same typed
/// errors the in-process handle would raise.
#[derive(Clone)]
pub struct RemoteKernel {
    shared: Arc<ClientShared>,
    name: String,
    kernel: u32,
    n_inputs: usize,
    n_outputs: usize,
}

impl std::fmt::Debug for RemoteKernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RemoteKernel({} -> kernel#{})", self.name, self.kernel)
    }
}

impl RemoteKernel {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The server-side dense kernel id.
    pub fn id(&self) -> u32 {
        self.kernel
    }

    /// Input arity (words per request row).
    pub fn arity(&self) -> usize {
        self.n_inputs
    }

    /// Output arity (words per reply row).
    pub fn n_outputs(&self) -> usize {
        self.n_outputs
    }

    /// Non-blocking submit: the request is on the wire when this
    /// returns; the reply arrives on the [`RemotePending`].
    pub fn submit(&self, inputs: &[i32]) -> Result<RemotePending, ServiceError> {
        self.submit_with(inputs, None, None)
    }

    /// [`Self::submit`] carrying a deadline budget on the wire
    /// (wire v2): the server sheds the request at admission when the
    /// estimated queue wait already exceeds `budget`, and evicts the
    /// row unexecuted if the budget lapses while it is still queued —
    /// either way the caller gets the typed
    /// [`ServiceError::DeadlineExceeded`].
    pub fn submit_with_deadline(
        &self,
        inputs: &[i32],
        budget: Duration,
    ) -> Result<RemotePending, ServiceError> {
        self.require_v2("call deadline")?;
        self.submit_with(inputs, Some(budget_us(budget)), None)
    }

    /// [`Self::submit`] with a completion doorbell: `target` is rung
    /// when the reply settles (or the connection dies), so a reactor
    /// can multiplex many remote calls on one wake source.
    /// Crate-internal: the router's forwarding loop is the consumer
    /// (which is also why the deadline travels as raw microseconds —
    /// the router forwards the *remaining* budget from the frame).
    pub(crate) fn submit_tagged(
        &self,
        inputs: &[i32],
        deadline_us: Option<u64>,
        target: WakeTarget,
    ) -> Result<RemotePending, ServiceError> {
        self.submit_with(inputs, deadline_us, Some(target))
    }

    fn submit_with(
        &self,
        inputs: &[i32],
        deadline_us: Option<u64>,
        waker: Option<WakeTarget>,
    ) -> Result<RemotePending, ServiceError> {
        // A v1 peer cannot decode the deadline suffix: strip it
        // rather than breach the negotiated protocol (the public
        // deadline APIs refuse v1 outright before reaching here; the
        // router's forwarder relies on this downgrade and keeps
        // enforcing the budget with its own timer).
        let deadline_us = deadline_us.filter(|_| self.shared.version >= 2);
        let ticket = self.shared.send_with(&self.name, waker, |id| Frame::Call {
            id,
            kernel: self.kernel,
            inputs: inputs.to_vec(),
            deadline_us,
        })?;
        Ok(RemotePending {
            ticket,
            shared: Arc::clone(&self.shared),
            kernel: self.name.clone(),
            done: false,
        })
    }

    /// Blocking call: submit one row and wait for its reply.
    pub fn call(&self, inputs: &[i32]) -> Result<Vec<i32>, ServiceError> {
        self.submit(inputs)?.wait()
    }

    /// Deadline-bounded blocking call (wire v2): the budget rides the
    /// Call frame (server-side shed/expiry) *and* bounds the local
    /// wait. A local timeout cancels the request on the server —
    /// queued rows purge, the reply slot frees — so a missed deadline
    /// leaves nothing behind on either side.
    pub fn call_with_deadline(
        &self,
        inputs: &[i32],
        budget: Duration,
    ) -> Result<Vec<i32>, ServiceError> {
        let mut p = self.submit_with_deadline(inputs, budget)?;
        match p.wait_timeout(budget) {
            Err(e @ ServiceError::DeadlineExceeded { .. }) => {
                p.cancel();
                Err(e)
            }
            other => other,
        }
    }

    /// Non-blocking batch submit: rows travel as one contiguous
    /// buffer, are admitted atomically server-side, and come back in
    /// row order on the [`RemotePendingBatch`].
    pub fn submit_batch(&self, batch: &FlatBatch) -> Result<RemotePendingBatch, ServiceError> {
        self.submit_batch_with(batch, None, None)
    }

    /// Batch twin of [`Self::submit_with_deadline`] (wire v2): one
    /// budget covers the whole batch.
    pub fn submit_batch_with_deadline(
        &self,
        batch: &FlatBatch,
        budget: Duration,
    ) -> Result<RemotePendingBatch, ServiceError> {
        self.require_v2("call deadline")?;
        self.submit_batch_with(batch, Some(budget_us(budget)), None)
    }

    /// Batch twin of [`Self::submit_tagged`] (crate-internal, for the
    /// router).
    pub(crate) fn submit_batch_tagged(
        &self,
        batch: &FlatBatch,
        deadline_us: Option<u64>,
        target: WakeTarget,
    ) -> Result<RemotePendingBatch, ServiceError> {
        self.submit_batch_with(batch, deadline_us, Some(target))
    }

    fn submit_batch_with(
        &self,
        batch: &FlatBatch,
        deadline_us: Option<u64>,
        waker: Option<WakeTarget>,
    ) -> Result<RemotePendingBatch, ServiceError> {
        // Same v1 downgrade as `submit_with`.
        let deadline_us = deadline_us.filter(|_| self.shared.version >= 2);
        let ticket = self.shared.send_with(&self.name, waker, |id| Frame::CallBatch {
            id,
            kernel: self.kernel,
            batch: batch.clone(),
            deadline_us,
        })?;
        Ok(RemotePendingBatch {
            ticket,
            shared: Arc::clone(&self.shared),
            kernel: self.name.clone(),
            done: false,
        })
    }

    /// Blocking batch call: submit the batch and wait for its reply.
    pub fn call_batch(&self, batch: &FlatBatch) -> Result<FlatBatch, ServiceError> {
        self.submit_batch(batch)?.wait()
    }

    /// Deadline-bounded blocking batch call (wire v2): same contract
    /// as [`Self::call_with_deadline`], one budget for the batch.
    pub fn call_batch_with_deadline(
        &self,
        batch: &FlatBatch,
        budget: Duration,
    ) -> Result<FlatBatch, ServiceError> {
        let mut p = self.submit_batch_with_deadline(batch, budget)?;
        match p.wait_timeout(budget) {
            Err(e @ ServiceError::DeadlineExceeded { .. }) => {
                p.cancel();
                Err(e)
            }
            other => other,
        }
    }

    fn require_v2(&self, what: &str) -> Result<(), ServiceError> {
        if self.shared.version >= 2 {
            Ok(())
        } else {
            Err(ServiceError::Backend {
                backend: "wire".to_string(),
                message: format!(
                    "{what} requires protocol v2 (server negotiated v{})",
                    self.shared.version
                ),
            })
        }
    }
}

/// Clamp a deadline budget to the wire's u64 microseconds.
fn budget_us(budget: Duration) -> u64 {
    // cast-ok: saturating — a budget past u64::MAX microseconds
    // (584 thousand years) clamps to "effectively unbounded".
    u64::try_from(budget.as_micros()).unwrap_or(u64::MAX)
}

// ---------------------------------------------------------------------
// Pending replies
// ---------------------------------------------------------------------

/// A future-like remote reply, mirroring
/// [`Pending`](crate::service::Pending): poll it, block on it, or
/// bound the wait. `Send`, so replies can be collected on another
/// thread. Like its in-process twin, it is a thin recycled-slot
/// ticket, not a channel — dropping it without collecting recycles
/// the slot automatically.
pub struct RemotePending {
    ticket: ReplyTicket,
    shared: Arc<ClientShared>,
    kernel: String,
    done: bool,
}

impl std::fmt::Debug for RemotePending {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RemotePending({})", self.kernel)
    }
}

impl RemotePending {
    /// The kernel this reply belongs to.
    pub fn kernel_name(&self) -> &str {
        &self.kernel
    }

    fn one_row(&self, reply: ReplyResult) -> Result<Vec<i32>, ServiceError> {
        match reply? {
            ServerReply::Rows(batch) if batch.n_rows() == 1 => Ok(batch.row(0).to_vec()),
            _ => Err(bad_reply(&self.kernel)),
        }
    }

    /// Non-blocking check: `Some(result)` once the reply has arrived.
    pub fn poll(&mut self) -> Option<Result<Vec<i32>, ServiceError>> {
        if self.done {
            return Some(Err(self.shared.drain_error(&self.kernel)));
        }
        let reply = self.ticket.try_take(&self.shared, &self.kernel)?;
        self.done = true;
        Some(self.one_row(reply))
    }

    /// Block until the reply arrives.
    pub fn wait(mut self) -> Result<Vec<i32>, ServiceError> {
        if self.done {
            return Err(self.shared.drain_error(&self.kernel));
        }
        let reply = self
            .ticket
            .wait_take(&self.shared, None, &self.kernel)
            .expect("unbounded wait cannot time out");
        self.done = true;
        self.one_row(reply)
    }

    /// Block at most `timeout`; [`ServiceError::DeadlineExceeded`] if
    /// the reply has not arrived by then. The request stays in flight —
    /// poll or wait again later (same contract as the in-process
    /// `Pending`).
    pub fn wait_timeout(&mut self, timeout: Duration) -> Result<Vec<i32>, ServiceError> {
        if self.done {
            return Err(self.shared.drain_error(&self.kernel));
        }
        let deadline = Instant::now().checked_add(timeout);
        match self.ticket.wait_take(&self.shared, deadline, &self.kernel) {
            Some(reply) => {
                self.done = true;
                self.one_row(reply)
            }
            None => Err(ServiceError::DeadlineExceeded {
                kernel: self.kernel.clone(),
            }),
        }
    }

    /// Block until `deadline` at the latest (expressed through
    /// [`Self::wait_timeout`], the one timing implementation).
    pub fn wait_deadline(&mut self, deadline: Instant) -> Result<Vec<i32>, ServiceError> {
        self.wait_timeout(deadline.saturating_duration_since(Instant::now()))
    }

    /// Give up on this request. On a v2 connection a `Cancel` frame
    /// tells the server to purge the queued rows and free its reply
    /// slot (fire-and-forget — an already-completed id is a no-op
    /// there), and the local slot recycles immediately. On v1 the
    /// request is merely abandoned locally. Idempotent; also what
    /// dropping an uncollected pending does.
    pub fn cancel(&mut self) {
        if self.done {
            return;
        }
        self.done = true;
        if self.shared.version >= 2 {
            self.shared.send_cancel(self.ticket.request_id());
            self.ticket.discard(&self.shared);
        } else {
            self.ticket.abandon(&self.shared);
        }
    }
}

impl Drop for RemotePending {
    fn drop(&mut self) {
        // Dropping without collecting used to leak the server-side
        // slab slot until the reply happened to arrive; now the drop
        // cancels, so the server frees the slot promptly.
        self.cancel();
    }
}

/// The batch twin of [`RemotePending`]: same slot-ticket mechanics,
/// yielding the whole reply [`FlatBatch`] in row order.
pub struct RemotePendingBatch {
    ticket: ReplyTicket,
    shared: Arc<ClientShared>,
    kernel: String,
    done: bool,
}

impl std::fmt::Debug for RemotePendingBatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RemotePendingBatch({})", self.kernel)
    }
}

impl RemotePendingBatch {
    /// The kernel this reply belongs to.
    pub fn kernel_name(&self) -> &str {
        &self.kernel
    }

    fn rows(&self, reply: ReplyResult) -> Result<FlatBatch, ServiceError> {
        match reply? {
            ServerReply::Rows(batch) => Ok(batch),
            _ => Err(bad_reply(&self.kernel)),
        }
    }

    /// Non-blocking check: `Some(result)` once the reply has arrived.
    pub fn poll(&mut self) -> Option<Result<FlatBatch, ServiceError>> {
        if self.done {
            return Some(Err(self.shared.drain_error(&self.kernel)));
        }
        let reply = self.ticket.try_take(&self.shared, &self.kernel)?;
        self.done = true;
        Some(self.rows(reply))
    }

    /// Block until the reply arrives.
    pub fn wait(mut self) -> Result<FlatBatch, ServiceError> {
        if self.done {
            return Err(self.shared.drain_error(&self.kernel));
        }
        let reply = self
            .ticket
            .wait_take(&self.shared, None, &self.kernel)
            .expect("unbounded wait cannot time out");
        self.done = true;
        self.rows(reply)
    }

    /// Block at most `timeout`; [`ServiceError::DeadlineExceeded`] if
    /// the reply has not arrived by then (request stays in flight).
    pub fn wait_timeout(&mut self, timeout: Duration) -> Result<FlatBatch, ServiceError> {
        if self.done {
            return Err(self.shared.drain_error(&self.kernel));
        }
        let deadline = Instant::now().checked_add(timeout);
        match self.ticket.wait_take(&self.shared, deadline, &self.kernel) {
            Some(reply) => {
                self.done = true;
                self.rows(reply)
            }
            None => Err(ServiceError::DeadlineExceeded {
                kernel: self.kernel.clone(),
            }),
        }
    }

    /// Give up on this batch (same contract as
    /// [`RemotePending::cancel`]): v2 sends `Cancel` — queued rows
    /// purge server-side, both reply slots free — v1 abandons
    /// locally. Idempotent; also the drop path.
    pub fn cancel(&mut self) {
        if self.done {
            return;
        }
        self.done = true;
        if self.shared.version >= 2 {
            self.shared.send_cancel(self.ticket.request_id());
            self.ticket.discard(&self.shared);
        } else {
            self.ticket.abandon(&self.shared);
        }
    }
}

impl Drop for RemotePendingBatch {
    fn drop(&mut self) {
        self.cancel();
    }
}

// ---------------------------------------------------------------------
// Retry backoff
// ---------------------------------------------------------------------

/// Capped exponential backoff with deterministic jitter, shared by the
/// router's replica-reconnect loop and `tmfu call --retries`. Delays
/// double from `base` up to `cap`; each is then scaled by a uniform
/// factor in [0.5, 1.0] so a fleet of retriers spreads out instead of
/// thundering back in lockstep.
#[derive(Debug)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    attempt: u32,
    rng: Rng,
}

impl Backoff {
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Backoff {
        Backoff {
            base,
            cap,
            attempt: 0,
            rng: Rng::new(seed),
        }
    }

    /// The next delay to sleep before retrying (advances the
    /// schedule).
    pub fn next_delay(&mut self) -> Duration {
        // 2^16 × base already dwarfs any sane cap; clamping the
        // exponent keeps the shift defined for unbounded retry loops.
        let exp = self.attempt.min(16);
        self.attempt = self.attempt.saturating_add(1);
        let raw = self.base.saturating_mul(1u32 << exp).min(self.cap);
        raw.mul_f64(0.5 + 0.5 * self.rng.f64())
    }

    /// Success: the next failure restarts the schedule from `base`.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_envelope_and_reset() {
        let mut b = Backoff::new(Duration::from_millis(10), Duration::from_millis(200), 42);
        let first = b.next_delay();
        assert!(first >= Duration::from_millis(5), "jitter floor is 0.5×");
        assert!(first <= Duration::from_millis(10));
        let mut last = Duration::ZERO;
        for _ in 0..10 {
            last = b.next_delay();
            assert!(last <= Duration::from_millis(200), "cap respected");
        }
        // Ten doublings from 10ms is far past the cap: the schedule
        // sits in the capped region, jittered no lower than half.
        assert!(last >= Duration::from_millis(100));
        b.reset();
        let again = b.next_delay();
        assert!(again <= Duration::from_millis(10), "reset restarts at base");
    }
}
