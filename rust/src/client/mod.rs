//! Thin wire client: call a `tmfu listen` server from another process.
//!
//! [`OverlayClient::connect`] dials a server (TCP `host:port` or
//! `unix:<path>`), performs the Hello version handshake, and starts
//! one reader thread that demultiplexes reply frames by request id —
//! so a single connection carries any number of in-flight calls from
//! any number of threads. [`OverlayClient::kernel`] resolves a kernel
//! name once into a [`RemoteKernel`] session that mirrors
//! [`KernelHandle`](crate::service::KernelHandle) method for method:
//! [`RemoteKernel::call`], [`RemoteKernel::call_batch`], and
//! non-blocking [`RemoteKernel::submit`] returning a [`RemotePending`]
//! with the same `poll` / `wait` / `wait_timeout` / `wait_deadline`
//! surface as the in-process `Pending`.
//!
//! Demultiplexing mirrors the server's completion slab
//! (DESIGN.md §10): each in-flight request is a recycled **reply
//! slot** with its own generation counter, and the request id on the
//! wire *encodes* the slot index and generation
//! (`id = generation << 32 | slot`). The reader resolves a reply to
//! its slot with one index — no hash map, no per-request channel
//! allocation — and a stale id (a slot already recycled) can never
//! complete the wrong request. Each slot carries its own condvar, so
//! completing one request wakes exactly its waiter, not the herd.
//!
//! Every failure is the same typed [`ServiceError`] a linked-in caller
//! would see: service-side errors round-trip the wire bit-exactly
//! (DESIGN.md §9), transport failures surface as
//! `Backend { backend: "wire", .. }`, and a dead connection answers
//! [`ServiceError::Disconnected`]. The client deliberately does **not**
//! pre-validate shapes — the server is authoritative, which is what
//! lets a test observe `ShapeMismatch` or `EmptyBatch` arrive over the
//! socket rather than be short-circuited locally.
//!
//! ```no_run
//! use tmfu_overlay::client::OverlayClient;
//!
//! fn main() -> Result<(), Box<dyn std::error::Error>> {
//!     let client = OverlayClient::connect("127.0.0.1:7700")?;
//!     let gradient = client.kernel("gradient")?;
//!     assert_eq!(gradient.call(&[3, 5, 2, 7, 1])?, vec![36]);
//!     println!("{}", client.metrics()?.to_string_pretty());
//!     Ok(())
//! }
//! ```
//!
//! Lifetime: sessions hold the connection by `Arc`, but dropping the
//! [`OverlayClient`] closes the socket — outstanding [`RemoteKernel`]s
//! and [`RemotePending`]s then answer `Disconnected` (a network
//! session ends with its connection, unlike in-process handles, which
//! outlive the service value).

use crate::exec::FlatBatch;
use crate::service::ServiceError;
use crate::util::json::{self, Json};
use crate::wire::{
    read_frame, write_frame, Frame, ListenAddr, WireStream, WIRE_VERSION_MAX, WIRE_VERSION_MIN,
};
use std::io::{BufReader, BufWriter, Write};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// One decoded server reply, routed to the waiting request.
enum ServerReply {
    Info {
        kernel: u32,
        n_inputs: u16,
        n_outputs: u16,
    },
    Rows(FlatBatch),
    Metrics(String),
}

type ReplyResult = Result<ServerReply, ServiceError>;

// ---------------------------------------------------------------------
// Reply-slot demux
// ---------------------------------------------------------------------

/// Where one reply slot is in its lifecycle.
enum Phase {
    /// On the free list.
    Free,
    /// A request is in flight under this slot's current generation.
    Waiting,
    /// The reply arrived and awaits collection.
    Done(ReplyResult),
    /// The pending handle was dropped; recycle on completion.
    Abandoned,
    /// The connection died with this request in flight.
    Gone,
}

struct ReplyState {
    generation: u32,
    phase: Phase,
}

/// One recycled reply slot: its own mutex + condvar, so a completion
/// wakes exactly the thread waiting on *this* request.
struct ReplySlot {
    m: Mutex<ReplyState>,
    cv: Condvar,
}

impl ReplySlot {
    fn new() -> ReplySlot {
        ReplySlot {
            m: Mutex::new(ReplyState {
                // Start at 1 so a live request id is never 0 — id 0 is
                // the handshake convention and doubles as the server's
                // "no correlatable request" sentinel.
                generation: 1,
                phase: Phase::Free,
            }),
            cv: Condvar::new(),
        }
    }
}

struct DemuxSlots {
    slots: Vec<Arc<ReplySlot>>,
    free: Vec<u32>,
    /// Set (under this lock) when the connection dies, so no slot can
    /// be reserved after the drain sweep — a late reservation would
    /// wait forever.
    closed: bool,
}

/// The client-side completion structure: slot reservation/release plus
/// the id ↔ slot mapping (pure arithmetic — the id carries the slot).
struct Demux {
    m: Mutex<DemuxSlots>,
}

/// A reserved slot: what `send` hands back, and what [`RemotePending`]
/// wraps. The generation pins one life of the slot.
struct ReplyTicket {
    slot: Arc<ReplySlot>,
    idx: u32,
    generation: u32,
}

impl ReplyTicket {
    fn request_id(&self) -> u64 {
        (u64::from(self.generation) << 32) | u64::from(self.idx)
    }
}

/// Outcome of inspecting a slot under its lock.
enum TakeState {
    NotReady,
    Ready(ReplyResult),
    /// Connection died mid-flight (slot still needs releasing).
    Gone,
    /// Generation mismatch: the slot was already recycled. Nothing to
    /// release.
    Stale,
}

impl Demux {
    fn new() -> Demux {
        Demux {
            m: Mutex::new(DemuxSlots {
                slots: Vec::new(),
                free: Vec::new(),
                closed: false,
            }),
        }
    }

    /// Reserve a slot for one request. `None` once the connection is
    /// closed. The `closed` check *and* the Waiting mark share the
    /// demux critical section with [`Self::drain`]'s
    /// closed-store-and-sweep, so a reservation is either refused or
    /// visible to the sweep — it can never slip in behind it and wait
    /// forever. (Nesting the slot lock inside the demux lock here is
    /// the one place the two are held together; every other path
    /// takes them strictly one at a time, so no cycle exists.)
    fn reserve(&self) -> Option<ReplyTicket> {
        let mut d = self.m.lock().unwrap();
        if d.closed {
            return None;
        }
        let idx = match d.free.pop() {
            Some(i) => i,
            None => {
                d.slots.push(Arc::new(ReplySlot::new()));
                (d.slots.len() - 1) as u32
            }
        };
        let slot = Arc::clone(&d.slots[idx as usize]);
        let generation = {
            let mut s = slot.m.lock().unwrap();
            debug_assert!(matches!(s.phase, Phase::Free), "reserved a non-free slot");
            s.phase = Phase::Waiting;
            s.generation
        };
        drop(d);
        Some(ReplyTicket {
            slot,
            idx,
            generation,
        })
    }

    /// Refuse all future reservations. Used when a partial frame may
    /// be stuck on the wire (the stream is no longer frame-aligned);
    /// in-flight slots drain normally once the reader observes the
    /// connection die.
    fn close(&self) {
        self.m.lock().unwrap().closed = true;
    }

    /// Return a slot to the free list (generation bumped first, so
    /// every outstanding id for the old life goes stale).
    fn release(&self, slot: &Arc<ReplySlot>, idx: u32) {
        {
            let mut s = slot.m.lock().unwrap();
            s.generation = s.generation.wrapping_add(1);
            s.phase = Phase::Free;
        }
        self.m.lock().unwrap().free.push(idx);
    }

    /// Reader-side: complete the request a reply frame names. `false`
    /// when no live request matches (stale generation, unknown slot,
    /// or the id-0 sentinel) — the caller treats that as a
    /// connection-level announcement.
    fn complete(&self, id: u64, result: ReplyResult) -> bool {
        let idx = (id & 0xffff_ffff) as usize;
        let generation = (id >> 32) as u32;
        let slot = {
            let d = self.m.lock().unwrap();
            match d.slots.get(idx) {
                Some(s) => Arc::clone(s),
                None => return false,
            }
        };
        let mut s = slot.m.lock().unwrap();
        if s.generation != generation {
            return false;
        }
        if matches!(s.phase, Phase::Abandoned) {
            // Nobody will collect: recycle now.
            drop(s);
            self.release(&slot, idx as u32);
            return true;
        }
        if matches!(s.phase, Phase::Waiting) {
            s.phase = Phase::Done(result);
            drop(s);
            slot.cv.notify_all();
            return true;
        }
        false
    }

    /// Reader-side: the connection is over. Mark every in-flight slot
    /// `Gone` (waiters wake and construct their own typed error) and
    /// refuse all future reservations.
    fn drain(&self) {
        let slots: Vec<(Arc<ReplySlot>, u32)> = {
            let mut d = self.m.lock().unwrap();
            d.closed = true;
            d.slots
                .iter()
                .enumerate()
                .map(|(i, s)| (Arc::clone(s), i as u32))
                .collect()
        };
        for (slot, idx) in slots {
            let mut s = slot.m.lock().unwrap();
            if matches!(s.phase, Phase::Waiting) {
                s.phase = Phase::Gone;
                drop(s);
                slot.cv.notify_all();
            } else if matches!(s.phase, Phase::Abandoned) {
                drop(s);
                self.release(&slot, idx);
            }
        }
    }
}

impl ReplyTicket {
    /// Inspect the slot once (under its lock).
    fn take_state(&self, s: &mut ReplyState) -> TakeState {
        if s.generation != self.generation {
            return TakeState::Stale;
        }
        if matches!(s.phase, Phase::Done(_)) {
            let Phase::Done(r) = std::mem::replace(&mut s.phase, Phase::Waiting) else {
                unreachable!("checked Done above");
            };
            return TakeState::Ready(r);
        }
        if matches!(s.phase, Phase::Gone) {
            return TakeState::Gone;
        }
        TakeState::NotReady
    }

    /// Blocking (optionally deadline-bounded) take. `None` = deadline
    /// passed, request still in flight. On `Some`, the slot has been
    /// released.
    fn wait_take(
        &self,
        shared: &ClientShared,
        deadline: Option<Instant>,
        kernel: &str,
    ) -> Option<ReplyResult> {
        let mut s = self.slot.m.lock().unwrap();
        loop {
            match self.take_state(&mut s) {
                TakeState::Ready(r) => {
                    drop(s);
                    shared.demux.release(&self.slot, self.idx);
                    return Some(r);
                }
                TakeState::Gone => {
                    drop(s);
                    shared.demux.release(&self.slot, self.idx);
                    return Some(Err(shared.drain_error(kernel)));
                }
                TakeState::Stale => return Some(Err(shared.drain_error(kernel))),
                TakeState::NotReady => {}
            }
            match deadline {
                None => s = self.slot.cv.wait(s).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return None;
                    }
                    s = self.slot.cv.wait_timeout(s, d - now).unwrap().0;
                }
            }
        }
    }

    /// Non-blocking take. Same release semantics as [`Self::wait_take`].
    fn try_take(&self, shared: &ClientShared, kernel: &str) -> Option<ReplyResult> {
        let mut s = self.slot.m.lock().unwrap();
        match self.take_state(&mut s) {
            TakeState::Ready(r) => {
                drop(s);
                shared.demux.release(&self.slot, self.idx);
                Some(r)
            }
            TakeState::Gone => {
                drop(s);
                shared.demux.release(&self.slot, self.idx);
                Some(Err(shared.drain_error(kernel)))
            }
            TakeState::Stale => Some(Err(shared.drain_error(kernel))),
            TakeState::NotReady => None,
        }
    }

    /// The pending handle is going away without collecting.
    fn abandon(&self, shared: &ClientShared) {
        let mut s = self.slot.m.lock().unwrap();
        if s.generation != self.generation {
            return;
        }
        if matches!(s.phase, Phase::Waiting) {
            // The reader (or the drain) recycles it on completion.
            s.phase = Phase::Abandoned;
            return;
        }
        if matches!(s.phase, Phase::Done(_) | Phase::Gone) {
            drop(s);
            shared.demux.release(&self.slot, self.idx);
        }
    }

    /// A send failed before anything reached the socket: cancel the
    /// reservation outright.
    fn cancel(&self, shared: &ClientShared) {
        let mut s = self.slot.m.lock().unwrap();
        if s.generation != self.generation || !matches!(s.phase, Phase::Waiting) {
            return;
        }
        drop(s);
        shared.demux.release(&self.slot, self.idx);
    }
}

// ---------------------------------------------------------------------
// Connection state
// ---------------------------------------------------------------------

/// Connection state shared by the client value, every session and the
/// reader thread.
struct ClientShared {
    writer: Mutex<BufWriter<WireStream>>,
    control: WireStream,
    demux: Demux,
    /// A connection-fatal error frame (e.g. `Malformed` with no
    /// correlatable id) reported just before the server hung up;
    /// used to explain the drain to every waiter.
    fatal: Mutex<Option<ServiceError>>,
}

impl ClientShared {
    fn disconnected(&self, kernel: &str) -> ServiceError {
        ServiceError::Disconnected {
            kernel: kernel.to_string(),
        }
    }

    /// The error to hand out once the connection is gone.
    fn drain_error(&self, kernel: &str) -> ServiceError {
        self.fatal
            .lock()
            .unwrap()
            .clone()
            .unwrap_or_else(|| self.disconnected(kernel))
    }

    /// Reserve a reply slot, then write the frame built from its
    /// encoded request id. The reservation is visible to the reader
    /// before the first byte leaves, so a fast reply always finds its
    /// slot.
    fn send(
        &self,
        kernel: &str,
        build: impl FnOnce(u64) -> Frame,
    ) -> Result<ReplyTicket, ServiceError> {
        let Some(ticket) = self.demux.reserve() else {
            return Err(self.drain_error(kernel));
        };
        let frame = build(ticket.request_id());
        let wrote = {
            let mut w = self.writer.lock().unwrap();
            write_frame(&mut *w, &frame).and_then(|()| w.flush())
        };
        if let Err(e) = wrote {
            // `InvalidInput` is the pre-write encode/size failure
            // (oversized arity or batch): nothing reached the socket,
            // the stream is still frame-aligned, and only this one
            // request fails. Anything else is a real I/O failure that
            // may have left a partial frame on the wire — the stream
            // is no longer frame-aligned, so refuse all future sends
            // and kick the reader so in-flight work drains promptly.
            ticket.cancel(self);
            if e.kind() != std::io::ErrorKind::InvalidInput {
                self.demux.close();
                self.control.shutdown_both();
            }
            return Err(ServiceError::Backend {
                backend: "wire".to_string(),
                message: format!("send failed: {e}"),
            });
        }
        Ok(ticket)
    }

    /// Send + block for the one reply a request expects.
    fn call_roundtrip(
        &self,
        kernel: &str,
        build: impl FnOnce(u64) -> Frame,
    ) -> Result<ServerReply, ServiceError> {
        let ticket = self.send(kernel, build)?;
        ticket
            .wait_take(self, None, kernel)
            .expect("unbounded wait cannot time out")
    }
}

fn bad_reply(kernel: &str) -> ServiceError {
    ServiceError::Backend {
        backend: "wire".to_string(),
        message: format!("unexpected reply kind for kernel '{kernel}'"),
    }
}

/// Takes the handshake-time `BufReader` whole — its buffer may already
/// hold bytes past HelloOk, which a raw-stream restart would lose.
fn reader_loop(shared: Arc<ClientShared>, mut r: BufReader<WireStream>) {
    loop {
        let frame = match read_frame(&mut r) {
            Ok(Some(f)) => f,
            Ok(None) => break,
            Err(e) => {
                *shared.fatal.lock().unwrap() = Some(ServiceError::Backend {
                    backend: "wire".to_string(),
                    message: format!("receive failed: {e}"),
                });
                break;
            }
        };
        let id = frame.request_id();
        match frame {
            Frame::KernelInfo {
                kernel,
                n_inputs,
                n_outputs,
                ..
            } => {
                shared.demux.complete(
                    id,
                    Ok(ServerReply::Info {
                        kernel,
                        n_inputs,
                        n_outputs,
                    }),
                );
            }
            Frame::Reply { batch, .. } => {
                shared.demux.complete(id, Ok(ServerReply::Rows(batch)));
            }
            Frame::Metrics { json, .. } => {
                shared.demux.complete(id, Ok(ServerReply::Metrics(json)));
            }
            Frame::Error { err, .. } => {
                let e = err.into_service_error();
                if !shared.demux.complete(id, Err(e.clone())) {
                    // No waiting request (id 0 / already gone): this is
                    // the server explaining an imminent hang-up.
                    *shared.fatal.lock().unwrap() = Some(e);
                }
            }
            // A server never sends client-side opcodes mid-stream; an
            // unexpected one means the peer is not speaking the
            // protocol. Stop reading rather than guess.
            _ => {
                *shared.fatal.lock().unwrap() = Some(ServiceError::Backend {
                    backend: "wire".to_string(),
                    message: "server sent a client-side frame".to_string(),
                });
                break;
            }
        }
    }
    shared.demux.drain();
}

// ---------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------

/// A connection to a `tmfu listen` server. One value per connection;
/// cheap sessions come from [`OverlayClient::kernel`]. Dropping the
/// client closes the socket and fails outstanding work with
/// [`ServiceError::Disconnected`].
pub struct OverlayClient {
    shared: Arc<ClientShared>,
    reader: Option<thread::JoinHandle<()>>,
    version: u16,
    backend: String,
}

impl OverlayClient {
    /// Dial `addr` (`host:port` or `unix:<path>`), shake hands, and
    /// start the reply-demultiplexing reader.
    pub fn connect(addr: &str) -> Result<OverlayClient, ServiceError> {
        let addr = ListenAddr::parse(addr);
        let stream = WireStream::connect(&addr).map_err(|e| ServiceError::Backend {
            backend: "wire".to_string(),
            message: format!("connect {addr}: {e}"),
        })?;
        let wire_err = |what: &str, e: std::io::Error| ServiceError::Backend {
            backend: "wire".to_string(),
            message: format!("{what}: {e}"),
        };
        let read_half = stream.try_clone().map_err(|e| wire_err("clone stream", e))?;
        let control = stream.try_clone().map_err(|e| wire_err("clone stream", e))?;
        // Synchronous handshake before any concurrency exists.
        let mut writer = BufWriter::new(stream);
        write_frame(
            &mut writer,
            &Frame::Hello {
                id: 0,
                min: WIRE_VERSION_MIN,
                max: WIRE_VERSION_MAX,
            },
        )
        .and_then(|()| writer.flush())
        .map_err(|e| wire_err("send hello", e))?;
        let mut reader = BufReader::new(read_half);
        let (version, backend) = match read_frame(&mut reader) {
            Ok(Some(Frame::HelloOk {
                version, backend, ..
            })) => (version, backend),
            Ok(Some(Frame::Error { err, .. })) => return Err(err.into_service_error()),
            Ok(Some(_)) => {
                return Err(wire_err(
                    "handshake",
                    std::io::Error::new(std::io::ErrorKind::InvalidData, "unexpected frame"),
                ))
            }
            Ok(None) => {
                return Err(wire_err(
                    "handshake",
                    std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "server hung up"),
                ))
            }
            Err(e) => return Err(wire_err("handshake", e)),
        };
        let shared = Arc::new(ClientShared {
            writer: Mutex::new(writer),
            control,
            demux: Demux::new(),
            fatal: Mutex::new(None),
        });
        let reader_shared = Arc::clone(&shared);
        let reader = thread::Builder::new()
            .name("wire-client-read".to_string())
            .spawn(move || reader_loop(reader_shared, reader))
            .map_err(|e| wire_err("spawn reader", e))?;
        Ok(OverlayClient {
            shared,
            reader: Some(reader),
            version,
            backend,
        })
    }

    /// Negotiated protocol version.
    pub fn version(&self) -> u16 {
        self.version
    }

    /// The server's execution-backend name (from the Hello banner).
    pub fn backend(&self) -> &str {
        &self.backend
    }

    /// Resolve a kernel name to a remote session (the wire mirror of
    /// `OverlayService::kernel`): id and arities are fetched once,
    /// then calls move only the dense id.
    pub fn kernel(&self, name: &str) -> Result<RemoteKernel, ServiceError> {
        let reply = self.shared.call_roundtrip(name, |id| Frame::Resolve {
            id,
            name: name.to_string(),
        })?;
        match reply {
            ServerReply::Info {
                kernel,
                n_inputs,
                n_outputs,
            } => Ok(RemoteKernel {
                shared: Arc::clone(&self.shared),
                name: name.to_string(),
                kernel,
                n_inputs: n_inputs as usize,
                n_outputs: n_outputs as usize,
            }),
            _ => Err(bad_reply(name)),
        }
    }

    /// Fetch the server's `MetricsSnapshot` as parsed JSON (same
    /// field names as `tmfu serve --metrics-json`).
    pub fn metrics(&self) -> Result<Json, ServiceError> {
        match self.shared.call_roundtrip("", |id| Frame::GetMetrics { id })? {
            ServerReply::Metrics(text) => json::parse(&text).map_err(|e| ServiceError::Backend {
                backend: "wire".to_string(),
                message: format!("metrics json: {e}"),
            }),
            _ => Err(bad_reply("metrics")),
        }
    }

    /// Close the connection explicitly (also happens on drop).
    pub fn close(self) {
        let _ = self;
    }
}

impl Drop for OverlayClient {
    fn drop(&mut self) {
        self.shared.control.shutdown_both();
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
    }
}

// ---------------------------------------------------------------------
// Remote sessions
// ---------------------------------------------------------------------

/// A remote kernel session: pre-resolved id + arities, `Clone + Send`,
/// mirroring [`KernelHandle`](crate::service::KernelHandle). Shapes
/// are **not** validated locally — the server answers the same typed
/// errors the in-process handle would raise.
#[derive(Clone)]
pub struct RemoteKernel {
    shared: Arc<ClientShared>,
    name: String,
    kernel: u32,
    n_inputs: usize,
    n_outputs: usize,
}

impl std::fmt::Debug for RemoteKernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RemoteKernel({} -> kernel#{})", self.name, self.kernel)
    }
}

impl RemoteKernel {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The server-side dense kernel id.
    pub fn id(&self) -> u32 {
        self.kernel
    }

    /// Input arity (words per request row).
    pub fn arity(&self) -> usize {
        self.n_inputs
    }

    /// Output arity (words per reply row).
    pub fn n_outputs(&self) -> usize {
        self.n_outputs
    }

    /// Non-blocking submit: the request is on the wire when this
    /// returns; the reply arrives on the [`RemotePending`].
    pub fn submit(&self, inputs: &[i32]) -> Result<RemotePending, ServiceError> {
        let ticket = self.shared.send(&self.name, |id| Frame::Call {
            id,
            kernel: self.kernel,
            inputs: inputs.to_vec(),
        })?;
        Ok(RemotePending {
            ticket,
            shared: Arc::clone(&self.shared),
            kernel: self.name.clone(),
            done: false,
        })
    }

    /// Blocking call: submit one row and wait for its reply.
    pub fn call(&self, inputs: &[i32]) -> Result<Vec<i32>, ServiceError> {
        self.submit(inputs)?.wait()
    }

    /// Blocking batch call: rows travel as one contiguous buffer, are
    /// admitted atomically server-side, and come back in row order.
    pub fn call_batch(&self, batch: &FlatBatch) -> Result<FlatBatch, ServiceError> {
        let reply = self.shared.call_roundtrip(&self.name, |id| Frame::CallBatch {
            id,
            kernel: self.kernel,
            batch: batch.clone(),
        })?;
        match reply {
            ServerReply::Rows(out) => Ok(out),
            _ => Err(bad_reply(&self.name)),
        }
    }
}

// ---------------------------------------------------------------------
// Pending replies
// ---------------------------------------------------------------------

/// A future-like remote reply, mirroring
/// [`Pending`](crate::service::Pending): poll it, block on it, or
/// bound the wait. `Send`, so replies can be collected on another
/// thread. Like its in-process twin, it is a thin recycled-slot
/// ticket, not a channel — dropping it without collecting recycles
/// the slot automatically.
pub struct RemotePending {
    ticket: ReplyTicket,
    shared: Arc<ClientShared>,
    kernel: String,
    done: bool,
}

impl std::fmt::Debug for RemotePending {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RemotePending({})", self.kernel)
    }
}

impl RemotePending {
    /// The kernel this reply belongs to.
    pub fn kernel_name(&self) -> &str {
        &self.kernel
    }

    fn one_row(&self, reply: ReplyResult) -> Result<Vec<i32>, ServiceError> {
        match reply? {
            ServerReply::Rows(batch) if batch.n_rows() == 1 => Ok(batch.row(0).to_vec()),
            _ => Err(bad_reply(&self.kernel)),
        }
    }

    /// Non-blocking check: `Some(result)` once the reply has arrived.
    pub fn poll(&mut self) -> Option<Result<Vec<i32>, ServiceError>> {
        if self.done {
            return Some(Err(self.shared.drain_error(&self.kernel)));
        }
        let reply = self.ticket.try_take(&self.shared, &self.kernel)?;
        self.done = true;
        Some(self.one_row(reply))
    }

    /// Block until the reply arrives.
    pub fn wait(mut self) -> Result<Vec<i32>, ServiceError> {
        if self.done {
            return Err(self.shared.drain_error(&self.kernel));
        }
        let reply = self
            .ticket
            .wait_take(&self.shared, None, &self.kernel)
            .expect("unbounded wait cannot time out");
        self.done = true;
        self.one_row(reply)
    }

    /// Block at most `timeout`; [`ServiceError::DeadlineExceeded`] if
    /// the reply has not arrived by then. The request stays in flight —
    /// poll or wait again later (same contract as the in-process
    /// `Pending`).
    pub fn wait_timeout(&mut self, timeout: Duration) -> Result<Vec<i32>, ServiceError> {
        if self.done {
            return Err(self.shared.drain_error(&self.kernel));
        }
        let deadline = Instant::now().checked_add(timeout);
        match self.ticket.wait_take(&self.shared, deadline, &self.kernel) {
            Some(reply) => {
                self.done = true;
                self.one_row(reply)
            }
            None => Err(ServiceError::DeadlineExceeded {
                kernel: self.kernel.clone(),
            }),
        }
    }

    /// Block until `deadline` at the latest (expressed through
    /// [`Self::wait_timeout`], the one timing implementation).
    pub fn wait_deadline(&mut self, deadline: Instant) -> Result<Vec<i32>, ServiceError> {
        self.wait_timeout(deadline.saturating_duration_since(Instant::now()))
    }
}

impl Drop for RemotePending {
    fn drop(&mut self) {
        if !self.done {
            self.ticket.abandon(&self.shared);
        }
    }
}
