//! `tmfu` — CLI for the TMFU overlay reproduction.
//!
//! Subcommands cover the paper's complete flow: kernel compilation
//! (`compile`, `export-dfg`), scheduling and inspection (`schedule`,
//! `table1`, `dot`), cycle-accurate simulation (`simulate`), reports
//! (`table2`, `table3`, `fig5`, `fig6`, `ctx-switch`, `resources`),
//! and the serving runtime (`serve --backend {ref,sim,pjrt,turbo}`;
//! only the pjrt backend requires `make artifacts`). `serve` drives
//! the typed service API ([`tmfu_overlay::service::OverlayService`] +
//! `KernelHandle` sessions) with a mixed-kernel oracle-checked
//! workload, and can write its typed metrics snapshot as JSON
//! (`--metrics-json`) for CI and tooling to assert on.
//!
//! Network serving: `listen` exposes the same service over the
//! length-prefixed wire protocol (DESIGN.md §9) on TCP and/or a Unix
//! socket, and `call` is the matching client (one-shot or `--count`
//! bursts, with `--retries`/`--timeout-ms` reusing the router's retry
//! policy) — together they are the two-terminal walkthrough in the
//! README. `router` fronts several `listen` backends with health
//! checks, transparent retry, and graceful drain (DESIGN.md §11).
//! `listen` and `router` both drain gracefully on SIGTERM: finish
//! in-flight replies, then exit 0.

use std::process::ExitCode;
use std::sync::Arc;
use std::time::{Duration, Instant};
use tmfu_overlay::client::{Backoff, ClientBuilder, OverlayClient};
use tmfu_overlay::exec::BackendKind;
use tmfu_overlay::router::{retryable, Router, RouterConfig};
use tmfu_overlay::service::{OverlayService, ServiceError};
use tmfu_overlay::util::cli::{Command, Matches};
use tmfu_overlay::util::prng::Rng;
use tmfu_overlay::wire::auth::TenantKeyring;
use tmfu_overlay::wire::server::{install_sigterm_drain, ServerCtl, WireServer};
use tmfu_overlay::wire::ListenAddr;
use tmfu_overlay::{bench_suite, dfg, frontend, report, sched};

/// Exit code for a typed [`ServiceError::DeadlineExceeded`]: scripts
/// driving `tmfu call --deadline-ms` can tell "the budget lapsed"
/// (retry with a bigger budget, or accept the shed) apart from every
/// other failure without parsing stderr.
const EXIT_DEADLINE: u8 = 3;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("{e}");
            match e.downcast_ref::<ServiceError>() {
                Some(ServiceError::DeadlineExceeded { .. }) => ExitCode::from(EXIT_DEADLINE),
                _ => ExitCode::FAILURE,
            }
        }
    }
}

fn commands() -> Vec<Command> {
    vec![
        Command::new("list", "list the benchmark kernels"),
        Command::new("compile", "compile a kernel source file to a DFG")
            .positional("file", "path to a .k kernel source")
            .flag("dot", "emit graphviz instead of JSON"),
        Command::new("export-dfg", "write DFG+schedule JSON for all benchmarks")
            .opt("out-dir", "output directory", Some("benchmarks/dfg")),
        Command::new("schedule", "print the stage schedule for a benchmark")
            .positional("kernel", "benchmark name (see 'list')"),
        Command::new("table1", "print the cycle-by-cycle schedule table")
            .positional("kernel", "benchmark name")
            .opt("cycles", "cycles to print", Some("32")),
        Command::new("dot", "emit the DFG in graphviz format")
            .positional("kernel", "benchmark name"),
        Command::new("simulate", "run the cycle-accurate simulator")
            .positional("kernel", "benchmark name")
            .opt("packets", "number of data packets", Some("16"))
            .opt("seed", "input PRNG seed", Some("7")),
        Command::new("table2", "reproduce Table II (DFG characteristics)"),
        Command::new("table3", "reproduce Table III (area & throughput)"),
        Command::new("fig5", "reproduce Fig. 5 (FU counts)"),
        Command::new("fig6", "reproduce Fig. 6 (area comparison)"),
        Command::new("ctx-switch", "reproduce the context-switch comparison"),
        Command::new("resources", "reproduce the §III.A resource results"),
        Command::new("verify", "statically verify compiled kernels + committed artifacts")
            .opt("artifacts-dir", "DFG+schedule JSON directory", Some("benchmarks/dfg")),
        Command::new("serve", "run the overlay service (any execution backend)")
            .opt(
                "backend",
                "execution backend: ref | sim | pjrt | turbo",
                Some("sim"),
            )
            .opt("artifacts", "artifacts directory (pjrt backend)", Some("artifacts"))
            .opt("pipelines", "overlay pipelines (workers)", Some("2"))
            .opt("requests", "requests to serve", Some("200"))
            .opt("batch", "max batch size", Some("16"))
            .opt("queue-depth", "per-kernel admission limit", Some("1024"))
            .opt("seed", "workload seed", Some("42"))
            .opt("metrics-json", "write the metrics snapshot JSON here on exit", None),
        Command::new("listen", "serve the overlay over the wire protocol (DESIGN.md §9)")
            .opt(
                "backend",
                "execution backend: ref | sim | pjrt | turbo",
                Some("turbo"),
            )
            .opt("artifacts", "artifacts directory (pjrt backend)", Some("artifacts"))
            .opt("pipelines", "overlay pipelines (workers)", Some("2"))
            .opt("batch", "max batch size", Some("16"))
            .opt("queue-depth", "per-kernel admission limit", Some("1024"))
            .opt("tcp", "TCP listen address (empty disables)", Some("127.0.0.1:7700"))
            .opt("socket", "unix socket path (empty disables)", Some(""))
            .opt(
                "max-conns",
                "exit after this many connections; single transport only (0 = run forever)",
                Some("0"),
            )
            .opt(
                "tenants",
                "tenant keyring file (name:secret[:weight[:quota]] per line); \
                 requires signed Hellos when set",
                None,
            ),
        Command::new("call", "call a kernel on a 'tmfu listen' server or a router")
            .positional("kernel", "kernel name (see 'list')")
            .opt("addr", "server address: host:port or unix:<path>", Some("127.0.0.1:7700"))
            .opt("inputs", "comma-separated i32 inputs", Some(""))
            .opt("count", "submit the call this many times (burst mode)", Some("1"))
            .opt("retries", "reconnect-and-retry budget on retryable failures", Some("0"))
            .opt("timeout-ms", "overall deadline across all retries", Some("30000"))
            .opt(
                "deadline-ms",
                "per-call deadline budget carried on the wire (v2; 0 = none): the server \
                 sheds or expires the call instead of executing it late",
                Some("0"),
            )
            .opt(
                "cancel-after-ms",
                "submit, wait this many ms, then cancel instead of collecting the reply \
                 (exercises the Cancel opcode; exits 0)",
                None,
            )
            .opt("tenant", "tenant name to authenticate as", None)
            .opt("secret", "shared secret for --tenant (signs the Hello)", None)
            .flag("metrics", "also fetch and print the server metrics JSON"),
        Command::new("router", "fault-tolerant front for replicated 'tmfu listen' backends")
            .opt(
                "backends",
                "comma-separated backend addresses (host:port or unix:<path>)",
                Some("127.0.0.1:7701,127.0.0.1:7702"),
            )
            .opt("tcp", "TCP listen address (empty disables)", Some("127.0.0.1:7700"))
            .opt("socket", "unix socket path (empty disables)", Some(""))
            .opt("probe-ms", "health-probe period per backend", Some("2000"))
            .opt("retries", "per-call re-dispatch budget", Some("4"))
            .opt("timeout-ms", "per-call deadline", Some("30000"))
            .opt("tenant", "tenant to authenticate as on downstream backends", None)
            .opt("secret", "shared secret for --tenant", None),
    ]
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let cmds = commands();
    let name = args.first().map(String::as_str).unwrap_or("");
    if name.is_empty() || name == "--help" || name == "-h" || name == "help" {
        let mut s = String::from(
            "tmfu — DSP-block time-multiplexed FPGA overlay (reproduction)\n\nCOMMANDS:\n",
        );
        for c in &cmds {
            s.push_str(&format!("  {:<12} {}\n", c.name(), c.about()));
        }
        s.push_str("\nRun 'tmfu <command> --help' for details.");
        println!("{s}");
        return Ok(());
    }
    let cmd = cmds
        .iter()
        .find(|c| c.name() == name)
        .ok_or_else(|| anyhow::anyhow!("unknown command '{name}' (try 'tmfu help')"))?;
    let m = cmd.parse(&args[1..]).map_err(|e| anyhow::anyhow!("{e}"))?;

    match name {
        "list" => {
            for n in bench_suite::all_names() {
                let g = bench_suite::load(n)?;
                let c = dfg::Characteristics::of(&g);
                println!(
                    "{n:<12} {} in / {} out, {} ops, depth {}",
                    c.n_inputs, c.n_outputs, c.n_ops, c.depth
                );
            }
        }
        "compile" => {
            let path = m.get_pos("file").unwrap();
            let src = std::fs::read_to_string(path)?;
            let g = frontend::compile(&src).map_err(|e| anyhow::anyhow!("{e}"))?;
            if m.flag("dot") {
                println!("{}", g.to_dot());
            } else {
                let p = sched::Program::schedule(&g)?;
                println!("{}", sched::program_to_json(&g, &p).to_string_pretty());
            }
        }
        "export-dfg" => {
            let dir = m.get("out-dir").unwrap();
            std::fs::create_dir_all(dir)?;
            for n in bench_suite::all_names() {
                let g = bench_suite::load(n)?;
                let p = sched::Program::schedule(&g)?;
                let path = format!("{dir}/{n}.json");
                std::fs::write(&path, sched::program_to_json(&g, &p).to_string_pretty())?;
                println!("wrote {path}");
            }
        }
        "schedule" => {
            let kernel = m.get_pos("kernel").unwrap();
            let g = bench_suite::load(kernel)?;
            let p = sched::Program::schedule(&g)?;
            let t = sched::Timing::of(&p);
            println!(
                "kernel {} — {} stages, II = {}, latency = {} cycles",
                kernel,
                p.n_stages(),
                t.ii,
                t.latency()
            );
            for st in &p.stages {
                println!(
                    "  stage {}: {} loads, {} ops, {} bypasses, {} consts",
                    st.stage,
                    st.n_loads(),
                    st.ops.len(),
                    st.bypasses.len(),
                    st.consts.len()
                );
                for ins in &st.instrs {
                    println!("      {}", ins.mnemonic());
                }
            }
            let img = p.context_image()?;
            println!(
                "context: {} instruction words = {} B (paper accounting), {} B with RF consts",
                img.n_instrs(),
                img.size_bytes_instr_only(),
                img.size_bytes_total().map_err(|e| anyhow::anyhow!("{e}"))?
            );
        }
        "table1" => {
            let kernel = m.get_pos("kernel").unwrap();
            let cycles = m
                .get_usize("cycles")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .unwrap();
            let g = bench_suite::load(kernel)?;
            let p = sched::Program::schedule(&g)?;
            let t = sched::ScheduleTable::generate(&p, cycles);
            print!("{}", t.render());
        }
        "dot" => {
            let kernel = m.get_pos("kernel").unwrap();
            println!("{}", bench_suite::load(kernel)?.to_dot());
        }
        "simulate" => {
            let kernel = m.get_pos("kernel").unwrap();
            let n = m
                .get_usize("packets")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .unwrap();
            let seed = m
                .get_usize("seed")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .unwrap() as u64;
            report::simulate::run_and_print(kernel, n, seed)?;
        }
        "table2" => print!("{}", report::table2::render()?),
        "table3" => print!("{}", report::table3::render()?),
        "fig5" => print!("{}", report::fig5::render()?),
        "fig6" => print!("{}", report::fig6::render()?),
        "ctx-switch" => print!("{}", report::ctx_switch::render()?),
        "resources" => print!("{}", report::resources_report::render()),
        "verify" => verify_cmd(&m)?,
        "serve" => serve(&m)?,
        "listen" => listen(&m)?,
        "call" => call(&m)?,
        "router" => router(&m)?,
        _ => unreachable!(),
    }
    Ok(())
}

/// `tmfu verify`: the static verifier gate (DESIGN.md §12). Checks
/// every compiled bench-suite kernel (DFG well-formedness, schedule
/// legality, tape slot safety, ISA-context consistency), then
/// re-validates the committed DFG+schedule artifacts against a fresh
/// compile. Exits nonzero on the first violation — `make verify` and
/// CI run this as a permanent gate.
fn verify_cmd(m: &Matches) -> anyhow::Result<()> {
    let reg = tmfu_overlay::exec::KernelRegistry::compile_bench_suite()?;
    tmfu_overlay::verify::verify_registry(&reg).map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut n = 0;
    for k in reg.iter() {
        println!("ok  kernel    {}", k.name);
        n += 1;
    }
    let dir = m.get("artifacts-dir").unwrap();
    let path = std::path::Path::new(dir);
    if !path.is_dir() {
        anyhow::bail!("verify: artifacts directory '{dir}' not found (run 'tmfu export-dfg')");
    }
    let names = tmfu_overlay::verify::verify_artifacts_dir(path)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    for name in &names {
        println!("ok  artifact  {dir}/{name}.json");
    }
    println!("verify: {n} kernels, {} artifacts — all checks passed", names.len());
    Ok(())
}

/// `tmfu listen`: bind the wire protocol on TCP and/or a Unix socket
/// and serve an `OverlayService` until killed (or until `--max-conns`
/// connections have come and gone — the CI smoke mode).
fn listen(m: &Matches) -> anyhow::Result<()> {
    let backend: BackendKind = m
        .get("backend")
        .unwrap()
        .parse()
        .map_err(|e: String| anyhow::anyhow!("{e}"))?;
    let pipelines = m.get_usize("pipelines").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let batch = m.get_usize("batch").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let queue_depth = m
        .get_usize("queue-depth")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .unwrap();
    let max_conns = m.get_usize("max-conns").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let mut addrs = Vec::new();
    if let Some(path) = m.get("socket").filter(|s| !s.is_empty()) {
        addrs.push(ListenAddr::Unix(path.into()));
    }
    if let Some(tcp) = m.get("tcp").filter(|s| !s.is_empty()) {
        addrs.push(ListenAddr::Tcp(tcp.to_string()));
    }
    anyhow::ensure!(
        !addrs.is_empty(),
        "nothing to bind: --tcp and --socket are both disabled"
    );
    // The limit counts connections on one listener; with two listeners
    // "exit after N connections" would be ambiguous (and the process
    // would linger until every listener hit its own limit).
    anyhow::ensure!(
        max_conns == 0 || addrs.len() == 1,
        "--max-conns needs exactly one transport (disable the other with --tcp= or --socket=)"
    );

    // A keyring file switches the server to auth-required mode: every
    // connection must present a Hello signed by one of these tenants,
    // and each tenant gets its own DRR lane (weight) and admission
    // quota straight from the file.
    let keyring = match m.get("tenants") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--tenants {path}: {e}"))?;
            Some(Arc::new(
                TenantKeyring::parse(&text).map_err(|e| anyhow::anyhow!("--tenants {path}: {e}"))?,
            ))
        }
        None => None,
    };
    let mut builder = OverlayService::builder()
        .backend(backend)
        .artifacts_dir(m.get("artifacts").unwrap().to_string())
        .pipelines(pipelines)
        .max_batch(batch)
        .queue_depth(queue_depth);
    if let Some(keyring) = &keyring {
        for entry in keyring.entries() {
            builder = builder
                .tenant_weight(&entry.name, entry.weight)
                .tenant_quota(&entry.name, entry.quota);
        }
    }
    let service = Arc::new(builder.build()?);
    let limit = (max_conns > 0).then_some(max_conns);
    // One control across every bound transport, plus the SIGTERM hook:
    // a Drain frame on either listener (or a SIGTERM) drains them
    // together — in-flight replies finish, then the process exits 0.
    install_sigterm_drain();
    let ctl = ServerCtl::new();
    if let Some(keyring) = keyring {
        let n = keyring.entries().len();
        ctl.set_auth(keyring);
        println!("tenant auth required ({n} tenant(s) in the keyring)");
    }
    let mut servers = Vec::new();
    for addr in &addrs {
        let server =
            WireServer::bind_with_ctl(Arc::clone(&service), addr, limit, Arc::clone(&ctl))?;
        println!(
            "listening on {} ({} kernels, backend '{backend}', {pipelines} pipeline(s), \
             queue depth {queue_depth})",
            server.addr(),
            service.kernel_names().len()
        );
        servers.push(server);
    }
    println!("call with: tmfu call <kernel> --addr {} --inputs ...", servers[0].addr());
    for server in servers {
        server.wait();
    }
    // Reached on --max-conns exhaustion or a graceful drain; report
    // what was served either way.
    println!("{}", service.metrics().render());
    service.shutdown()?;
    Ok(())
}

/// `tmfu router`: front a fleet of `tmfu listen` backends. Routes each
/// call to a healthy replica, retries idempotent calls on replica
/// failure, drains gracefully on SIGTERM or a `Drain` frame.
fn router(m: &Matches) -> anyhow::Result<()> {
    let backends: Vec<String> = m
        .get("backends")
        .unwrap()
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    anyhow::ensure!(!backends.is_empty(), "--backends needs at least one address");
    let probe_ms = m.get_usize("probe-ms").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let retries = m.get_usize("retries").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let timeout_ms = m.get_usize("timeout-ms").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let addr = match (
        m.get("socket").filter(|s| !s.is_empty()),
        m.get("tcp").filter(|s| !s.is_empty()),
    ) {
        (Some(path), _) => ListenAddr::Unix(path.into()),
        (None, Some(tcp)) => ListenAddr::Tcp(tcp.to_string()),
        (None, None) => anyhow::bail!("nothing to bind: --tcp and --socket are both disabled"),
    };
    let n_backends = backends.len();
    let mut cfg = RouterConfig::new(backends);
    cfg.probe_interval = Duration::from_millis(probe_ms as u64);
    cfg.max_retries = retries as u32;
    cfg.call_deadline = Duration::from_millis(timeout_ms as u64);
    cfg.tenant = m.get("tenant").map(String::from);
    cfg.secret = m.get("secret").map(|s| s.as_bytes().to_vec());
    install_sigterm_drain();
    let router = Router::start(cfg, &addr)?;
    println!(
        "routing {n_backends} backend(s) on {} (probe every {probe_ms} ms, {retries} retries, \
         {timeout_ms} ms deadline)",
        router.addr()
    );
    println!("call with: tmfu call <kernel> --addr {} --inputs ...", router.addr());
    router.wait();
    Ok(())
}

/// `tmfu call`: wire client — resolve, call (`--count` times), print
/// the output row (and optionally the server's metrics snapshot). On a
/// retryable failure it reconnects and retries the unfinished calls,
/// up to `--retries` times within the `--timeout-ms` deadline — safe
/// because overlay kernels are pure (re-running a call is idempotent).
fn call(m: &Matches) -> anyhow::Result<()> {
    let addr = m.get("addr").unwrap();
    let kernel = m.get_pos("kernel").unwrap();
    let raw = m.get("inputs").unwrap();
    let inputs: Vec<i32> = raw
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| {
            s.parse::<i32>()
                .map_err(|_| anyhow::anyhow!("--inputs: '{s}' is not an i32"))
        })
        .collect::<anyhow::Result<_>>()?;
    let count = m.get_usize("count").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let retries = m.get_usize("retries").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let timeout_ms = m.get_usize("timeout-ms").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let deadline_ms = m.get_usize("deadline-ms").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let budget = (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms as u64));
    let cancel_after = m
        .get_usize("cancel-after-ms")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .map(|ms| Duration::from_millis(ms as u64));
    anyhow::ensure!(count >= 1, "--count must be at least 1");
    let mut builder = OverlayClient::builder();
    if let Some(tenant) = m.get("tenant") {
        builder = builder.tenant(tenant);
    }
    if let Some(secret) = m.get("secret") {
        anyhow::ensure!(
            m.get("tenant").is_some(),
            "--secret needs --tenant (who is this secret for?)"
        );
        builder = builder.secret(secret.as_bytes());
    }
    // Cancel mode: submit, linger, then withdraw the calls with the
    // wire `Cancel` opcode instead of collecting replies. The server
    // purges queued rows and frees the reply slots; nothing leaks.
    if let Some(linger) = cancel_after {
        let client = builder.connect(addr)?;
        let remote = client.kernel(kernel)?;
        let mut pendings = Vec::with_capacity(count);
        for _ in 0..count {
            match budget {
                Some(b) => pendings.push(remote.submit_with_deadline(&inputs, b)?),
                None => pendings.push(remote.submit(&inputs)?),
            }
        }
        std::thread::sleep(linger);
        for p in &mut pendings {
            p.cancel();
        }
        eprintln!("cancelled {count} call(s) after {} ms", linger.as_millis());
        return Ok(());
    }
    let deadline = Instant::now() + Duration::from_millis(timeout_ms as u64);
    // Same retry policy as the router: capped exponential backoff,
    // only for failures classified retryable, all under one deadline.
    let mut backoff = Backoff::new(Duration::from_millis(50), Duration::from_secs(2), 1);
    let mut done = 0usize;
    let mut attempt = 0usize;
    let out = loop {
        match call_round(&builder, addr, kernel, &inputs, count - done, budget, deadline) {
            Ok(row) => break row,
            Err((ok, e)) => {
                done += ok;
                attempt += 1;
                let out_of_time = Instant::now() >= deadline;
                if attempt > retries || !retryable(&e) || out_of_time {
                    if done > 0 {
                        eprintln!("{done}/{count} calls completed before the failure");
                    }
                    return Err(e.into());
                }
                eprintln!("attempt {attempt}/{retries} failed retryably ({e}); retrying");
                let left = deadline.saturating_duration_since(Instant::now());
                std::thread::sleep(backoff.next_delay().min(left));
            }
        }
    };
    println!(
        "{}",
        out.iter().map(ToString::to_string).collect::<Vec<_>>().join(" ")
    );
    if count > 1 {
        eprintln!("{count} calls completed");
    }
    if m.flag("metrics") {
        let client = builder.connect(addr)?;
        println!("{}", client.metrics()?.to_string_pretty());
    }
    Ok(())
}

/// One `tmfu call` round over a fresh connection: submit `n` copies of
/// the call (each carrying `budget` on the wire when `--deadline-ms`
/// is set), wait them all out under `deadline`. `Ok` with the output
/// row when every call succeeded; otherwise the number that did
/// succeed plus the first typed error (the retry loop's classifier
/// input).
fn call_round(
    builder: &ClientBuilder,
    addr: &str,
    kernel: &str,
    inputs: &[i32],
    n: usize,
    budget: Option<Duration>,
    deadline: Instant,
) -> Result<Vec<i32>, (usize, ServiceError)> {
    let client = builder.connect(addr).map_err(|e| (0, e))?;
    let remote = client.kernel(kernel).map_err(|e| (0, e))?;
    let mut first_err: Option<ServiceError> = None;
    let mut pendings = Vec::with_capacity(n);
    for _ in 0..n {
        let submitted = match budget {
            Some(b) => remote.submit_with_deadline(inputs, b),
            None => remote.submit(inputs),
        };
        match submitted {
            Ok(p) => pendings.push(p),
            Err(e) => {
                first_err = Some(e);
                break;
            }
        }
    }
    let mut row: Option<Vec<i32>> = None;
    let mut ok = 0usize;
    for mut p in pendings {
        let left = deadline.saturating_duration_since(Instant::now());
        match p.wait_timeout(left) {
            Ok(r) => {
                ok += 1;
                row = Some(r);
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    match first_err {
        None => Ok(row.unwrap_or_default()),
        Some(e) => Err((ok, e)),
    }
}

/// `tmfu serve`: drive the service with a mixed-kernel workload and
/// print the metrics (the paper's Fig. 4 usage model). Every admitted
/// response is verified against the functional oracle; rejected
/// requests (admission control) are reported, not failed.
fn serve(m: &Matches) -> anyhow::Result<()> {
    let backend: BackendKind = m
        .get("backend")
        .unwrap()
        .parse()
        .map_err(|e: String| anyhow::anyhow!("{e}"))?;
    let dir = m.get("artifacts").unwrap().to_string();
    let pipelines = m
        .get_usize("pipelines")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .unwrap();
    let requests = m
        .get_usize("requests")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .unwrap();
    let batch = m
        .get_usize("batch")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .unwrap();
    let queue_depth = m
        .get_usize("queue-depth")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .unwrap();
    let seed = m
        .get_usize("seed")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .unwrap() as u64;

    let service = OverlayService::builder()
        .backend(backend)
        .artifacts_dir(dir)
        .pipelines(pipelines)
        .max_batch(batch)
        .queue_depth(queue_depth)
        .build()?;
    let handles = service.handles();
    println!(
        "serving {requests} requests across {} kernels on {pipelines} pipeline(s), \
         max batch {batch}, queue depth {queue_depth}, backend '{backend}'",
        handles.len()
    );
    let mut rng = Rng::new(seed);
    let mut pending = Vec::with_capacity(requests);
    let mut expected = Vec::with_capacity(requests);
    let mut rejected = 0usize;
    for _ in 0..requests {
        let h = rng.choose(&handles);
        let inputs: Vec<i32> = (0..h.arity())
            .map(|_| rng.range_i64(-1000, 1000) as i32)
            .collect();
        match h.submit(&inputs) {
            Ok(p) => {
                expected.push(dfg::eval(&h.compiled().dfg, &inputs));
                pending.push(p);
            }
            // Backpressure is a reportable outcome, not a crash: an
            // open-loop client that outruns the queue depth sees
            // explicit rejections.
            Err(ServiceError::Rejected { .. }) => rejected += 1,
            Err(e) => return Err(e.into()),
        }
    }
    let mut errors = 0usize;
    for (p, want) in pending.into_iter().zip(expected) {
        match p.wait() {
            Ok(got) if got == want => {}
            _ => errors += 1,
        }
    }
    let snapshot = service.metrics();
    println!("{}", snapshot.render());
    if let Some(path) = m.get("metrics-json") {
        let mut text = snapshot.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text)?;
        println!("wrote {path}");
    }
    service.shutdown()?;
    if errors > 0 {
        anyhow::bail!("{errors} requests returned wrong results");
    }
    let admitted = requests - rejected;
    if rejected > 0 {
        println!(
            "all {admitted} admitted responses verified against the functional oracle \
             ({rejected} rejected by admission control)"
        );
    } else {
        println!("all responses verified against the functional oracle");
    }
    Ok(())
}
