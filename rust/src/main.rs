//! `tmfu` — CLI for the TMFU overlay reproduction.
//!
//! Subcommands cover the paper's complete flow: kernel compilation
//! (`compile`, `export-dfg`), scheduling and inspection (`schedule`,
//! `table1`, `dot`), cycle-accurate simulation (`simulate`), reports
//! (`table2`, `table3`, `fig5`, `fig6`, `ctx-switch`, `resources`),
//! and the serving runtime (`serve --backend {ref,sim,pjrt,turbo}`;
//! only the pjrt backend requires `make artifacts`). `serve` drives
//! the typed service API ([`tmfu_overlay::service::OverlayService`] +
//! `KernelHandle` sessions) with a mixed-kernel oracle-checked
//! workload, and can write its typed metrics snapshot as JSON
//! (`--metrics-json`) for CI and tooling to assert on.
//!
//! Network serving: `listen` exposes the same service over the
//! length-prefixed wire protocol (DESIGN.md §9) on TCP and/or a Unix
//! socket, and `call` is the matching one-shot client — together they
//! are the two-terminal walkthrough in the README.

use std::process::ExitCode;
use std::sync::Arc;
use tmfu_overlay::client::OverlayClient;
use tmfu_overlay::exec::BackendKind;
use tmfu_overlay::service::{OverlayService, ServiceError};
use tmfu_overlay::util::cli::{Command, Matches};
use tmfu_overlay::util::prng::Rng;
use tmfu_overlay::wire::server::WireServer;
use tmfu_overlay::wire::ListenAddr;
use tmfu_overlay::{bench_suite, dfg, frontend, report, sched};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("{e}");
            ExitCode::FAILURE
        }
    }
}

fn commands() -> Vec<Command> {
    vec![
        Command::new("list", "list the benchmark kernels"),
        Command::new("compile", "compile a kernel source file to a DFG")
            .positional("file", "path to a .k kernel source")
            .flag("dot", "emit graphviz instead of JSON"),
        Command::new("export-dfg", "write DFG+schedule JSON for all benchmarks")
            .opt("out-dir", "output directory", Some("benchmarks/dfg")),
        Command::new("schedule", "print the stage schedule for a benchmark")
            .positional("kernel", "benchmark name (see 'list')"),
        Command::new("table1", "print the cycle-by-cycle schedule table")
            .positional("kernel", "benchmark name")
            .opt("cycles", "cycles to print", Some("32")),
        Command::new("dot", "emit the DFG in graphviz format")
            .positional("kernel", "benchmark name"),
        Command::new("simulate", "run the cycle-accurate simulator")
            .positional("kernel", "benchmark name")
            .opt("packets", "number of data packets", Some("16"))
            .opt("seed", "input PRNG seed", Some("7")),
        Command::new("table2", "reproduce Table II (DFG characteristics)"),
        Command::new("table3", "reproduce Table III (area & throughput)"),
        Command::new("fig5", "reproduce Fig. 5 (FU counts)"),
        Command::new("fig6", "reproduce Fig. 6 (area comparison)"),
        Command::new("ctx-switch", "reproduce the context-switch comparison"),
        Command::new("resources", "reproduce the §III.A resource results"),
        Command::new("serve", "run the overlay service (any execution backend)")
            .opt(
                "backend",
                "execution backend: ref | sim | pjrt | turbo",
                Some("sim"),
            )
            .opt("artifacts", "artifacts directory (pjrt backend)", Some("artifacts"))
            .opt("pipelines", "overlay pipelines (workers)", Some("2"))
            .opt("requests", "requests to serve", Some("200"))
            .opt("batch", "max batch size", Some("16"))
            .opt("queue-depth", "per-kernel admission limit", Some("1024"))
            .opt("seed", "workload seed", Some("42"))
            .opt("metrics-json", "write the metrics snapshot JSON here on exit", None),
        Command::new("listen", "serve the overlay over the wire protocol (DESIGN.md §9)")
            .opt(
                "backend",
                "execution backend: ref | sim | pjrt | turbo",
                Some("turbo"),
            )
            .opt("artifacts", "artifacts directory (pjrt backend)", Some("artifacts"))
            .opt("pipelines", "overlay pipelines (workers)", Some("2"))
            .opt("batch", "max batch size", Some("16"))
            .opt("queue-depth", "per-kernel admission limit", Some("1024"))
            .opt("tcp", "TCP listen address (empty disables)", Some("127.0.0.1:7700"))
            .opt("socket", "unix socket path (empty disables)", Some(""))
            .opt(
                "max-conns",
                "exit after this many connections; single transport only (0 = run forever)",
                Some("0"),
            ),
        Command::new("call", "call a kernel on a 'tmfu listen' server")
            .positional("kernel", "kernel name (see 'list')")
            .opt("addr", "server address: host:port or unix:<path>", Some("127.0.0.1:7700"))
            .opt("inputs", "comma-separated i32 inputs", Some(""))
            .flag("metrics", "also fetch and print the server metrics JSON"),
    ]
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let cmds = commands();
    let name = args.first().map(String::as_str).unwrap_or("");
    if name.is_empty() || name == "--help" || name == "-h" || name == "help" {
        let mut s = String::from(
            "tmfu — DSP-block time-multiplexed FPGA overlay (reproduction)\n\nCOMMANDS:\n",
        );
        for c in &cmds {
            s.push_str(&format!("  {:<12} {}\n", c.name(), c.about()));
        }
        s.push_str("\nRun 'tmfu <command> --help' for details.");
        println!("{s}");
        return Ok(());
    }
    let cmd = cmds
        .iter()
        .find(|c| c.name() == name)
        .ok_or_else(|| anyhow::anyhow!("unknown command '{name}' (try 'tmfu help')"))?;
    let m = cmd.parse(&args[1..]).map_err(|e| anyhow::anyhow!("{e}"))?;

    match name {
        "list" => {
            for n in bench_suite::all_names() {
                let g = bench_suite::load(n)?;
                let c = dfg::Characteristics::of(&g);
                println!(
                    "{n:<12} {} in / {} out, {} ops, depth {}",
                    c.n_inputs, c.n_outputs, c.n_ops, c.depth
                );
            }
        }
        "compile" => {
            let path = m.get_pos("file").unwrap();
            let src = std::fs::read_to_string(path)?;
            let g = frontend::compile(&src).map_err(|e| anyhow::anyhow!("{e}"))?;
            if m.flag("dot") {
                println!("{}", g.to_dot());
            } else {
                let p = sched::Program::schedule(&g)?;
                println!("{}", sched::program_to_json(&g, &p).to_string_pretty());
            }
        }
        "export-dfg" => {
            let dir = m.get("out-dir").unwrap();
            std::fs::create_dir_all(dir)?;
            for n in bench_suite::all_names() {
                let g = bench_suite::load(n)?;
                let p = sched::Program::schedule(&g)?;
                let path = format!("{dir}/{n}.json");
                std::fs::write(&path, sched::program_to_json(&g, &p).to_string_pretty())?;
                println!("wrote {path}");
            }
        }
        "schedule" => {
            let kernel = m.get_pos("kernel").unwrap();
            let g = bench_suite::load(kernel)?;
            let p = sched::Program::schedule(&g)?;
            let t = sched::Timing::of(&p);
            println!(
                "kernel {} — {} stages, II = {}, latency = {} cycles",
                kernel,
                p.n_stages(),
                t.ii,
                t.latency()
            );
            for st in &p.stages {
                println!(
                    "  stage {}: {} loads, {} ops, {} bypasses, {} consts",
                    st.stage,
                    st.n_loads(),
                    st.ops.len(),
                    st.bypasses.len(),
                    st.consts.len()
                );
                for ins in &st.instrs {
                    println!("      {}", ins.mnemonic());
                }
            }
            let img = p.context_image()?;
            println!(
                "context: {} instruction words = {} B (paper accounting), {} B with RF consts",
                img.n_instrs(),
                img.size_bytes_instr_only(),
                img.size_bytes_total().map_err(|e| anyhow::anyhow!("{e}"))?
            );
        }
        "table1" => {
            let kernel = m.get_pos("kernel").unwrap();
            let cycles = m
                .get_usize("cycles")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .unwrap();
            let g = bench_suite::load(kernel)?;
            let p = sched::Program::schedule(&g)?;
            let t = sched::ScheduleTable::generate(&p, cycles);
            print!("{}", t.render());
        }
        "dot" => {
            let kernel = m.get_pos("kernel").unwrap();
            println!("{}", bench_suite::load(kernel)?.to_dot());
        }
        "simulate" => {
            let kernel = m.get_pos("kernel").unwrap();
            let n = m
                .get_usize("packets")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .unwrap();
            let seed = m
                .get_usize("seed")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .unwrap() as u64;
            report::simulate::run_and_print(kernel, n, seed)?;
        }
        "table2" => print!("{}", report::table2::render()?),
        "table3" => print!("{}", report::table3::render()?),
        "fig5" => print!("{}", report::fig5::render()?),
        "fig6" => print!("{}", report::fig6::render()?),
        "ctx-switch" => print!("{}", report::ctx_switch::render()?),
        "resources" => print!("{}", report::resources_report::render()),
        "serve" => serve(&m)?,
        "listen" => listen(&m)?,
        "call" => call(&m)?,
        _ => unreachable!(),
    }
    Ok(())
}

/// `tmfu listen`: bind the wire protocol on TCP and/or a Unix socket
/// and serve an `OverlayService` until killed (or until `--max-conns`
/// connections have come and gone — the CI smoke mode).
fn listen(m: &Matches) -> anyhow::Result<()> {
    let backend: BackendKind = m
        .get("backend")
        .unwrap()
        .parse()
        .map_err(|e: String| anyhow::anyhow!("{e}"))?;
    let pipelines = m.get_usize("pipelines").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let batch = m.get_usize("batch").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let queue_depth = m
        .get_usize("queue-depth")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .unwrap();
    let max_conns = m.get_usize("max-conns").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let mut addrs = Vec::new();
    if let Some(path) = m.get("socket").filter(|s| !s.is_empty()) {
        addrs.push(ListenAddr::Unix(path.into()));
    }
    if let Some(tcp) = m.get("tcp").filter(|s| !s.is_empty()) {
        addrs.push(ListenAddr::Tcp(tcp.to_string()));
    }
    anyhow::ensure!(
        !addrs.is_empty(),
        "nothing to bind: --tcp and --socket are both disabled"
    );
    // The limit counts connections on one listener; with two listeners
    // "exit after N connections" would be ambiguous (and the process
    // would linger until every listener hit its own limit).
    anyhow::ensure!(
        max_conns == 0 || addrs.len() == 1,
        "--max-conns needs exactly one transport (disable the other with --tcp= or --socket=)"
    );

    let service = Arc::new(
        OverlayService::builder()
            .backend(backend)
            .artifacts_dir(m.get("artifacts").unwrap().to_string())
            .pipelines(pipelines)
            .max_batch(batch)
            .queue_depth(queue_depth)
            .build()?,
    );
    let limit = (max_conns > 0).then_some(max_conns);
    let mut servers = Vec::new();
    for addr in &addrs {
        let server = WireServer::bind_with_limit(Arc::clone(&service), addr, limit)?;
        println!(
            "listening on {} ({} kernels, backend '{backend}', {pipelines} pipeline(s), \
             queue depth {queue_depth})",
            server.addr(),
            service.kernel_names().len()
        );
        servers.push(server);
    }
    println!("call with: tmfu call <kernel> --addr {} --inputs ...", servers[0].addr());
    for server in servers {
        server.wait();
    }
    // Only reachable in --max-conns mode; report what was served.
    println!("{}", service.metrics().render());
    service.shutdown()?;
    Ok(())
}

/// `tmfu call`: one-shot wire client — resolve, call, print the output
/// row (and optionally the server's metrics snapshot).
fn call(m: &Matches) -> anyhow::Result<()> {
    let addr = m.get("addr").unwrap();
    let kernel = m.get_pos("kernel").unwrap();
    let raw = m.get("inputs").unwrap();
    let inputs: Vec<i32> = raw
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| {
            s.parse::<i32>()
                .map_err(|_| anyhow::anyhow!("--inputs: '{s}' is not an i32"))
        })
        .collect::<anyhow::Result<_>>()?;
    let client = OverlayClient::connect(addr)?;
    let remote = client.kernel(kernel)?;
    let out = remote.call(&inputs)?;
    println!(
        "{}",
        out.iter().map(ToString::to_string).collect::<Vec<_>>().join(" ")
    );
    if m.flag("metrics") {
        println!("{}", client.metrics()?.to_string_pretty());
    }
    Ok(())
}

/// `tmfu serve`: drive the service with a mixed-kernel workload and
/// print the metrics (the paper's Fig. 4 usage model). Every admitted
/// response is verified against the functional oracle; rejected
/// requests (admission control) are reported, not failed.
fn serve(m: &Matches) -> anyhow::Result<()> {
    let backend: BackendKind = m
        .get("backend")
        .unwrap()
        .parse()
        .map_err(|e: String| anyhow::anyhow!("{e}"))?;
    let dir = m.get("artifacts").unwrap().to_string();
    let pipelines = m
        .get_usize("pipelines")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .unwrap();
    let requests = m
        .get_usize("requests")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .unwrap();
    let batch = m
        .get_usize("batch")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .unwrap();
    let queue_depth = m
        .get_usize("queue-depth")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .unwrap();
    let seed = m
        .get_usize("seed")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .unwrap() as u64;

    let service = OverlayService::builder()
        .backend(backend)
        .artifacts_dir(dir)
        .pipelines(pipelines)
        .max_batch(batch)
        .queue_depth(queue_depth)
        .build()?;
    let handles = service.handles();
    println!(
        "serving {requests} requests across {} kernels on {pipelines} pipeline(s), \
         max batch {batch}, queue depth {queue_depth}, backend '{backend}'",
        handles.len()
    );
    let mut rng = Rng::new(seed);
    let mut pending = Vec::with_capacity(requests);
    let mut expected = Vec::with_capacity(requests);
    let mut rejected = 0usize;
    for _ in 0..requests {
        let h = rng.choose(&handles);
        let inputs: Vec<i32> = (0..h.arity())
            .map(|_| rng.range_i64(-1000, 1000) as i32)
            .collect();
        match h.submit(&inputs) {
            Ok(p) => {
                expected.push(dfg::eval(&h.compiled().dfg, &inputs));
                pending.push(p);
            }
            // Backpressure is a reportable outcome, not a crash: an
            // open-loop client that outruns the queue depth sees
            // explicit rejections.
            Err(ServiceError::Rejected { .. }) => rejected += 1,
            Err(e) => return Err(e.into()),
        }
    }
    let mut errors = 0usize;
    for (p, want) in pending.into_iter().zip(expected) {
        match p.wait() {
            Ok(got) if got == want => {}
            _ => errors += 1,
        }
    }
    let snapshot = service.metrics();
    println!("{}", snapshot.render());
    if let Some(path) = m.get("metrics-json") {
        let mut text = snapshot.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text)?;
        println!("wrote {path}");
    }
    service.shutdown()?;
    if errors > 0 {
        anyhow::bail!("{errors} requests returned wrong results");
    }
    let admitted = requests - rejected;
    if rejected > 0 {
        println!(
            "all {admitted} admitted responses verified against the functional oracle \
             ({rejected} rejected by admission control)"
        );
    } else {
        println!("all responses verified against the functional oracle");
    }
    Ok(())
}
