//! The completion slab: one shared, generational structure for every
//! in-flight request (DESIGN.md §10).
//!
//! Before this module, each `submit` allocated an `mpsc::channel` plus
//! a boxed reply `Vec`, and every in-flight wire call burned a
//! short-lived waiter thread bridging `Pending::wait` to the socket.
//! The slab replaces both with the serving analogue of the paper's
//! time-multiplexed FU: instead of replicating per-request control
//! (one channel, one thread each), all requests share one densely
//! packed pool of completion *slots* that are multiplexed over time —
//! the same resource-sharing argument, applied to the request
//! lifecycle instead of the datapath.
//!
//! Shape:
//!
//! * slots live in **shards** (each a mutex + condvar + free list);
//!   a reservation round-robins across shards so submit-side lock
//!   traffic spreads out;
//! * [`CompletionSlab::reserve`] is O(1) and allocation-free in steady
//!   state: freed slots recycle through the shard's free list, and a
//!   slot *owns* its input/output buffers, which keep their capacity
//!   across generations (`FlatBatch::reset` / `resize_rows`);
//! * a slot serves one request *or one whole batch*: `reserve_batch`
//!   costs a single reservation for any row count, workers write each
//!   output row in place (`complete_row_ok`) and the last row flips
//!   the slot to `Ready` — a 1024-row batch is one slot, not 1024
//!   channels;
//! * tickets are thin `{slot, generation}` pairs ([`Ticket`]); the
//!   generation counter defends against ABA reuse — a stale ticket
//!   can never read another request's result;
//! * blockers wait on the shard condvar (skipped entirely when nobody
//!   waits — the `waiters` count gates the notify); event-driven
//!   consumers like the wire reactor register a [`Wake`] doorbell
//!   instead and are rung exactly once, when the slot becomes ready;
//! * dropping a reply handle without collecting it ([`Self::abandon`])
//!   never leaks: an already-ready slot frees immediately, an
//!   in-flight one frees the moment its last row completes.
//!
//! Lock order (must never be violated): engine queue lock → shard
//! lock → nothing. Doorbells are rung *after* the shard lock is
//! released, so a `Wake` implementation may take its own locks freely.

use crate::exec::{ExecError, FlatBatch};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// An event-driven completion listener (the wire reactor's doorbell).
/// Rung exactly once per reservation, when the slot becomes ready;
/// never rung under the shard lock, so implementations may lock.
pub trait Wake: Send + Sync {
    fn ring(&self, tag: u64);
}

/// A doorbell registration: ring `.0` with tag `.1` on completion.
pub type WakeTarget = (Arc<dyn Wake>, u64);

/// A thin handle to one reserved slot. `generation` must match the
/// slot's current generation for any operation — stale tickets (the
/// ABA hazard of slot recycling) are rejected, never misread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ticket {
    slot: u32,
    generation: u32,
}

/// One queued row of a reservation: the engine's queue entries carry
/// these instead of owned input buffers + reply channels.
#[derive(Debug, Clone, Copy)]
pub struct RowTicket {
    pub ticket: Ticket,
    pub row: u32,
}

/// Where a slot is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotState {
    /// On the free list, awaiting reuse.
    Free,
    /// Reserved; rows are queued or executing.
    Pending,
    /// Every row completed; result awaits collection.
    Ready,
}

/// One completion slot. The buffers are never dropped on free — their
/// capacity is the allocation-free steady state.
struct Slot {
    generation: u32,
    state: SlotState,
    /// Rows still awaiting a worker write (counts down to 0 = ready).
    remaining: u32,
    /// The reply handle was dropped; free on completion, wake nobody.
    abandoned: bool,
    /// Request rows, written at reserve time, read by workers.
    inputs: FlatBatch,
    /// Reply rows, written in place by workers (possibly out of row
    /// order when a batch is split across workers).
    output: FlatBatch,
    /// First error wins; a slot-level error fails the whole request.
    error: Option<ExecError>,
    waker: Option<WakeTarget>,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            // Start at 1 so a ticket id is never the all-zeros value.
            generation: 1,
            state: SlotState::Free,
            remaining: 0,
            abandoned: false,
            inputs: FlatBatch::default(),
            output: FlatBatch::default(),
            error: None,
            waker: None,
        }
    }
}

struct ShardSlots {
    slots: Vec<Slot>,
    /// Local indices of free slots (LIFO: reuse the warmest slot).
    free: Vec<u32>,
    /// Blocked `wait_*` callers on this shard; completions skip the
    /// condvar notify entirely when this is zero (the wire path waits
    /// on doorbells, not condvars).
    waiters: usize,
}

struct Shard {
    m: Mutex<ShardSlots>,
    cv: Condvar,
}

/// The shared completion structure (one per engine).
pub struct CompletionSlab {
    shards: Vec<Shard>,
    rr: AtomicUsize,
}

impl CompletionSlab {
    /// `n_shards` bounds submit-side lock spreading; sized from the
    /// worker count by the engine.
    pub fn new(n_shards: usize) -> CompletionSlab {
        let n = n_shards.max(1);
        CompletionSlab {
            shards: (0..n)
                .map(|_| Shard {
                    m: Mutex::new(ShardSlots {
                        slots: Vec::new(),
                        free: Vec::new(),
                        waiters: 0,
                    }),
                    cv: Condvar::new(),
                })
                .collect(),
            rr: AtomicUsize::new(0),
        }
    }

    fn shard_of(&self, slot: u32) -> &Shard {
        &self.shards[slot as usize % self.shards.len()]
    }

    fn local_index(&self, slot: u32) -> usize {
        slot as usize / self.shards.len()
    }

    fn global_id(&self, shard_idx: usize, local: usize) -> u32 {
        (local * self.shards.len() + shard_idx) as u32
    }

    /// Reserve one slot for a single-row request. O(1), allocation-free
    /// once the slab and its buffers are warm. `n_outputs` is the
    /// kernel's output arity (the caller owns the signature).
    pub fn reserve(
        &self,
        inputs: &[i32],
        n_outputs: usize,
        waker: Option<WakeTarget>,
    ) -> Ticket {
        self.reserve_with(1, inputs.len(), n_outputs, waker, |buf| buf.push(inputs))
    }

    /// Reserve one slot for a whole batch: one reservation regardless
    /// of row count, with the output buffer pre-shaped so workers can
    /// write rows in place, in any order.
    pub fn reserve_batch(
        &self,
        batch: &FlatBatch,
        n_outputs: usize,
        waker: Option<WakeTarget>,
    ) -> Ticket {
        self.reserve_with(
            batch.n_rows() as u32,
            batch.arity(),
            n_outputs,
            waker,
            |buf| buf.extend_from_batch(batch),
        )
    }

    fn reserve_with(
        &self,
        rows: u32,
        arity: usize,
        n_outputs: usize,
        waker: Option<WakeTarget>,
        fill: impl FnOnce(&mut FlatBatch),
    ) -> Ticket {
        let shard_idx = self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        let mut st = self.shards[shard_idx].m.lock().unwrap();
        let local = match st.free.pop() {
            Some(i) => i as usize,
            None => {
                st.slots.push(Slot::new());
                st.slots.len() - 1
            }
        };
        let slot = &mut st.slots[local];
        debug_assert_eq!(slot.state, SlotState::Free, "reserved a non-free slot");
        slot.state = SlotState::Pending;
        slot.remaining = rows;
        slot.abandoned = false;
        slot.error = None;
        slot.waker = waker;
        slot.inputs.reset(arity);
        fill(&mut slot.inputs);
        slot.output.reset(n_outputs);
        slot.output.resize_rows(rows as usize);
        let ticket = Ticket {
            slot: self.global_id(shard_idx, local),
            generation: slot.generation,
        };
        // A zero-row reservation has no completion to flip it Ready —
        // it is born Ready (empty output), so a wait can never hang on
        // it. The service layer refuses empty batches before this
        // point; this keeps the engine port safe for future ingress
        // paths too.
        let ready_waker = if rows == 0 {
            slot.state = SlotState::Ready;
            slot.waker.take()
        } else {
            None
        };
        drop(st);
        if let Some((w, tag)) = ready_waker {
            w.ring(tag);
        }
        ticket
    }

    /// Worker-side: run `f` over one queued row's inputs. `None` for a
    /// stale generation (structurally unreachable from the engine —
    /// slots stay allocated until their last row completes).
    pub fn with_inputs<R>(&self, rt: RowTicket, f: impl FnOnce(&[i32]) -> R) -> Option<R> {
        let shard = self.shard_of(rt.ticket.slot);
        let st = shard.m.lock().unwrap();
        let slot = &st.slots[self.local_index(rt.ticket.slot)];
        if slot.generation != rt.ticket.generation {
            debug_assert!(false, "input read through a stale ticket");
            return None;
        }
        Some(f(slot.inputs.row(rt.row as usize)))
    }

    /// Worker-side: write one reply row in place and count it done.
    pub fn complete_row_ok(&self, rt: RowTicket, out_row: &[i32]) {
        self.complete_row(rt, Ok(out_row));
    }

    /// Worker-side: fail one row. The first error recorded fails the
    /// whole slot (per-request for singles; whole-batch for batches,
    /// matching the blocking `call_batch` contract).
    pub fn complete_row_err(&self, rt: RowTicket, err: &ExecError) {
        self.complete_row(rt, Err(err));
    }

    fn complete_row(&self, rt: RowTicket, result: Result<&[i32], &ExecError>) {
        let shard = self.shard_of(rt.ticket.slot);
        let mut st = shard.m.lock().unwrap();
        let local = self.local_index(rt.ticket.slot);
        {
            let slot = &mut st.slots[local];
            if slot.generation != rt.ticket.generation || slot.state != SlotState::Pending {
                debug_assert!(false, "completion through a stale ticket");
                return;
            }
            match result {
                Ok(row) => slot.output.row_mut(rt.row as usize).copy_from_slice(row),
                Err(e) => {
                    if slot.error.is_none() {
                        slot.error = Some(e.clone());
                    }
                }
            }
            slot.remaining -= 1;
            if slot.remaining > 0 {
                return;
            }
        }
        if st.slots[local].abandoned {
            Self::free_slot(&mut st, local);
            return;
        }
        let slot = &mut st.slots[local];
        slot.state = SlotState::Ready;
        let waker = slot.waker.take();
        let has_waiters = st.waiters > 0;
        drop(st);
        if has_waiters {
            shard.cv.notify_all();
        }
        if let Some((w, tag)) = waker {
            w.ring(tag);
        }
    }

    fn free_slot(st: &mut ShardSlots, local: usize) {
        let slot = &mut st.slots[local];
        // The generation bump is the ABA defense: every ticket minted
        // for the old life of this slot is now stale.
        slot.generation = slot.generation.wrapping_add(1);
        slot.state = SlotState::Free;
        slot.remaining = 0;
        slot.abandoned = false;
        slot.error = None;
        slot.waker = None;
        st.free.push(local as u32);
    }

    /// The error a stale ticket observes. Unreachable through the
    /// one-shot service handles (their `done` flag refuses re-takes);
    /// kept structured so a future consumer cannot misread a recycled
    /// slot.
    fn stale_error() -> ExecError {
        ExecError::Backend {
            backend: "engine",
            message: "stale completion ticket (slot was recycled)".to_string(),
        }
    }

    /// Non-blocking single-row take: copies the reply row into `out`
    /// (clearing it first) and frees the slot. `None` = not ready yet.
    pub fn try_take_row(&self, t: Ticket, out: &mut Vec<i32>) -> Option<Result<(), ExecError>> {
        let shard = self.shard_of(t.slot);
        let mut st = shard.m.lock().unwrap();
        self.take_row_locked(&mut st, t, out)
    }

    fn take_row_locked(
        &self,
        st: &mut ShardSlots,
        t: Ticket,
        out: &mut Vec<i32>,
    ) -> Option<Result<(), ExecError>> {
        let local = self.local_index(t.slot);
        let slot = &mut st.slots[local];
        if slot.generation != t.generation {
            return Some(Err(Self::stale_error()));
        }
        if slot.state != SlotState::Ready {
            return None;
        }
        let res = match slot.error.take() {
            Some(e) => Err(e),
            None => {
                out.clear();
                out.extend_from_slice(slot.output.row(0));
                Ok(())
            }
        };
        Self::free_slot(st, local);
        Some(res)
    }

    /// Blocking single-row take, optionally bounded by `deadline`.
    /// `None` = the deadline passed first (the request stays in
    /// flight; take again later).
    pub fn wait_row(
        &self,
        t: Ticket,
        deadline: Option<Instant>,
        out: &mut Vec<i32>,
    ) -> Option<Result<(), ExecError>> {
        let shard = self.shard_of(t.slot);
        let mut st = shard.m.lock().unwrap();
        loop {
            if let Some(r) = self.take_row_locked(&mut st, t, out) {
                return Some(r);
            }
            st = match Self::park(shard, st, deadline) {
                Some(g) => g,
                None => return None,
            };
        }
    }

    /// Non-blocking whole-batch take: copies every reply row into
    /// `out` (reshaped) and frees the slot. `None` = not ready yet.
    pub fn try_take_batch(
        &self,
        t: Ticket,
        out: &mut FlatBatch,
    ) -> Option<Result<(), ExecError>> {
        let shard = self.shard_of(t.slot);
        let mut st = shard.m.lock().unwrap();
        self.take_batch_locked(&mut st, t, out)
    }

    fn take_batch_locked(
        &self,
        st: &mut ShardSlots,
        t: Ticket,
        out: &mut FlatBatch,
    ) -> Option<Result<(), ExecError>> {
        let local = self.local_index(t.slot);
        let slot = &mut st.slots[local];
        if slot.generation != t.generation {
            return Some(Err(Self::stale_error()));
        }
        if slot.state != SlotState::Ready {
            return None;
        }
        let res = match slot.error.take() {
            Some(e) => Err(e),
            None => {
                out.reset(slot.output.arity());
                out.extend_from_batch(&slot.output);
                Ok(())
            }
        };
        Self::free_slot(st, local);
        Some(res)
    }

    /// Blocking whole-batch take, optionally bounded by `deadline`.
    pub fn wait_batch(
        &self,
        t: Ticket,
        deadline: Option<Instant>,
        out: &mut FlatBatch,
    ) -> Option<Result<(), ExecError>> {
        let shard = self.shard_of(t.slot);
        let mut st = shard.m.lock().unwrap();
        loop {
            if let Some(r) = self.take_batch_locked(&mut st, t, out) {
                return Some(r);
            }
            st = match Self::park(shard, st, deadline) {
                Some(g) => g,
                None => return None,
            };
        }
    }

    /// One condvar park, registered in the shard's waiter count so
    /// completions know whether a notify is needed at all. `None` =
    /// the deadline passed.
    fn park<'a>(
        shard: &'a Shard,
        mut st: std::sync::MutexGuard<'a, ShardSlots>,
        deadline: Option<Instant>,
    ) -> Option<std::sync::MutexGuard<'a, ShardSlots>> {
        match deadline {
            None => {
                st.waiters += 1;
                let mut g = shard.cv.wait(st).unwrap();
                g.waiters -= 1;
                Some(g)
            }
            Some(d) => {
                let now = Instant::now();
                if now >= d {
                    return None;
                }
                st.waiters += 1;
                let (mut g, _timed_out) = shard.cv.wait_timeout(st, d - now).unwrap();
                g.waiters -= 1;
                Some(g)
            }
        }
    }

    /// The reply handle was dropped without collecting. Ready slots
    /// free immediately; in-flight ones free when their last row
    /// completes (workers still own the slot's buffers until then).
    pub fn abandon(&self, t: Ticket) {
        let shard = self.shard_of(t.slot);
        let mut st = shard.m.lock().unwrap();
        let local = self.local_index(t.slot);
        {
            let slot = &mut st.slots[local];
            if slot.generation != t.generation {
                return;
            }
            if slot.state == SlotState::Pending {
                slot.abandoned = true;
                slot.waker = None;
                return;
            }
        }
        if st.slots[local].state == SlotState::Ready {
            Self::free_slot(&mut st, local);
        }
    }

    /// Safety net for engine teardown: any slot still pending after
    /// the workers have been joined can never complete normally (a
    /// worker died mid-batch). Fail them all with `err` so no waiter
    /// blocks forever. Drain-on-shutdown makes this a no-op in every
    /// healthy shutdown.
    pub fn fail_all_pending(&self, err: &ExecError) {
        for shard in &self.shards {
            let mut wakers: Vec<WakeTarget> = Vec::new();
            {
                let mut st = shard.m.lock().unwrap();
                let pending: Vec<usize> = st
                    .slots
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| s.state == SlotState::Pending)
                    .map(|(i, _)| i)
                    .collect();
                for local in pending {
                    if st.slots[local].abandoned {
                        Self::free_slot(&mut st, local);
                        continue;
                    }
                    let slot = &mut st.slots[local];
                    slot.state = SlotState::Ready;
                    slot.remaining = 0;
                    if slot.error.is_none() {
                        slot.error = Some(err.clone());
                    }
                    if let Some(w) = slot.waker.take() {
                        wakers.push(w);
                    }
                }
            }
            shard.cv.notify_all();
            for (w, tag) in wakers {
                w.ring(tag);
            }
        }
    }

    /// Slots currently reserved (pending or ready) — telemetry and the
    /// leak regression tests.
    pub fn live_slots(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                let st = s.m.lock().unwrap();
                st.slots.len() - st.free.len()
            })
            .sum()
    }

    /// Total slots ever grown (free + live) — the steady-state bound.
    pub fn capacity(&self) -> usize {
        self.shards.iter().map(|s| s.m.lock().unwrap().slots.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn row_of(t: Ticket, row: u32) -> RowTicket {
        RowTicket { ticket: t, row }
    }

    #[test]
    fn single_row_round_trip_and_recycling() {
        let slab = CompletionSlab::new(2);
        let mut out = Vec::new();
        for i in 0..10i32 {
            let t = slab.reserve(&[i, i + 1], 1, None);
            assert_eq!(slab.try_take_row(t, &mut out), None, "not ready yet");
            slab.with_inputs(row_of(t, 0), |row| assert_eq!(row, &[i, i + 1]))
                .expect("live ticket");
            slab.complete_row_ok(row_of(t, 0), &[i * 2]);
            assert_eq!(slab.try_take_row(t, &mut out), Some(Ok(())));
            assert_eq!(out, vec![i * 2]);
        }
        // All ten requests recycled through at most 2 slots (one per
        // shard the round-robin touched).
        assert!(slab.capacity() <= 2, "slots leaked: {}", slab.capacity());
        assert_eq!(slab.live_slots(), 0);
    }

    #[test]
    fn batch_rows_complete_out_of_order() {
        let slab = CompletionSlab::new(1);
        let batch = FlatBatch::from_rows(2, &[vec![1, 2], vec![3, 4], vec![5, 6]]);
        let t = slab.reserve_batch(&batch, 1, None);
        slab.complete_row_ok(row_of(t, 2), &[60]);
        slab.complete_row_ok(row_of(t, 0), &[20]);
        let mut out = FlatBatch::default();
        assert_eq!(slab.try_take_batch(t, &mut out), None, "one row missing");
        slab.complete_row_ok(row_of(t, 1), &[40]);
        assert_eq!(slab.wait_batch(t, None, &mut out), Some(Ok(())));
        assert_eq!(out.to_rows(), vec![vec![20], vec![40], vec![60]]);
    }

    #[test]
    fn zero_row_reservation_is_born_ready() {
        // No row will ever complete a 0-row slot; it must be Ready at
        // reservation so no waiter can hang on it.
        let slab = CompletionSlab::new(1);
        let t = slab.reserve_batch(&FlatBatch::new(3), 1, None);
        let mut out = FlatBatch::default();
        assert_eq!(slab.try_take_batch(t, &mut out), Some(Ok(())));
        assert!(out.is_empty());
        assert_eq!(slab.live_slots(), 0);
    }

    #[test]
    fn stale_generation_is_refused() {
        let slab = CompletionSlab::new(1);
        let t1 = slab.reserve(&[7], 1, None);
        slab.complete_row_ok(row_of(t1, 0), &[1]);
        let mut out = Vec::new();
        assert_eq!(slab.try_take_row(t1, &mut out), Some(Ok(())));
        // The slot recycles; the old ticket is now a different life.
        let t2 = slab.reserve(&[8], 1, None);
        assert_ne!(t1, t2);
        slab.complete_row_ok(row_of(t2, 0), &[2]);
        assert!(matches!(slab.try_take_row(t1, &mut out), Some(Err(_))));
        assert_eq!(slab.try_take_row(t2, &mut out), Some(Ok(())));
        assert_eq!(out, vec![2]);
    }

    #[test]
    fn first_error_fails_the_slot() {
        let slab = CompletionSlab::new(1);
        let batch = FlatBatch::from_rows(1, &[vec![1], vec![2]]);
        let t = slab.reserve_batch(&batch, 1, None);
        let err = ExecError::Backend {
            backend: "test",
            message: "boom".to_string(),
        };
        slab.complete_row_err(row_of(t, 0), &err);
        slab.complete_row_ok(row_of(t, 1), &[9]);
        let mut out = FlatBatch::default();
        match slab.wait_batch(t, None, &mut out) {
            Some(Err(ExecError::Backend { message, .. })) => assert_eq!(message, "boom"),
            other => panic!("expected the recorded error, got {other:?}"),
        }
        assert_eq!(slab.live_slots(), 0);
    }

    #[test]
    fn abandon_frees_in_both_orders() {
        let slab = CompletionSlab::new(1);
        // Abandon before completion: the worker's last row frees.
        let t = slab.reserve(&[1], 1, None);
        slab.abandon(t);
        assert_eq!(slab.live_slots(), 1, "slot still owned by the worker");
        slab.complete_row_ok(row_of(t, 0), &[5]);
        assert_eq!(slab.live_slots(), 0);
        // Abandon after completion: frees immediately.
        let t = slab.reserve(&[2], 1, None);
        slab.complete_row_ok(row_of(t, 0), &[6]);
        assert_eq!(slab.live_slots(), 1);
        slab.abandon(t);
        assert_eq!(slab.live_slots(), 0);
        // Double-abandon (stale by then) is harmless.
        slab.abandon(t);
        assert_eq!(slab.live_slots(), 0);
    }

    #[test]
    fn deadline_wait_leaves_the_request_in_flight() {
        let slab = CompletionSlab::new(1);
        let t = slab.reserve(&[1], 1, None);
        let mut out = Vec::new();
        let deadline = Instant::now() + std::time::Duration::from_millis(10);
        assert_eq!(slab.wait_row(t, Some(deadline), &mut out), None, "timed out");
        slab.complete_row_ok(row_of(t, 0), &[3]);
        assert_eq!(slab.wait_row(t, None, &mut out), Some(Ok(())));
        assert_eq!(out, vec![3]);
    }

    #[test]
    fn doorbell_rings_once_on_ready() {
        struct Bell(AtomicU64);
        impl Wake for Bell {
            fn ring(&self, tag: u64) {
                self.0.fetch_add(tag, Ordering::SeqCst);
            }
        }
        let slab = CompletionSlab::new(1);
        let bell = Arc::new(Bell(AtomicU64::new(0)));
        let waker: Arc<dyn Wake> = Arc::clone(&bell);
        let batch = FlatBatch::from_rows(1, &[vec![1], vec![2]]);
        let t = slab.reserve_batch(&batch, 1, Some((waker, 7)));
        slab.complete_row_ok(row_of(t, 0), &[1]);
        assert_eq!(bell.0.load(Ordering::SeqCst), 0, "not ready yet");
        slab.complete_row_ok(row_of(t, 1), &[2]);
        assert_eq!(bell.0.load(Ordering::SeqCst), 7, "rung once with the tag");
        let mut out = FlatBatch::default();
        assert_eq!(slab.try_take_batch(t, &mut out), Some(Ok(())));
    }

    #[test]
    fn fail_all_pending_wakes_waiters_with_the_error() {
        let slab = Arc::new(CompletionSlab::new(2));
        let t = slab.reserve(&[1], 1, None);
        let slab2 = Arc::clone(&slab);
        let waiter = std::thread::spawn(move || {
            let mut out = Vec::new();
            slab2.wait_row(t, None, &mut out).unwrap()
        });
        // Give the waiter time to park, then fail everything.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let err = ExecError::Backend {
            backend: "engine",
            message: "worker lost".to_string(),
        };
        slab.fail_all_pending(&err);
        match waiter.join().unwrap() {
            Err(ExecError::Backend { message, .. }) => assert!(message.contains("worker lost")),
            other => panic!("expected the teardown error, got {other:?}"),
        }
    }
}
