//! The completion slab: one shared, generational structure for every
//! in-flight request (DESIGN.md §10).
//!
//! Before this module, each `submit` allocated an `mpsc::channel` plus
//! a boxed reply `Vec`, and every in-flight wire call burned a
//! short-lived waiter thread bridging `Pending::wait` to the socket.
//! The slab replaces both with the serving analogue of the paper's
//! time-multiplexed FU: instead of replicating per-request control
//! (one channel, one thread each), all requests share one densely
//! packed pool of completion *slots* that are multiplexed over time —
//! the same resource-sharing argument, applied to the request
//! lifecycle instead of the datapath.
//!
//! Shape:
//!
//! * slots live in **shards** (each a mutex + condvar + free list);
//!   a reservation round-robins across shards so submit-side lock
//!   traffic spreads out;
//! * [`CompletionSlab::reserve`] is O(1) and allocation-free in steady
//!   state: freed slots recycle through the shard's free list, and a
//!   slot *owns* its input/output buffers, which keep their capacity
//!   across generations (`FlatBatch::reset` / `resize_rows`);
//! * a slot serves one request *or one whole batch*: `reserve_batch`
//!   costs a single reservation for any row count, workers write each
//!   output row in place (`complete_spans_ok`) and the last row flips
//!   the slot to `Ready` — a 1024-row batch is one slot, not 1024
//!   channels;
//! * tickets are thin `{slot, generation}` pairs ([`Ticket`]); the
//!   generation counter defends against ABA reuse — a stale ticket
//!   can never read another request's result;
//! * blockers wait on the shard condvar (skipped entirely when nobody
//!   waits — the `waiters` count gates the notify); event-driven
//!   consumers like the wire reactor register a [`Wake`] doorbell
//!   instead and are rung exactly once, when the slot becomes ready;
//! * dropping a reply handle without collecting it ([`Self::abandon`])
//!   never leaks: an already-ready slot frees immediately, an
//!   in-flight one frees the moment its last row completes;
//! * workers move whole **spans** of rows per lock trip
//!   ([`CompletionSlab::gather_spans`] /
//!   [`CompletionSlab::complete_spans_ok`]): a dispatch run costs one
//!   shard-lock round-trip per run of same-shard spans instead of two
//!   per row, and a batch split across workers recombines here by row
//!   index (rows complete in any order);
//! * recycled slots are trimmed toward a **high-watermark**
//!   ([`CompletionSlab::with_trim`]): one 64k-row burst does not pin
//!   its peak buffer capacity on a pooled slot forever, while
//!   steady-state traffic under the watermark never re-allocates.
//!
//! Lock order (must never be violated): engine queue lock → shard
//! lock → nothing. Doorbells are rung *after* the shard lock is
//! released, so a `Wake` implementation may take its own locks freely.
//! Bulk span operations lock **one shard at a time** (never two at
//! once), so two workers completing interleaved spans cannot deadlock.

use super::queue::SpanToken;
use crate::exec::{ExecError, FlatBatch};
use crate::util::sync::LockExt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// An event-driven completion listener (the wire reactor's doorbell).
/// Rung exactly once per reservation, when the slot becomes ready;
/// never rung under the shard lock, so implementations may lock.
pub(crate) trait Wake: Send + Sync {
    fn ring(&self, tag: u64);
}

/// A doorbell registration: ring `.0` with tag `.1` on completion.
pub(crate) type WakeTarget = (Arc<dyn Wake>, u64);

/// A thin handle to one reserved slot. `generation` must match the
/// slot's current generation for any operation — stale tickets (the
/// ABA hazard of slot recycling) are rejected, never misread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Ticket {
    slot: u32,
    generation: u32,
}

/// A contiguous run of rows of one reservation — what the engine's
/// queues carry since the span refactor. A whole-batch submit is one
/// span; the queue splits it at row boundaries when a worker's budget
/// runs out, and the pieces recombine in the slot by row index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct RowSpan {
    pub(crate) ticket: Ticket,
    /// First row of the run within the reservation.
    pub(crate) row: u32,
    /// Rows in the run (≥ 1 once queued).
    pub(crate) len: u32,
}

impl SpanToken for RowSpan {
    fn rows(&self) -> usize {
        self.len as usize
    }

    fn take_front(&mut self, n: usize) -> RowSpan {
        debug_assert!(n > 0 && n < self.len as usize, "split out of range");
        let head = RowSpan {
            ticket: self.ticket,
            row: self.row,
            len: n as u32,
        };
        self.row += n as u32;
        self.len -= n as u32;
        head
    }
}

/// Where a slot is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotState {
    /// On the free list, awaiting reuse.
    Free,
    /// Reserved; rows are queued or executing.
    Pending,
    /// Every row completed; result awaits collection.
    Ready,
}

/// One completion slot. The buffers are never dropped on free — their
/// capacity is the allocation-free steady state.
struct Slot {
    generation: u32,
    state: SlotState,
    /// Rows still awaiting a worker write (counts down to 0 = ready).
    remaining: u32,
    /// The reply handle was dropped; free on completion, wake nobody.
    abandoned: bool,
    /// Request rows, written at reserve time, read by workers.
    inputs: FlatBatch,
    /// Reply rows, written in place by workers (possibly out of row
    /// order when a batch is split across workers).
    output: FlatBatch,
    /// First error wins; a slot-level error fails the whole request.
    error: Option<ExecError>,
    waker: Option<WakeTarget>,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            // Start at 1 so a ticket id is never the all-zeros value.
            generation: 1,
            state: SlotState::Free,
            remaining: 0,
            abandoned: false,
            inputs: FlatBatch::default(),
            output: FlatBatch::default(),
            error: None,
            waker: None,
        }
    }
}

struct ShardSlots {
    slots: Vec<Slot>,
    /// Local indices of free slots (LIFO: reuse the warmest slot).
    free: Vec<u32>,
    /// Blocked `wait_*` callers on this shard; completions skip the
    /// condvar notify entirely when this is zero (the wire path waits
    /// on doorbells, not condvars).
    waiters: usize,
    /// High-watermark (in `i32` words) a recycled slot's buffers are
    /// trimmed toward on free. Buffers at or under it are untouched.
    trim_words: usize,
}

struct Shard {
    m: Mutex<ShardSlots>,
    cv: Condvar,
}

/// The shared completion structure (one per engine).
pub(crate) struct CompletionSlab {
    shards: Vec<Shard>,
    rr: AtomicUsize,
}

/// Default slot-buffer watermark: 64 Ki words (256 KiB) per buffer —
/// far above any steady serving batch, so trims only ever fire after
/// a genuinely oversized burst.
pub(crate) const DEFAULT_TRIM_WORDS: usize = 1 << 16;

impl CompletionSlab {
    /// `n_shards` bounds submit-side lock spreading; sized from the
    /// worker count by the engine. Uses [`DEFAULT_TRIM_WORDS`].
    pub(crate) fn new(n_shards: usize) -> CompletionSlab {
        CompletionSlab::with_trim(n_shards, DEFAULT_TRIM_WORDS)
    }

    /// Like [`CompletionSlab::new`] with an explicit buffer watermark:
    /// freed slots shrink input/output buffers larger than
    /// `trim_words` back down, so one burst cannot pin its peak
    /// allocation on the pool forever.
    pub(crate) fn with_trim(n_shards: usize, trim_words: usize) -> CompletionSlab {
        let n = n_shards.max(1);
        CompletionSlab {
            shards: (0..n)
                .map(|_| Shard {
                    m: Mutex::new(ShardSlots {
                        slots: Vec::new(),
                        free: Vec::new(),
                        waiters: 0,
                        trim_words,
                    }),
                    cv: Condvar::new(),
                })
                .collect(),
            rr: AtomicUsize::new(0),
        }
    }

    fn shard_index(&self, slot: u32) -> usize {
        slot as usize % self.shards.len()
    }

    fn shard_of(&self, slot: u32) -> &Shard {
        &self.shards[self.shard_index(slot)]
    }

    fn local_index(&self, slot: u32) -> usize {
        slot as usize / self.shards.len()
    }

    fn global_id(&self, shard_idx: usize, local: usize) -> u32 {
        (local * self.shards.len() + shard_idx) as u32
    }

    /// Reserve one slot for a single-row request. O(1), allocation-free
    /// once the slab and its buffers are warm. `n_outputs` is the
    /// kernel's output arity (the caller owns the signature).
    pub(crate) fn reserve(
        &self,
        inputs: &[i32],
        n_outputs: usize,
        waker: Option<WakeTarget>,
    ) -> Ticket {
        self.reserve_with(1, inputs.len(), n_outputs, waker, |buf| buf.push(inputs))
    }

    /// Reserve one slot for a whole batch: one reservation regardless
    /// of row count, with the output buffer pre-shaped so workers can
    /// write rows in place, in any order.
    pub(crate) fn reserve_batch(
        &self,
        batch: &FlatBatch,
        n_outputs: usize,
        waker: Option<WakeTarget>,
    ) -> Ticket {
        self.reserve_with(
            batch.n_rows() as u32,
            batch.arity(),
            n_outputs,
            waker,
            |buf| buf.extend_from_batch(batch),
        )
    }

    fn reserve_with(
        &self,
        rows: u32,
        arity: usize,
        n_outputs: usize,
        waker: Option<WakeTarget>,
        fill: impl FnOnce(&mut FlatBatch),
    ) -> Ticket {
        // relaxed-ok: rotation cursor; any interleaving only changes
        // which shard a ticket lands in, never correctness.
        let shard_idx = self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        let mut st = self.shards[shard_idx].m.lock_unpoisoned();
        let local = match st.free.pop() {
            Some(i) => i as usize,
            None => {
                st.slots.push(Slot::new());
                st.slots.len() - 1
            }
        };
        let slot = &mut st.slots[local];
        debug_assert_eq!(slot.state, SlotState::Free, "reserved a non-free slot");
        slot.state = SlotState::Pending;
        slot.remaining = rows;
        slot.abandoned = false;
        slot.error = None;
        slot.waker = waker;
        slot.inputs.reset(arity);
        fill(&mut slot.inputs);
        slot.output.reset(n_outputs);
        slot.output.resize_rows(rows as usize);
        let ticket = Ticket {
            slot: self.global_id(shard_idx, local),
            generation: slot.generation,
        };
        // A zero-row reservation has no completion to flip it Ready —
        // it is born Ready (empty output), so a wait can never hang on
        // it. The service layer refuses empty batches before this
        // point; this keeps the engine port safe for future ingress
        // paths too.
        let ready_waker = if rows == 0 {
            slot.state = SlotState::Ready;
            slot.waker.take()
        } else {
            None
        };
        drop(st);
        if let Some((w, tag)) = ready_waker {
            w.ring(tag);
        }
        ticket
    }

    /// Worker-side bulk gather: append every span's input rows to
    /// `out`, in span order, taking **one shard-lock round-trip per
    /// run of same-shard spans** instead of one per row. Spans whose
    /// slot cannot be gathered — stale generation (structurally
    /// unreachable from the engine) or an input arity that does not
    /// match `out` (a malformed ingress write) — contribute no rows
    /// and are pushed to `bad` for the caller to fail; `out`'s rows
    /// align with the surviving spans, span by span.
    pub(crate) fn gather_spans(&self, spans: &[RowSpan], out: &mut FlatBatch, bad: &mut Vec<RowSpan>) {
        let mut i = 0;
        while i < spans.len() {
            let shard_idx = self.shard_index(spans[i].ticket.slot);
            let st = self.shards[shard_idx].m.lock_unpoisoned();
            while i < spans.len() && self.shard_index(spans[i].ticket.slot) == shard_idx {
                let sp = spans[i];
                i += 1;
                let slot = &st.slots[self.local_index(sp.ticket.slot)];
                if slot.generation != sp.ticket.generation || slot.inputs.arity() != out.arity()
                {
                    debug_assert_eq!(
                        slot.generation, sp.ticket.generation,
                        "gather through a stale span"
                    );
                    bad.push(sp);
                    continue;
                }
                let base = sp.row as usize;
                for r in 0..sp.len as usize {
                    out.push(slot.inputs.row(base + r));
                }
            }
        }
    }

    /// Worker-side bulk completion: write each span's reply rows (read
    /// from consecutive rows of `rows`, in span order — exactly the
    /// layout [`Self::gather_spans`] produced and the backend
    /// preserved) into its slot and count them done, one shard-lock
    /// round-trip per run of same-shard spans.
    pub(crate) fn complete_spans_ok(&self, spans: &[RowSpan], rows: &FlatBatch) {
        self.complete_spans(spans, Ok(rows));
    }

    /// Worker-side bulk failure: fail every span's slot with `err`
    /// (first error wins per slot), one lock trip per same-shard run.
    pub(crate) fn complete_spans_err(&self, spans: &[RowSpan], err: &ExecError) {
        self.complete_spans(spans, Err(err));
    }

    fn complete_spans(&self, spans: &[RowSpan], result: Result<&FlatBatch, &ExecError>) {
        // Doorbells collected under the lock, rung after it drops.
        // Stays heap-free when no span carries a waker (the blocking
        // in-process path — the audited steady state).
        let mut ring: Vec<WakeTarget> = Vec::new();
        let mut i = 0;
        let mut out_row = 0usize;
        while i < spans.len() {
            let shard_idx = self.shard_index(spans[i].ticket.slot);
            let shard = &self.shards[shard_idx];
            let mut st = shard.m.lock_unpoisoned();
            let mut notify = false;
            while i < spans.len() && self.shard_index(spans[i].ticket.slot) == shard_idx {
                let sp = spans[i];
                i += 1;
                let local = self.local_index(sp.ticket.slot);
                let done = {
                    let slot = &mut st.slots[local];
                    if slot.generation != sp.ticket.generation
                        || slot.state != SlotState::Pending
                    {
                        debug_assert!(false, "completion through a stale span");
                        if result.is_ok() {
                            out_row += sp.len as usize;
                        }
                        continue;
                    }
                    match result {
                        Ok(rows) => {
                            let base = sp.row as usize;
                            for r in 0..sp.len as usize {
                                slot.output
                                    .row_mut(base + r)
                                    .copy_from_slice(rows.row(out_row + r));
                            }
                            out_row += sp.len as usize;
                        }
                        Err(e) => {
                            if slot.error.is_none() {
                                slot.error = Some(e.clone());
                            }
                        }
                    }
                    debug_assert!(slot.remaining >= sp.len, "span over-completes its slot");
                    slot.remaining -= sp.len;
                    slot.remaining == 0
                };
                if done {
                    if st.slots[local].abandoned {
                        Self::free_slot(&mut st, local);
                    } else {
                        let slot = &mut st.slots[local];
                        slot.state = SlotState::Ready;
                        if let Some(w) = slot.waker.take() {
                            ring.push(w);
                        }
                        notify = true;
                    }
                }
            }
            let has_waiters = st.waiters > 0;
            drop(st);
            if notify && has_waiters {
                shard.cv.notify_all();
            }
            for (w, tag) in ring.drain(..) {
                w.ring(tag);
            }
        }
    }

    fn free_slot(st: &mut ShardSlots, local: usize) {
        let trim = st.trim_words;
        let slot = &mut st.slots[local];
        // The generation bump is the ABA defense: every ticket minted
        // for the old life of this slot is now stale.
        slot.generation = slot.generation.wrapping_add(1);
        slot.state = SlotState::Free;
        slot.remaining = 0;
        slot.abandoned = false;
        slot.error = None;
        slot.waker = None;
        // Watermark trim: a no-op for every buffer at or under the
        // watermark (the allocation-free steady state), a shrink for
        // burst-sized ones so the pool's footprint decays.
        slot.inputs.trim_to_words(trim);
        slot.output.trim_to_words(trim);
        st.free.push(local as u32);
    }

    /// The error a stale ticket observes. Unreachable through the
    /// one-shot service handles (their `done` flag refuses re-takes);
    /// kept structured so a future consumer cannot misread a recycled
    /// slot.
    fn stale_error() -> ExecError {
        ExecError::Backend {
            backend: "engine",
            message: "stale completion ticket (slot was recycled)".to_string(),
        }
    }

    /// Non-blocking single-row take: copies the reply row into `out`
    /// (clearing it first) and frees the slot. `None` = not ready yet.
    pub(crate) fn try_take_row(&self, t: Ticket, out: &mut Vec<i32>) -> Option<Result<(), ExecError>> {
        let shard = self.shard_of(t.slot);
        let mut st = shard.m.lock_unpoisoned();
        self.take_row_locked(&mut st, t, out)
    }

    fn take_row_locked(
        &self,
        st: &mut ShardSlots,
        t: Ticket,
        out: &mut Vec<i32>,
    ) -> Option<Result<(), ExecError>> {
        let local = self.local_index(t.slot);
        let slot = &mut st.slots[local];
        if slot.generation != t.generation {
            return Some(Err(Self::stale_error()));
        }
        if slot.state != SlotState::Ready {
            return None;
        }
        let res = match slot.error.take() {
            Some(e) => Err(e),
            None => {
                out.clear();
                out.extend_from_slice(slot.output.row(0));
                Ok(())
            }
        };
        Self::free_slot(st, local);
        Some(res)
    }

    /// Blocking single-row take, optionally bounded by `deadline`.
    /// `None` = the deadline passed first (the request stays in
    /// flight; take again later).
    pub(crate) fn wait_row(
        &self,
        t: Ticket,
        deadline: Option<Instant>,
        out: &mut Vec<i32>,
    ) -> Option<Result<(), ExecError>> {
        let shard = self.shard_of(t.slot);
        let mut st = shard.m.lock_unpoisoned();
        loop {
            if let Some(r) = self.take_row_locked(&mut st, t, out) {
                return Some(r);
            }
            st = match Self::park(shard, st, deadline) {
                Some(g) => g,
                None => return None,
            };
        }
    }

    /// Non-blocking whole-batch take: copies every reply row into
    /// `out` (reshaped) and frees the slot. `None` = not ready yet.
    pub(crate) fn try_take_batch(
        &self,
        t: Ticket,
        out: &mut FlatBatch,
    ) -> Option<Result<(), ExecError>> {
        let shard = self.shard_of(t.slot);
        let mut st = shard.m.lock_unpoisoned();
        self.take_batch_locked(&mut st, t, out)
    }

    fn take_batch_locked(
        &self,
        st: &mut ShardSlots,
        t: Ticket,
        out: &mut FlatBatch,
    ) -> Option<Result<(), ExecError>> {
        let local = self.local_index(t.slot);
        let slot = &mut st.slots[local];
        if slot.generation != t.generation {
            return Some(Err(Self::stale_error()));
        }
        if slot.state != SlotState::Ready {
            return None;
        }
        let res = match slot.error.take() {
            Some(e) => Err(e),
            None => {
                out.reset(slot.output.arity());
                out.extend_from_batch(&slot.output);
                Ok(())
            }
        };
        Self::free_slot(st, local);
        Some(res)
    }

    /// Blocking whole-batch take, optionally bounded by `deadline`.
    pub(crate) fn wait_batch(
        &self,
        t: Ticket,
        deadline: Option<Instant>,
        out: &mut FlatBatch,
    ) -> Option<Result<(), ExecError>> {
        let shard = self.shard_of(t.slot);
        let mut st = shard.m.lock_unpoisoned();
        loop {
            if let Some(r) = self.take_batch_locked(&mut st, t, out) {
                return Some(r);
            }
            st = match Self::park(shard, st, deadline) {
                Some(g) => g,
                None => return None,
            };
        }
    }

    /// One condvar park, registered in the shard's waiter count so
    /// completions know whether a notify is needed at all. `None` =
    /// the deadline passed.
    fn park<'a>(
        shard: &'a Shard,
        mut st: std::sync::MutexGuard<'a, ShardSlots>,
        deadline: Option<Instant>,
    ) -> Option<std::sync::MutexGuard<'a, ShardSlots>> {
        match deadline {
            None => {
                st.waiters += 1;
                let mut g = shard.cv.wait(st).unwrap();
                g.waiters -= 1;
                Some(g)
            }
            Some(d) => {
                let now = Instant::now();
                if now >= d {
                    return None;
                }
                st.waiters += 1;
                let (mut g, _timed_out) = shard.cv.wait_timeout(st, d - now).unwrap();
                g.waiters -= 1;
                Some(g)
            }
        }
    }

    /// The reply handle was dropped without collecting. Ready slots
    /// free immediately; in-flight ones free when their last row
    /// completes (workers still own the slot's buffers until then).
    pub(crate) fn abandon(&self, t: Ticket) {
        let shard = self.shard_of(t.slot);
        let mut st = shard.m.lock_unpoisoned();
        let local = self.local_index(t.slot);
        {
            let slot = &mut st.slots[local];
            if slot.generation != t.generation {
                return;
            }
            if slot.state == SlotState::Pending {
                slot.abandoned = true;
                slot.waker = None;
                return;
            }
        }
        if st.slots[local].state == SlotState::Ready {
            Self::free_slot(&mut st, local);
        }
    }

    /// Cancel a reservation whose still-queued rows have already been
    /// evicted from the engine queues: `queued_rows_removed` of the
    /// slot's outstanding rows will never see a worker write, so they
    /// are discounted here. Rows a worker already holds (gathered but
    /// not yet completed) finish normally into the abandoned slot,
    /// and the last of them frees it. A Ready slot frees immediately
    /// (the result is discarded); a stale ticket is a no-op. Returns
    /// whether the ticket was live.
    pub(crate) fn cancel(&self, t: Ticket, queued_rows_removed: u32) -> bool {
        let shard = self.shard_of(t.slot);
        let mut st = shard.m.lock_unpoisoned();
        let local = self.local_index(t.slot);
        {
            let slot = &mut st.slots[local];
            if slot.generation != t.generation {
                return false;
            }
            if slot.state == SlotState::Pending {
                debug_assert!(
                    slot.remaining >= queued_rows_removed,
                    "cancel removes more rows than remain"
                );
                slot.remaining = slot.remaining.saturating_sub(queued_rows_removed);
                slot.abandoned = true;
                slot.waker = None;
                if slot.remaining > 0 {
                    // A worker still owns some rows; the last completion
                    // frees the abandoned slot.
                    return true;
                }
            }
        }
        Self::free_slot(&mut st, local);
        true
    }

    /// Safety net for engine teardown: any slot still pending after
    /// the workers have been joined can never complete normally (a
    /// worker died mid-batch). Fail them all with `err` so no waiter
    /// blocks forever. Drain-on-shutdown makes this a no-op in every
    /// healthy shutdown.
    pub(crate) fn fail_all_pending(&self, err: &ExecError) {
        for shard in &self.shards {
            let mut wakers: Vec<WakeTarget> = Vec::new();
            {
                let mut st = shard.m.lock_unpoisoned();
                let pending: Vec<usize> = st
                    .slots
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| s.state == SlotState::Pending)
                    .map(|(i, _)| i)
                    .collect();
                for local in pending {
                    if st.slots[local].abandoned {
                        Self::free_slot(&mut st, local);
                        continue;
                    }
                    let slot = &mut st.slots[local];
                    slot.state = SlotState::Ready;
                    slot.remaining = 0;
                    if slot.error.is_none() {
                        slot.error = Some(err.clone());
                    }
                    if let Some(w) = slot.waker.take() {
                        wakers.push(w);
                    }
                }
            }
            shard.cv.notify_all();
            for (w, tag) in wakers {
                w.ring(tag);
            }
        }
    }

    /// Slots currently reserved (pending or ready) — telemetry and the
    /// leak regression tests.
    pub(crate) fn live_slots(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                let st = s.m.lock_unpoisoned();
                st.slots.len() - st.free.len()
            })
            .sum()
    }

    /// Total slots ever grown (free + live) — the steady-state bound.
    pub(crate) fn capacity(&self) -> usize {
        self.shards.iter().map(|s| s.m.lock_unpoisoned().slots.len()).sum()
    }

    /// Total `i32` words of buffer capacity owned by every slot
    /// (inputs + outputs) — the watermark-trim regression probe.
    pub(crate) fn buffer_capacity_words(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                let st = s.m.lock_unpoisoned();
                st.slots
                    .iter()
                    .map(|sl| sl.inputs.capacity_words() + sl.output.capacity_words())
                    .sum::<usize>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn span_of(t: Ticket, row: u32, len: u32) -> RowSpan {
        RowSpan {
            ticket: t,
            row,
            len,
        }
    }

    /// Complete one row through the span path (what the engine's
    /// single-submit spans reduce to).
    fn complete_one(slab: &CompletionSlab, t: Ticket, row: u32, out_row: Vec<i32>) {
        let rows = FlatBatch::from_rows(out_row.len(), &[out_row]);
        slab.complete_spans_ok(&[span_of(t, row, 1)], &rows);
    }

    #[test]
    fn single_row_round_trip_and_recycling() {
        let slab = CompletionSlab::new(2);
        let mut out = Vec::new();
        for i in 0..10i32 {
            let t = slab.reserve(&[i, i + 1], 1, None);
            assert_eq!(slab.try_take_row(t, &mut out), None, "not ready yet");
            let mut inputs = FlatBatch::new(2);
            let mut bad = Vec::new();
            slab.gather_spans(&[span_of(t, 0, 1)], &mut inputs, &mut bad);
            assert!(bad.is_empty());
            assert_eq!(inputs.to_rows(), vec![vec![i, i + 1]]);
            complete_one(&slab, t, 0, vec![i * 2]);
            assert_eq!(slab.try_take_row(t, &mut out), Some(Ok(())));
            assert_eq!(out, vec![i * 2]);
        }
        // All ten requests recycled through at most 2 slots (one per
        // shard the round-robin touched).
        assert!(slab.capacity() <= 2, "slots leaked: {}", slab.capacity());
        assert_eq!(slab.live_slots(), 0);
    }

    #[test]
    fn batch_rows_complete_out_of_order() {
        let slab = CompletionSlab::new(1);
        let batch = FlatBatch::from_rows(2, &[vec![1, 2], vec![3, 4], vec![5, 6]]);
        let t = slab.reserve_batch(&batch, 1, None);
        complete_one(&slab, t, 2, vec![60]);
        complete_one(&slab, t, 0, vec![20]);
        let mut out = FlatBatch::default();
        assert_eq!(slab.try_take_batch(t, &mut out), None, "one row missing");
        complete_one(&slab, t, 1, vec![40]);
        assert_eq!(slab.wait_batch(t, None, &mut out), Some(Ok(())));
        assert_eq!(out.to_rows(), vec![vec![20], vec![40], vec![60]]);
    }

    #[test]
    fn zero_row_reservation_is_born_ready() {
        // No row will ever complete a 0-row slot; it must be Ready at
        // reservation so no waiter can hang on it.
        let slab = CompletionSlab::new(1);
        let t = slab.reserve_batch(&FlatBatch::new(3), 1, None);
        let mut out = FlatBatch::default();
        assert_eq!(slab.try_take_batch(t, &mut out), Some(Ok(())));
        assert!(out.is_empty());
        assert_eq!(slab.live_slots(), 0);
    }

    #[test]
    fn stale_generation_is_refused() {
        let slab = CompletionSlab::new(1);
        let t1 = slab.reserve(&[7], 1, None);
        complete_one(&slab, t1, 0, vec![1]);
        let mut out = Vec::new();
        assert_eq!(slab.try_take_row(t1, &mut out), Some(Ok(())));
        // The slot recycles; the old ticket is now a different life.
        let t2 = slab.reserve(&[8], 1, None);
        assert_ne!(t1, t2);
        complete_one(&slab, t2, 0, vec![2]);
        assert!(matches!(slab.try_take_row(t1, &mut out), Some(Err(_))));
        assert_eq!(slab.try_take_row(t2, &mut out), Some(Ok(())));
        assert_eq!(out, vec![2]);
    }

    #[test]
    fn first_error_fails_the_slot() {
        let slab = CompletionSlab::new(1);
        let batch = FlatBatch::from_rows(1, &[vec![1], vec![2]]);
        let t = slab.reserve_batch(&batch, 1, None);
        let err = ExecError::Backend {
            backend: "test",
            message: "boom".to_string(),
        };
        slab.complete_spans_err(&[span_of(t, 0, 1)], &err);
        complete_one(&slab, t, 1, vec![9]);
        let mut out = FlatBatch::default();
        match slab.wait_batch(t, None, &mut out) {
            Some(Err(ExecError::Backend { message, .. })) => assert_eq!(message, "boom"),
            other => panic!("expected the recorded error, got {other:?}"),
        }
        assert_eq!(slab.live_slots(), 0);
    }

    #[test]
    fn abandon_frees_in_both_orders() {
        let slab = CompletionSlab::new(1);
        // Abandon before completion: the worker's last row frees.
        let t = slab.reserve(&[1], 1, None);
        slab.abandon(t);
        assert_eq!(slab.live_slots(), 1, "slot still owned by the worker");
        complete_one(&slab, t, 0, vec![5]);
        assert_eq!(slab.live_slots(), 0);
        // Abandon after completion: frees immediately.
        let t = slab.reserve(&[2], 1, None);
        complete_one(&slab, t, 0, vec![6]);
        assert_eq!(slab.live_slots(), 1);
        slab.abandon(t);
        assert_eq!(slab.live_slots(), 0);
        // Double-abandon (stale by then) is harmless.
        slab.abandon(t);
        assert_eq!(slab.live_slots(), 0);
    }

    #[test]
    fn cancel_frees_queued_rows_immediately_and_defers_to_workers() {
        let slab = CompletionSlab::new(1);
        // Fully queued: cancelling all three rows frees on the spot.
        let b = FlatBatch::from_rows(1, &[vec![1], vec![2], vec![3]]);
        let t = slab.reserve_batch(&b, 1, None);
        assert!(slab.cancel(t, 3));
        assert_eq!(slab.live_slots(), 0);
        // Partially executing: two rows evicted from the queue, one
        // already in a worker's hands — the slot stays live (abandoned)
        // until that row completes.
        let t = slab.reserve_batch(&b, 1, None);
        assert!(slab.cancel(t, 2));
        assert_eq!(slab.live_slots(), 1, "worker still owns a row");
        complete_one(&slab, t, 0, vec![9]);
        assert_eq!(slab.live_slots(), 0);
        // Ready: frees immediately, result discarded.
        let t = slab.reserve(&[5], 1, None);
        complete_one(&slab, t, 0, vec![10]);
        assert!(slab.cancel(t, 0));
        assert_eq!(slab.live_slots(), 0);
        // Stale ticket: a no-op that reports dead.
        assert!(!slab.cancel(t, 0));
    }

    #[test]
    // Real-clock condvar timeout: pointless (and slow) under the
    // Miri interpreter.
    #[cfg_attr(miri, ignore)]
    fn deadline_wait_leaves_the_request_in_flight() {
        let slab = CompletionSlab::new(1);
        let t = slab.reserve(&[1], 1, None);
        let mut out = Vec::new();
        let deadline = Instant::now() + std::time::Duration::from_millis(10);
        assert_eq!(slab.wait_row(t, Some(deadline), &mut out), None, "timed out");
        complete_one(&slab, t, 0, vec![3]);
        assert_eq!(slab.wait_row(t, None, &mut out), Some(Ok(())));
        assert_eq!(out, vec![3]);
    }

    #[test]
    fn doorbell_rings_once_on_ready() {
        struct Bell(AtomicU64);
        impl Wake for Bell {
            fn ring(&self, tag: u64) {
                self.0.fetch_add(tag, Ordering::SeqCst);
            }
        }
        let slab = CompletionSlab::new(1);
        let bell = Arc::new(Bell(AtomicU64::new(0)));
        let waker: Arc<dyn Wake> = Arc::clone(&bell);
        let batch = FlatBatch::from_rows(1, &[vec![1], vec![2]]);
        let t = slab.reserve_batch(&batch, 1, Some((waker, 7)));
        complete_one(&slab, t, 0, vec![1]);
        assert_eq!(bell.0.load(Ordering::SeqCst), 0, "not ready yet");
        complete_one(&slab, t, 1, vec![2]);
        assert_eq!(bell.0.load(Ordering::SeqCst), 7, "rung once with the tag");
        let mut out = FlatBatch::default();
        assert_eq!(slab.try_take_batch(t, &mut out), Some(Ok(())));
    }

    #[test]
    fn spans_gather_and_complete_in_bulk() {
        let slab = CompletionSlab::new(2);
        let b1 = FlatBatch::from_rows(2, &[vec![1, 2], vec![3, 4], vec![5, 6]]);
        let b2 = FlatBatch::from_rows(2, &[vec![7, 8]]);
        let t1 = slab.reserve_batch(&b1, 1, None);
        let t2 = slab.reserve_batch(&b2, 1, None);
        // One worker's take: the queue split t1's 3-row span 2+1
        // around t2's single, so runs alternate shards.
        let spans = [span_of(t1, 0, 2), span_of(t2, 0, 1), span_of(t1, 2, 1)];
        let mut inputs = FlatBatch::new(2);
        let mut bad = Vec::new();
        slab.gather_spans(&spans, &mut inputs, &mut bad);
        assert!(bad.is_empty());
        assert_eq!(
            inputs.to_rows(),
            vec![vec![1, 2], vec![3, 4], vec![7, 8], vec![5, 6]]
        );
        // Reply rows line up with gathered rows, span by span, and
        // recombine in each slot by row index.
        let rows = FlatBatch::from_rows(1, &[vec![10], vec![20], vec![30], vec![40]]);
        slab.complete_spans_ok(&spans, &rows);
        let mut out = FlatBatch::default();
        assert_eq!(slab.try_take_batch(t1, &mut out), Some(Ok(())));
        assert_eq!(out.to_rows(), vec![vec![10], vec![20], vec![40]]);
        assert_eq!(slab.try_take_batch(t2, &mut out), Some(Ok(())));
        assert_eq!(out.to_rows(), vec![vec![30]]);
        assert_eq!(slab.live_slots(), 0);
    }

    #[test]
    fn complete_spans_err_fails_whole_slots() {
        let slab = CompletionSlab::new(1);
        let t = slab.reserve_batch(&FlatBatch::from_rows(1, &[vec![1], vec![2]]), 1, None);
        let err = ExecError::Backend {
            backend: "test",
            message: "boom".to_string(),
        };
        slab.complete_spans_err(&[span_of(t, 0, 2)], &err);
        let mut out = FlatBatch::default();
        match slab.try_take_batch(t, &mut out) {
            Some(Err(ExecError::Backend { message, .. })) => assert_eq!(message, "boom"),
            other => panic!("expected the recorded error, got {other:?}"),
        }
        assert_eq!(slab.live_slots(), 0);
    }

    #[test]
    fn gather_reports_arity_mismatch_spans_as_bad() {
        let slab = CompletionSlab::new(1);
        let good = slab.reserve_batch(&FlatBatch::from_rows(2, &[vec![1, 2]]), 1, None);
        let weird = slab.reserve_batch(&FlatBatch::from_rows(3, &[vec![7, 8, 9]]), 1, None);
        let spans = [span_of(good, 0, 1), span_of(weird, 0, 1)];
        let mut inputs = FlatBatch::new(2);
        let mut bad = Vec::new();
        slab.gather_spans(&spans, &mut inputs, &mut bad);
        assert_eq!(inputs.to_rows(), vec![vec![1, 2]]);
        assert_eq!(bad, vec![span_of(weird, 0, 1)]);
        // The caller fails the malformed span; its waiter gets a
        // structured error, and the good span still completes.
        let err = ExecError::Backend {
            backend: "test",
            message: "bad arity".to_string(),
        };
        slab.complete_spans_err(&bad, &err);
        slab.complete_spans_ok(&[span_of(good, 0, 1)], &FlatBatch::from_rows(1, &[vec![9]]));
        let mut out = FlatBatch::default();
        assert!(matches!(slab.try_take_batch(weird, &mut out), Some(Err(_))));
        assert_eq!(slab.try_take_batch(good, &mut out), Some(Ok(())));
        assert_eq!(out.to_rows(), vec![vec![9]]);
    }

    #[test]
    fn burst_buffers_decay_to_the_watermark() {
        let slab = CompletionSlab::with_trim(1, 64);
        // A 64k-row burst through one slot grows its buffers far past
        // the watermark...
        let mut big = FlatBatch::new(1);
        for i in 0..65536 {
            big.push(&[i]);
        }
        let t = slab.reserve_batch(&big, 1, None);
        let mut rows = FlatBatch::new(1);
        rows.resize_rows(65536);
        slab.complete_spans_ok(&[span_of(t, 0, 65536)], &rows);
        let mut out = FlatBatch::default();
        assert_eq!(slab.try_take_batch(t, &mut out), Some(Ok(())));
        assert_eq!(out.n_rows(), 65536);
        // ...and the free trimmed them back down.
        assert!(
            slab.buffer_capacity_words() <= 4 * 64,
            "burst capacity must decay, got {} words",
            slab.buffer_capacity_words()
        );
        // Steady small traffic reuses the trimmed buffers and the
        // footprint stays at the watermark.
        let mut small_out = Vec::new();
        for i in 0..100i32 {
            let t = slab.reserve(&[i], 1, None);
            slab.complete_spans_ok(&[span_of(t, 0, 1)], &FlatBatch::from_rows(1, &[vec![i * 3]]));
            assert_eq!(slab.try_take_row(t, &mut small_out), Some(Ok(())));
            assert_eq!(small_out, vec![i * 3]);
            assert!(slab.buffer_capacity_words() <= 4 * 64);
        }
    }

    #[test]
    // Spawns real threads that sleep on the wall clock; the race it
    // exercises is covered by the TSan job, not the Miri job.
    #[cfg_attr(miri, ignore)]
    fn fail_all_pending_wakes_waiters_with_the_error() {
        let slab = Arc::new(CompletionSlab::new(2));
        let t = slab.reserve(&[1], 1, None);
        let slab2 = Arc::clone(&slab);
        let waiter = std::thread::spawn(move || {
            let mut out = Vec::new();
            slab2.wait_row(t, None, &mut out).unwrap()
        });
        // Give the waiter time to park, then fail everything.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let err = ExecError::Backend {
            backend: "engine",
            message: "worker lost".to_string(),
        };
        slab.fail_all_pending(&err);
        match waiter.join().unwrap() {
            Err(ExecError::Backend { message, .. }) => assert!(message.contains("worker lost")),
            other => panic!("expected the teardown error, got {other:?}"),
        }
    }
}
