//! Request queues + batching policy (pure logic, tested without PJRT).
//!
//! The dispatcher maintains one FIFO queue per kernel context. Workers
//! (overlay pipelines) pick batches with **context affinity**: a worker
//! holding kernel K's context prefers K's queue — switching contexts is
//! cheap on this overlay (sub-µs, the paper's headline) but never free,
//! and affinity also models the BRAM-resident data staging of Fig. 4.
//! When the worker's context has no work it steals the longest queue
//! (weighted by age to prevent starvation).

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

/// One queued request.
#[derive(Debug)]
pub struct Pending<T> {
    pub inputs: Vec<i32>,
    pub enqueued: Instant,
    /// Opaque completion payload (reply channel in production, test id
    /// in tests).
    pub token: T,
}

/// Per-kernel FIFO queues.
#[derive(Debug)]
pub struct QueueSet<T> {
    queues: BTreeMap<String, VecDeque<Pending<T>>>,
    pub total_queued: usize,
}

/// A batch the dispatcher hands to a worker.
#[derive(Debug)]
pub struct Batch<T> {
    pub kernel: String,
    pub items: Vec<Pending<T>>,
}

impl<T> Default for QueueSet<T> {
    fn default() -> Self {
        Self {
            queues: BTreeMap::new(),
            total_queued: 0,
        }
    }
}

impl<T> QueueSet<T> {
    pub fn push(&mut self, kernel: &str, p: Pending<T>) {
        self.queues.entry(kernel.to_string()).or_default().push_back(p);
        self.total_queued += 1;
    }

    pub fn is_empty(&self) -> bool {
        self.total_queued == 0
    }

    pub fn queued_for(&self, kernel: &str) -> usize {
        self.queues.get(kernel).map_or(0, VecDeque::len)
    }

    /// Batching policy: prefer the worker's current context if it has
    /// work; otherwise the queue with the highest (length + age bonus)
    /// score. Takes up to `max_batch` requests FIFO.
    pub fn take_batch(
        &mut self,
        current_context: Option<&str>,
        max_batch: usize,
        now: Instant,
    ) -> Option<Batch<T>> {
        if self.is_empty() {
            return None;
        }
        let kernel = match current_context {
            Some(k) if self.queued_for(k) > 0 => k.to_string(),
            _ => self
                .queues
                .iter()
                .filter(|(_, q)| !q.is_empty())
                .max_by(|(_, a), (_, b)| {
                    let score = |q: &VecDeque<Pending<T>>| {
                        let age_ms = now
                            .duration_since(q.front().unwrap().enqueued)
                            .as_secs_f64()
                            * 1e3;
                        q.len() as f64 + age_ms * 0.1
                    };
                    score(a).partial_cmp(&score(b)).unwrap()
                })
                .map(|(k, _)| k.clone())?,
        };
        let q = self.queues.get_mut(&kernel).unwrap();
        let n = q.len().min(max_batch);
        let items: Vec<Pending<T>> = q.drain(..n).collect();
        self.total_queued -= items.len();
        Some(Batch { kernel, items })
    }

    /// Drain everything (shutdown path).
    pub fn drain_all(&mut self) -> Vec<Batch<T>> {
        let mut out = Vec::new();
        for (k, q) in self.queues.iter_mut() {
            if !q.is_empty() {
                let items: Vec<Pending<T>> = q.drain(..).collect();
                self.total_queued -= items.len();
                out.push(Batch {
                    kernel: k.clone(),
                    items,
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pend(token: u32) -> Pending<u32> {
        Pending {
            inputs: vec![1, 2, 3],
            enqueued: Instant::now(),
            token,
        }
    }

    #[test]
    fn affinity_preferred_when_context_has_work() {
        let mut qs = QueueSet::default();
        qs.push("a", pend(1));
        qs.push("b", pend(2));
        qs.push("b", pend(3));
        // Worker holds 'a': takes 'a' despite 'b' being longer.
        let b = qs.take_batch(Some("a"), 16, Instant::now()).unwrap();
        assert_eq!(b.kernel, "a");
        assert_eq!(b.items.len(), 1);
    }

    #[test]
    fn steals_longest_queue_without_affinity() {
        let mut qs = QueueSet::default();
        qs.push("a", pend(1));
        qs.push("b", pend(2));
        qs.push("b", pend(3));
        let b = qs.take_batch(Some("c"), 16, Instant::now()).unwrap();
        assert_eq!(b.kernel, "b");
        assert_eq!(b.items.len(), 2);
        assert_eq!(qs.total_queued, 1);
    }

    #[test]
    fn respects_max_batch_fifo() {
        let mut qs = QueueSet::default();
        for i in 0..10 {
            qs.push("k", pend(i));
        }
        let b = qs.take_batch(None, 4, Instant::now()).unwrap();
        assert_eq!(b.items.len(), 4);
        assert_eq!(b.items[0].token, 0);
        assert_eq!(b.items[3].token, 3);
        assert_eq!(qs.queued_for("k"), 6);
    }

    #[test]
    fn empty_returns_none() {
        let mut qs: QueueSet<u32> = QueueSet::default();
        assert!(qs.take_batch(None, 8, Instant::now()).is_none());
    }

    #[test]
    fn age_bonus_prevents_starvation() {
        let mut qs = QueueSet::default();
        let old = Instant::now() - std::time::Duration::from_millis(500);
        qs.push(
            "starved",
            Pending {
                inputs: vec![],
                enqueued: old,
                token: 0u32,
            },
        );
        for i in 0..3 {
            qs.push("busy", pend(i));
        }
        // 0.1/ms * 500ms = 50 > 3: the old queue wins.
        let b = qs.take_batch(None, 8, Instant::now()).unwrap();
        assert_eq!(b.kernel, "starved");
    }

    #[test]
    fn drain_all_empties() {
        let mut qs = QueueSet::default();
        qs.push("a", pend(1));
        qs.push("b", pend(2));
        let batches = qs.drain_all();
        assert_eq!(batches.len(), 2);
        assert!(qs.is_empty());
    }
}
