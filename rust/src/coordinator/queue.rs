//! Request queues + batching policy (pure logic, tested without PJRT).
//!
//! The dispatcher maintains one FIFO queue per kernel context, indexed
//! by dense [`KernelId`] — names are interned once at ingress, so a
//! push moves a `u32` and a `Vec<i32>`, never a `String`, and batch
//! selection is a linear scan over a fixed-size vector instead of a
//! `BTreeMap` walk. (The previous map-keyed design also leaked: an
//! empty per-kernel queue stayed resident forever once its name had
//! been seen, growing without bound as contexts churned. The dense
//! layout is bounded by the registry size by construction, and
//! [`QueueSet::drain_all`] additionally releases the per-queue buffers
//! so an idle engine holds no request memory.)
//!
//! Queues are **bounded**: every queue carries the same `depth` limit
//! and [`QueueSet::try_push`] refuses to grow past it, handing the
//! request back to the caller. This is the mechanical half of the
//! service layer's admission control — a client that outruns the
//! fabric gets an explicit `Rejected` reply instead of unbounded
//! memory growth and unbounded latency.
//!
//! Workers (overlay pipelines) pick batches with **context affinity**:
//! a worker holding kernel K's context prefers K's queue — switching
//! contexts is cheap on this overlay (sub-µs, the paper's headline)
//! but never free, and affinity also models the BRAM-resident data
//! staging of Fig. 4. When the worker's context has no work it steals
//! the longest queue (weighted by age to prevent starvation).

use crate::exec::KernelId;
use std::collections::VecDeque;
use std::time::Instant;

/// One queued request.
#[derive(Debug)]
pub struct Pending<T> {
    pub inputs: Vec<i32>,
    pub enqueued: Instant,
    /// Opaque completion payload (reply channel in production, test id
    /// in tests).
    pub token: T,
}

/// Per-kernel FIFO queues, dense over the kernel registry, each
/// bounded at `depth` entries.
#[derive(Debug)]
pub struct QueueSet<T> {
    queues: Vec<VecDeque<Pending<T>>>,
    depth: usize,
    pub total_queued: usize,
}

/// A batch the dispatcher hands to a worker.
#[derive(Debug)]
pub struct Batch<T> {
    pub kernel: KernelId,
    pub items: Vec<Pending<T>>,
}

impl<T> QueueSet<T> {
    /// One queue per registry kernel, each admitting at most `depth`
    /// waiting requests.
    pub fn new(n_kernels: usize, depth: usize) -> Self {
        assert!(depth >= 1, "queue depth must be positive");
        Self {
            queues: (0..n_kernels).map(|_| VecDeque::new()).collect(),
            depth,
            total_queued: 0,
        }
    }

    pub fn n_kernels(&self) -> usize {
        self.queues.len()
    }

    /// Per-kernel admission bound.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Enqueue one request, or hand it back when the kernel's queue is
    /// at its depth limit (the admission-control path). `kernel` must
    /// come from the registry this set was sized for (ingress interns
    /// and validates names).
    pub fn try_push(&mut self, kernel: KernelId, p: Pending<T>) -> Result<(), Pending<T>> {
        let q = &mut self.queues[kernel.index()];
        if q.len() >= self.depth {
            return Err(p);
        }
        q.push_back(p);
        self.total_queued += 1;
        Ok(())
    }

    pub fn is_empty(&self) -> bool {
        self.total_queued == 0
    }

    pub fn queued_for(&self, kernel: KernelId) -> usize {
        self.queues[kernel.index()].len()
    }

    /// Batching policy: prefer the worker's current context if it has
    /// work; otherwise the queue with the highest (length + age bonus)
    /// score. Takes up to `max_batch` requests FIFO.
    pub fn take_batch(
        &mut self,
        current_context: Option<KernelId>,
        max_batch: usize,
        now: Instant,
    ) -> Option<Batch<T>> {
        if self.is_empty() {
            return None;
        }
        let kernel = match current_context {
            Some(k) if self.queued_for(k) > 0 => k,
            _ => {
                let score = |q: &VecDeque<Pending<T>>| {
                    let age_ms = now
                        .duration_since(q.front().unwrap().enqueued)
                        .as_secs_f64()
                        * 1e3;
                    q.len() as f64 + age_ms * 0.1
                };
                self.queues
                    .iter()
                    .enumerate()
                    .filter(|(_, q)| !q.is_empty())
                    // total_cmp: scores are finite here, but a NaN-safe
                    // total order costs nothing and cannot panic.
                    .max_by(|(_, a), (_, b)| score(a).total_cmp(&score(b)))
                    .map(|(i, _)| KernelId(i as u32))?
            }
        };
        let q = &mut self.queues[kernel.index()];
        let n = q.len().min(max_batch);
        let items: Vec<Pending<T>> = q.drain(..n).collect();
        self.total_queued -= items.len();
        Some(Batch { kernel, items })
    }

    /// Drain everything (shutdown path) and release per-queue buffers —
    /// after a burst the deque capacities would otherwise stay resident
    /// for the life of the coordinator.
    pub fn drain_all(&mut self) -> Vec<Batch<T>> {
        let mut out = Vec::new();
        for (i, q) in self.queues.iter_mut().enumerate() {
            if !q.is_empty() {
                let items: Vec<Pending<T>> = q.drain(..).collect();
                self.total_queued -= items.len();
                out.push(Batch {
                    kernel: KernelId(i as u32),
                    items,
                });
            }
            // Prune: drop the buffer, not just the contents.
            *q = VecDeque::new();
        }
        out
    }

    /// Resident buffer capacity across all queues (memory telemetry /
    /// the pruning regression test).
    pub fn resident_capacity(&self) -> usize {
        self.queues.iter().map(VecDeque::capacity).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: KernelId = KernelId(0);
    const B: KernelId = KernelId(1);
    const C: KernelId = KernelId(2);

    fn pend(token: u32) -> Pending<u32> {
        Pending {
            inputs: vec![1, 2, 3],
            enqueued: Instant::now(),
            token,
        }
    }

    #[test]
    fn affinity_preferred_when_context_has_work() {
        let mut qs = QueueSet::new(3, 16);
        qs.try_push(A, pend(1)).unwrap();
        qs.try_push(B, pend(2)).unwrap();
        qs.try_push(B, pend(3)).unwrap();
        // Worker holds A: takes A despite B being longer.
        let b = qs.take_batch(Some(A), 16, Instant::now()).unwrap();
        assert_eq!(b.kernel, A);
        assert_eq!(b.items.len(), 1);
    }

    #[test]
    fn steals_longest_queue_without_affinity() {
        let mut qs = QueueSet::new(3, 16);
        qs.try_push(A, pend(1)).unwrap();
        qs.try_push(B, pend(2)).unwrap();
        qs.try_push(B, pend(3)).unwrap();
        let b = qs.take_batch(Some(C), 16, Instant::now()).unwrap();
        assert_eq!(b.kernel, B);
        assert_eq!(b.items.len(), 2);
        assert_eq!(qs.total_queued, 1);
    }

    #[test]
    fn respects_max_batch_fifo() {
        let mut qs = QueueSet::new(1, 16);
        for i in 0..10 {
            qs.try_push(A, pend(i)).unwrap();
        }
        let b = qs.take_batch(None, 4, Instant::now()).unwrap();
        assert_eq!(b.items.len(), 4);
        assert_eq!(b.items[0].token, 0);
        assert_eq!(b.items[3].token, 3);
        assert_eq!(qs.queued_for(A), 6);
    }

    #[test]
    fn empty_returns_none() {
        let mut qs: QueueSet<u32> = QueueSet::new(2, 16);
        assert!(qs.take_batch(None, 8, Instant::now()).is_none());
    }

    #[test]
    fn depth_limit_rejects_and_hands_back() {
        let mut qs = QueueSet::new(2, 2);
        assert_eq!(qs.depth(), 2);
        qs.try_push(A, pend(1)).unwrap();
        qs.try_push(A, pend(2)).unwrap();
        // A is full: the request comes back untouched.
        let rejected = qs.try_push(A, pend(3)).unwrap_err();
        assert_eq!(rejected.token, 3);
        assert_eq!(qs.queued_for(A), 2);
        assert_eq!(qs.total_queued, 2);
        // Other queues still admit (the bound is per kernel).
        qs.try_push(B, pend(4)).unwrap();
        // Draining a batch frees capacity again.
        qs.take_batch(Some(A), 1, Instant::now()).unwrap();
        qs.try_push(A, pend(5)).unwrap();
        assert_eq!(qs.queued_for(A), 2);
    }

    #[test]
    fn age_bonus_prevents_starvation() {
        let mut qs = QueueSet::new(2, 16);
        let old = Instant::now() - std::time::Duration::from_millis(500);
        qs.try_push(
            A, // starved
            Pending {
                inputs: vec![],
                enqueued: old,
                token: 0u32,
            },
        ).unwrap();
        for i in 0..3 {
            qs.try_push(B, pend(i)).unwrap(); // busy
        }
        // 0.1/ms * 500ms = 50 > 3: the old queue wins.
        let b = qs.take_batch(None, 8, Instant::now()).unwrap();
        assert_eq!(b.kernel, A);
    }

    #[test]
    fn drain_all_empties_and_releases_buffers() {
        let mut qs = QueueSet::new(2, 1024);
        for i in 0..512 {
            qs.try_push(A, pend(i)).unwrap();
        }
        qs.try_push(B, pend(999)).unwrap();
        assert!(qs.resident_capacity() >= 512);
        let batches = qs.drain_all();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].items.len(), 512);
        assert!(qs.is_empty());
        // The pruning fix: capacity is gone, not just the contents
        // (fresh VecDeques: zero on modern std, a word or two before
        // the 1.66 ring-buffer rewrite).
        assert!(qs.resident_capacity() < 16, "{}", qs.resident_capacity());
        // The set stays usable after a drain.
        qs.try_push(B, pend(1)).unwrap();
        assert_eq!(qs.queued_for(B), 1);
    }
}
