//! Request queues + batching policy (pure logic, tested without PJRT).
//!
//! The dispatcher maintains one FIFO queue per kernel context, indexed
//! by dense [`KernelId`] — names are interned once at ingress, so a
//! push moves a `u32` and a small `Copy` token, never a `String`, and
//! batch selection is a linear scan over a fixed-size vector instead
//! of a `BTreeMap` walk. (The previous map-keyed design also leaked:
//! an empty per-kernel queue stayed resident forever once its name had
//! been seen, growing without bound as contexts churned. The dense
//! layout is bounded by the registry size by construction; each
//! queue's ring buffer keeps its high-water capacity — bounded by
//! `depth` entries of a few words each — for the engine's life, and
//! is freed when the engine drops.)
//!
//! Since the completion-slab refactor (DESIGN.md §10) a queue entry is
//! a [`Queued`] — an enqueue timestamp plus an opaque token (a slab
//! [`RowTicket`](super::completion::RowTicket) in production). Request
//! *inputs* live in the slab slot, not the queue, so pushing a request
//! moves a handful of words and the steady-state submit path performs
//! no heap allocation at all. Workers refill a reused buffer through
//! [`QueueSet::take_batch_into`], so dispatch allocates nothing per
//! batch either.
//!
//! Queues are **bounded**: every queue carries the same `depth` limit
//! and [`QueueSet::try_push`] refuses to grow past it, handing the
//! request back to the caller. This is the mechanical half of the
//! service layer's admission control — a client that outruns the
//! fabric gets an explicit `Rejected` reply instead of unbounded
//! memory growth and unbounded latency.
//!
//! Workers (overlay pipelines) pick batches with **context affinity**:
//! a worker holding kernel K's context prefers K's queue — switching
//! contexts is cheap on this overlay (sub-µs, the paper's headline)
//! but never free, and affinity also models the BRAM-resident data
//! staging of Fig. 4. When the worker's context has no work it steals
//! the longest queue (weighted by age to prevent starvation).

use crate::exec::KernelId;
use std::collections::VecDeque;
use std::time::Instant;

/// One queued request: when it arrived, and the token that locates its
/// inputs and completion slot (a reply channel would be an allocation;
/// a slab ticket is two words).
#[derive(Debug, Clone, Copy)]
pub struct Queued<T> {
    pub enqueued: Instant,
    pub token: T,
}

/// Per-kernel FIFO queues, dense over the kernel registry, each
/// bounded at `depth` entries.
#[derive(Debug)]
pub struct QueueSet<T> {
    queues: Vec<VecDeque<Queued<T>>>,
    depth: usize,
    pub total_queued: usize,
}

impl<T> QueueSet<T> {
    /// One queue per registry kernel, each admitting at most `depth`
    /// waiting requests.
    pub fn new(n_kernels: usize, depth: usize) -> Self {
        assert!(depth >= 1, "queue depth must be positive");
        Self {
            queues: (0..n_kernels).map(|_| VecDeque::new()).collect(),
            depth,
            total_queued: 0,
        }
    }

    pub fn n_kernels(&self) -> usize {
        self.queues.len()
    }

    /// Per-kernel admission bound.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Enqueue one request, or hand it back when the kernel's queue is
    /// at its depth limit (the admission-control path). `kernel` must
    /// come from the registry this set was sized for (ingress interns
    /// and validates names).
    pub fn try_push(&mut self, kernel: KernelId, q: Queued<T>) -> Result<(), Queued<T>> {
        let queue = &mut self.queues[kernel.index()];
        if queue.len() >= self.depth {
            return Err(q);
        }
        queue.push_back(q);
        self.total_queued += 1;
        Ok(())
    }

    pub fn is_empty(&self) -> bool {
        self.total_queued == 0
    }

    pub fn queued_for(&self, kernel: KernelId) -> usize {
        self.queues[kernel.index()].len()
    }

    /// Batching policy: prefer the worker's current context if it has
    /// work; otherwise the queue with the highest (length + age bonus)
    /// score. Drains up to `max_batch` requests FIFO into `out`
    /// (cleared first), which the worker reuses across batches —
    /// dispatch performs no per-batch allocation in steady state.
    /// Returns the chosen kernel, or `None` when nothing is queued.
    pub fn take_batch_into(
        &mut self,
        current_context: Option<KernelId>,
        max_batch: usize,
        now: Instant,
        out: &mut Vec<Queued<T>>,
    ) -> Option<KernelId> {
        out.clear();
        if self.is_empty() {
            return None;
        }
        let kernel = match current_context {
            Some(k) if self.queued_for(k) > 0 => k,
            _ => {
                let score = |q: &VecDeque<Queued<T>>| {
                    let age_ms = now
                        .duration_since(q.front().unwrap().enqueued)
                        .as_secs_f64()
                        * 1e3;
                    q.len() as f64 + age_ms * 0.1
                };
                self.queues
                    .iter()
                    .enumerate()
                    .filter(|(_, q)| !q.is_empty())
                    // total_cmp: scores are finite here, but a NaN-safe
                    // total order costs nothing and cannot panic.
                    .max_by(|(_, a), (_, b)| score(a).total_cmp(&score(b)))
                    .map(|(i, _)| KernelId(i as u32))?
            }
        };
        let q = &mut self.queues[kernel.index()];
        let n = q.len().min(max_batch);
        out.extend(q.drain(..n));
        self.total_queued -= out.len();
        Some(kernel)
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    const A: KernelId = KernelId(0);
    const B: KernelId = KernelId(1);
    const C: KernelId = KernelId(2);

    fn pend(token: u32) -> Queued<u32> {
        Queued {
            enqueued: Instant::now(),
            token,
        }
    }

    fn take<T>(
        qs: &mut QueueSet<T>,
        ctx: Option<KernelId>,
        max: usize,
    ) -> Option<(KernelId, Vec<Queued<T>>)> {
        let mut out = Vec::new();
        let k = qs.take_batch_into(ctx, max, Instant::now(), &mut out)?;
        Some((k, out))
    }

    #[test]
    fn affinity_preferred_when_context_has_work() {
        let mut qs = QueueSet::new(3, 16);
        qs.try_push(A, pend(1)).unwrap();
        qs.try_push(B, pend(2)).unwrap();
        qs.try_push(B, pend(3)).unwrap();
        // Worker holds A: takes A despite B being longer.
        let (kernel, items) = take(&mut qs, Some(A), 16).unwrap();
        assert_eq!(kernel, A);
        assert_eq!(items.len(), 1);
    }

    #[test]
    fn steals_longest_queue_without_affinity() {
        let mut qs = QueueSet::new(3, 16);
        qs.try_push(A, pend(1)).unwrap();
        qs.try_push(B, pend(2)).unwrap();
        qs.try_push(B, pend(3)).unwrap();
        let (kernel, items) = take(&mut qs, Some(C), 16).unwrap();
        assert_eq!(kernel, B);
        assert_eq!(items.len(), 2);
        assert_eq!(qs.total_queued, 1);
    }

    #[test]
    fn respects_max_batch_fifo_and_reuses_the_buffer() {
        let mut qs = QueueSet::new(1, 16);
        for i in 0..10 {
            qs.try_push(A, pend(i)).unwrap();
        }
        let mut out = Vec::new();
        qs.take_batch_into(None, 4, Instant::now(), &mut out).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].token, 0);
        assert_eq!(out[3].token, 3);
        assert_eq!(qs.queued_for(A), 6);
        // The same buffer serves the next batch: cleared, not leaked.
        qs.take_batch_into(None, 4, Instant::now(), &mut out).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].token, 4);
    }

    #[test]
    fn empty_returns_none() {
        let mut qs: QueueSet<u32> = QueueSet::new(2, 16);
        assert!(take(&mut qs, None, 8).is_none());
    }

    #[test]
    fn depth_limit_rejects_and_hands_back() {
        let mut qs = QueueSet::new(2, 2);
        assert_eq!(qs.depth(), 2);
        qs.try_push(A, pend(1)).unwrap();
        qs.try_push(A, pend(2)).unwrap();
        // A is full: the request comes back untouched.
        let rejected = qs.try_push(A, pend(3)).unwrap_err();
        assert_eq!(rejected.token, 3);
        assert_eq!(qs.queued_for(A), 2);
        assert_eq!(qs.total_queued, 2);
        // Other queues still admit (the bound is per kernel).
        qs.try_push(B, pend(4)).unwrap();
        // Draining a batch frees capacity again.
        take(&mut qs, Some(A), 1).unwrap();
        qs.try_push(A, pend(5)).unwrap();
        assert_eq!(qs.queued_for(A), 2);
    }

    #[test]
    fn age_bonus_prevents_starvation() {
        let mut qs = QueueSet::new(2, 16);
        let old = Instant::now() - std::time::Duration::from_millis(500);
        qs.try_push(
            A, // starved
            Queued {
                enqueued: old,
                token: 0u32,
            },
        )
        .unwrap();
        for i in 0..3 {
            qs.try_push(B, pend(i)).unwrap(); // busy
        }
        // 0.1/ms * 500ms = 50 > 3: the old queue wins.
        let (kernel, _) = take(&mut qs, None, 8).unwrap();
        assert_eq!(kernel, A);
    }

    #[test]
    fn high_water_burst_drains_through_take_batch_into() {
        // The shutdown path drains by repeated take_batch_into (the
        // workers' loop), not a dedicated drain call — a burst must
        // come back out completely through the same door.
        let mut qs = QueueSet::new(2, 1024);
        for i in 0..512 {
            qs.try_push(A, pend(i)).unwrap();
        }
        qs.try_push(B, pend(999)).unwrap();
        let mut out = Vec::new();
        let mut drained = 0;
        while let Some(_k) = qs.take_batch_into(None, 64, Instant::now(), &mut out) {
            drained += out.len();
        }
        assert_eq!(drained, 513);
        assert!(qs.is_empty());
        // The set stays usable afterwards.
        qs.try_push(B, pend(1)).unwrap();
        assert_eq!(qs.queued_for(B), 1);
    }
}
