//! Request queues + batching policy (pure logic, tested without PJRT).
//!
//! The dispatcher maintains one FIFO queue per kernel context, indexed
//! by dense [`KernelId`] — names are interned once at ingress, so a
//! push moves a `u32` and a small `Copy` token, never a `String`, and
//! batch selection is a linear scan over a fixed-size vector instead
//! of a `BTreeMap` walk. (The previous map-keyed design also leaked:
//! an empty per-kernel queue stayed resident forever once its name had
//! been seen, growing without bound as contexts churned. The dense
//! layout is bounded by the registry size by construction; each
//! queue's ring buffer keeps its high-water capacity — bounded by
//! `depth` entries of a few words each — for the engine's life, and
//! is freed when the engine drops.)
//!
//! Since the completion-slab refactor (DESIGN.md §10) a queue entry is
//! a [`Queued`] — an enqueue timestamp plus an opaque token (a slab
//! [`RowSpan`](super::completion::RowSpan) in production). Request
//! *inputs* live in the slab slot, not the queue, so pushing a request
//! moves a handful of words and the steady-state submit path performs
//! no heap allocation at all. Workers refill a reused buffer through
//! [`QueueSet::take_batch_into`], so dispatch allocates nothing per
//! batch either.
//!
//! Tokens are **spans** ([`SpanToken`]): one entry can carry many
//! contiguous rows of a single slab slot, so a whole-batch submit
//! enqueues *one* entry regardless of row count. Accounting (`depth`,
//! [`QueueSet::queued_for`], `total_queued`) is therefore in **rows**,
//! not entries, and [`QueueSet::take_batch_into`] splits an oversized
//! front span at the row budget: the taken head rides out with this
//! worker while the remainder stays at the queue front for the next
//! idle worker — this is how one 64k-row batch fans out across the
//! whole worker pool and recombines in the slab by row index.
//!
//! Queues are **bounded**: every queue carries the same `depth` limit
//! (in rows) and [`QueueSet::try_push`] refuses to grow past it,
//! handing the request back to the caller. This is the mechanical half
//! of the service layer's admission control — a client that outruns
//! the fabric gets an explicit `Rejected` reply instead of unbounded
//! memory growth and unbounded latency.
//!
//! Workers (overlay pipelines) pick batches with **context affinity**:
//! a worker holding kernel K's context prefers K's queue — switching
//! contexts is cheap on this overlay (sub-µs, the paper's headline)
//! but never free, and affinity also models the BRAM-resident data
//! staging of Fig. 4. When the worker's context has no work it steals
//! the deepest queue in rows (weighted by age to prevent starvation).

use crate::exec::KernelId;
use std::collections::VecDeque;
use std::time::Instant;

/// A queue token that carries one or more contiguous rows and can be
/// split at a row boundary. Splitting is what lets a worker take a
/// partial batch while the remainder stays queued for its peers.
pub(crate) trait SpanToken {
    /// Rows this token carries (always ≥ 1 for queued tokens).
    fn rows(&self) -> usize;

    /// Split off the first `n` rows (0 < `n` < `self.rows()`) as a new
    /// token, leaving `self` holding the remainder.
    fn take_front(&mut self, n: usize) -> Self;
}

/// Single-row tokens for queue-policy tests: one row, never split.
#[cfg(test)]
impl SpanToken for u32 {
    fn rows(&self) -> usize {
        1
    }

    fn take_front(&mut self, _n: usize) -> Self {
        unreachable!("single-row tokens are never split")
    }
}

/// One queued request span: when it arrived, and the token that
/// locates its inputs and completion slot (a reply channel would be an
/// allocation; a slab span is three words).
#[derive(Debug, Clone, Copy)]
pub(crate) struct Queued<T> {
    pub(crate) enqueued: Instant,
    pub(crate) token: T,
}

/// Per-kernel FIFO queues, dense over the kernel registry, each
/// bounded at `depth` **rows** (entries are spans of ≥ 1 rows).
#[derive(Debug)]
pub(crate) struct QueueSet<T> {
    queues: Vec<VecDeque<Queued<T>>>,
    /// Queued rows per kernel (an entry may span many rows).
    rows: Vec<usize>,
    depth: usize,
    /// Total rows queued across every kernel.
    pub(crate) total_queued: usize,
}

impl<T: SpanToken> QueueSet<T> {
    /// One queue per registry kernel, each admitting at most `depth`
    /// waiting rows.
    pub(crate) fn new(n_kernels: usize, depth: usize) -> Self {
        assert!(depth >= 1, "queue depth must be positive");
        Self {
            queues: (0..n_kernels).map(|_| VecDeque::new()).collect(),
            rows: vec![0; n_kernels],
            depth,
            total_queued: 0,
        }
    }

    pub(crate) fn n_kernels(&self) -> usize {
        self.queues.len()
    }

    /// Per-kernel admission bound, in rows.
    pub(crate) fn depth(&self) -> usize {
        self.depth
    }

    /// Enqueue one request span, or hand it back when admitting its
    /// rows would push the kernel's queue past the depth limit (the
    /// admission-control path). `kernel` must come from the registry
    /// this set was sized for (ingress interns and validates names).
    pub(crate) fn try_push(&mut self, kernel: KernelId, q: Queued<T>) -> Result<(), Queued<T>> {
        let n = q.token.rows();
        debug_assert!(n > 0, "zero-row spans are completed at reserve time");
        if self.rows[kernel.index()] + n > self.depth {
            return Err(q);
        }
        self.queues[kernel.index()].push_back(q);
        self.rows[kernel.index()] += n;
        self.total_queued += n;
        Ok(())
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.total_queued == 0
    }

    /// Rows queued for `kernel` (what admission compares to `depth`).
    pub(crate) fn queued_for(&self, kernel: KernelId) -> usize {
        self.rows[kernel.index()]
    }

    /// Batching policy: prefer the worker's current context if it has
    /// work; otherwise the queue with the highest (rows + age bonus)
    /// score. Takes up to `max_batch` **rows** FIFO into `out`
    /// (cleared first), which the worker reuses across batches —
    /// dispatch performs no per-batch allocation in steady state.
    ///
    /// An entry whose span exceeds the remaining row budget is
    /// **split**: the head rides out with this take, the remainder
    /// stays at the queue front — so the next worker (or the next
    /// iteration of this one) picks up where this take stopped, and
    /// one oversized batch fans out across every idle worker.
    ///
    /// Returns the chosen kernel, or `None` when nothing is queued.
    pub(crate) fn take_batch_into(
        &mut self,
        current_context: Option<KernelId>,
        max_batch: usize,
        now: Instant,
        out: &mut Vec<Queued<T>>,
    ) -> Option<KernelId> {
        out.clear();
        if self.is_empty() {
            return None;
        }
        let kernel = match current_context {
            Some(k) if self.queued_for(k) > 0 => k,
            _ => {
                let score = |i: usize| {
                    let age_ms = now
                        .duration_since(self.queues[i].front().unwrap().enqueued)
                        .as_secs_f64()
                        * 1e3;
                    self.rows[i] as f64 + age_ms * 0.1
                };
                (0..self.queues.len())
                    .filter(|&i| !self.queues[i].is_empty())
                    // total_cmp: scores are finite here, but a NaN-safe
                    // total order costs nothing and cannot panic.
                    .max_by(|&a, &b| score(a).total_cmp(&score(b)))
                    .map(|i| KernelId(i as u32))?
            }
        };
        let q = &mut self.queues[kernel.index()];
        let mut taken = 0usize;
        while taken < max_batch {
            let Some(front) = q.front_mut() else { break };
            let span_rows = front.token.rows();
            debug_assert!(span_rows > 0, "zero-row span in queue");
            if span_rows <= max_batch - taken {
                taken += span_rows;
                out.push(q.pop_front().unwrap());
            } else {
                let head = Queued {
                    enqueued: front.enqueued,
                    token: front.token.take_front(max_batch - taken),
                };
                taken = max_batch;
                out.push(head);
            }
        }
        self.rows[kernel.index()] -= taken;
        self.total_queued -= taken;
        Some(kernel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: KernelId = KernelId(0);
    const B: KernelId = KernelId(1);
    const C: KernelId = KernelId(2);

    fn pend(token: u32) -> Queued<u32> {
        Queued {
            enqueued: Instant::now(),
            token,
        }
    }

    fn take<T: SpanToken>(
        qs: &mut QueueSet<T>,
        ctx: Option<KernelId>,
        max: usize,
    ) -> Option<(KernelId, Vec<Queued<T>>)> {
        let mut out = Vec::new();
        let k = qs.take_batch_into(ctx, max, Instant::now(), &mut out)?;
        Some((k, out))
    }

    /// A splittable test span mirroring the production `RowSpan` shape.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    struct Span {
        id: u32,
        row: u32,
        len: u32,
    }

    impl SpanToken for Span {
        fn rows(&self) -> usize {
            self.len as usize
        }

        fn take_front(&mut self, n: usize) -> Span {
            assert!(n > 0 && n < self.len as usize);
            let head = Span {
                id: self.id,
                row: self.row,
                len: n as u32,
            };
            self.row += n as u32;
            self.len -= n as u32;
            head
        }
    }

    fn span(id: u32, row: u32, len: u32) -> Queued<Span> {
        Queued {
            enqueued: Instant::now(),
            token: Span { id, row, len },
        }
    }

    #[test]
    fn affinity_preferred_when_context_has_work() {
        let mut qs = QueueSet::new(3, 16);
        qs.try_push(A, pend(1)).unwrap();
        qs.try_push(B, pend(2)).unwrap();
        qs.try_push(B, pend(3)).unwrap();
        // Worker holds A: takes A despite B being longer.
        let (kernel, items) = take(&mut qs, Some(A), 16).unwrap();
        assert_eq!(kernel, A);
        assert_eq!(items.len(), 1);
    }

    #[test]
    fn steals_longest_queue_without_affinity() {
        let mut qs = QueueSet::new(3, 16);
        qs.try_push(A, pend(1)).unwrap();
        qs.try_push(B, pend(2)).unwrap();
        qs.try_push(B, pend(3)).unwrap();
        let (kernel, items) = take(&mut qs, Some(C), 16).unwrap();
        assert_eq!(kernel, B);
        assert_eq!(items.len(), 2);
        assert_eq!(qs.total_queued, 1);
    }

    #[test]
    fn steal_weighs_rows_not_entries() {
        // One 8-row span must outweigh three single-row entries: the
        // policy measures queued work in rows.
        let mut qs = QueueSet::new(2, 64);
        qs.try_push(A, span(0, 0, 8)).unwrap();
        for i in 0..3 {
            qs.try_push(B, span(1, i, 1)).unwrap();
        }
        let (kernel, items) = take(&mut qs, None, 64).unwrap();
        assert_eq!(kernel, A);
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].token.rows(), 8);
    }

    #[test]
    fn respects_max_batch_fifo_and_reuses_the_buffer() {
        let mut qs = QueueSet::new(1, 16);
        for i in 0..10 {
            qs.try_push(A, pend(i)).unwrap();
        }
        let mut out = Vec::new();
        qs.take_batch_into(None, 4, Instant::now(), &mut out).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].token, 0);
        assert_eq!(out[3].token, 3);
        assert_eq!(qs.queued_for(A), 6);
        // The same buffer serves the next batch: cleared, not leaked.
        qs.take_batch_into(None, 4, Instant::now(), &mut out).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].token, 4);
    }

    #[test]
    fn oversized_span_splits_across_successive_takes() {
        // One 10-row span, workers taking 4 rows at a time: each take
        // carries a consecutive head while the tail stays queued —
        // the cross-worker fan-out of a single big batch.
        let mut qs = QueueSet::new(1, 64);
        qs.try_push(A, span(7, 0, 10)).unwrap();
        assert_eq!(qs.queued_for(A), 10);
        let (_, t1) = take(&mut qs, None, 4).unwrap();
        assert_eq!(t1.len(), 1);
        assert_eq!(t1[0].token, Span { id: 7, row: 0, len: 4 });
        assert_eq!(qs.queued_for(A), 6);
        let (_, t2) = take(&mut qs, None, 4).unwrap();
        assert_eq!(t2[0].token, Span { id: 7, row: 4, len: 4 });
        let (_, t3) = take(&mut qs, None, 4).unwrap();
        assert_eq!(t3[0].token, Span { id: 7, row: 8, len: 2 });
        assert!(qs.is_empty());
        assert!(take(&mut qs, None, 4).is_none());
    }

    #[test]
    fn take_pops_whole_spans_then_splits_the_last() {
        let mut qs = QueueSet::new(1, 64);
        qs.try_push(A, span(1, 0, 3)).unwrap();
        qs.try_push(A, span(2, 0, 5)).unwrap();
        // Budget 6: the whole first span plus a 3-row head of the
        // second; the second's 2-row tail stays at the front.
        let (_, items) = take(&mut qs, None, 6).unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].token, Span { id: 1, row: 0, len: 3 });
        assert_eq!(items[1].token, Span { id: 2, row: 0, len: 3 });
        assert_eq!(qs.queued_for(A), 2);
        let (_, rest) = take(&mut qs, None, 6).unwrap();
        assert_eq!(rest[0].token, Span { id: 2, row: 3, len: 2 });
    }

    #[test]
    fn depth_counts_rows_not_entries() {
        let mut qs = QueueSet::new(1, 8);
        qs.try_push(A, span(1, 0, 5)).unwrap();
        // 5 + 4 > 8: refused, handed back intact.
        let back = qs.try_push(A, span(2, 0, 4)).unwrap_err();
        assert_eq!(back.token, Span { id: 2, row: 0, len: 4 });
        qs.try_push(A, span(3, 0, 3)).unwrap();
        assert_eq!(qs.queued_for(A), 8);
        assert_eq!(qs.total_queued, 8);
    }

    #[test]
    fn empty_returns_none() {
        let mut qs: QueueSet<u32> = QueueSet::new(2, 16);
        assert!(take(&mut qs, None, 8).is_none());
    }

    #[test]
    fn depth_limit_rejects_and_hands_back() {
        let mut qs = QueueSet::new(2, 2);
        assert_eq!(qs.depth(), 2);
        qs.try_push(A, pend(1)).unwrap();
        qs.try_push(A, pend(2)).unwrap();
        // A is full: the request comes back untouched.
        let rejected = qs.try_push(A, pend(3)).unwrap_err();
        assert_eq!(rejected.token, 3);
        assert_eq!(qs.queued_for(A), 2);
        assert_eq!(qs.total_queued, 2);
        // Other queues still admit (the bound is per kernel).
        qs.try_push(B, pend(4)).unwrap();
        // Draining a batch frees capacity again.
        take(&mut qs, Some(A), 1).unwrap();
        qs.try_push(A, pend(5)).unwrap();
        assert_eq!(qs.queued_for(A), 2);
    }

    #[test]
    // Backdates entries with wall-clock Instant arithmetic; the
    // scheduling policy itself is covered by the clock-free tests.
    #[cfg_attr(miri, ignore)]
    fn age_bonus_prevents_starvation() {
        let mut qs = QueueSet::new(2, 16);
        let old = Instant::now() - std::time::Duration::from_millis(500);
        qs.try_push(
            A, // starved
            Queued {
                enqueued: old,
                token: 0u32,
            },
        )
        .unwrap();
        for i in 0..3 {
            qs.try_push(B, pend(i)).unwrap(); // busy
        }
        // 0.1/ms * 500ms = 50 > 3: the old queue wins.
        let (kernel, _) = take(&mut qs, None, 8).unwrap();
        assert_eq!(kernel, A);
    }

    #[test]
    fn high_water_burst_drains_through_take_batch_into() {
        // The shutdown path drains by repeated take_batch_into (the
        // workers' loop), not a dedicated drain call — a burst must
        // come back out completely through the same door.
        let mut qs = QueueSet::new(2, 1024);
        for i in 0..512 {
            qs.try_push(A, pend(i)).unwrap();
        }
        qs.try_push(B, pend(999)).unwrap();
        let mut out = Vec::new();
        let mut drained = 0;
        while let Some(_k) = qs.take_batch_into(None, 64, Instant::now(), &mut out) {
            drained += out.len();
        }
        assert_eq!(drained, 513);
        assert!(qs.is_empty());
        // The set stays usable afterwards.
        qs.try_push(B, pend(1)).unwrap();
        assert_eq!(qs.queued_for(B), 1);
    }
}
